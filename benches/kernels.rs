//! Kernel microbenchmarks: the PR-5 vectorized/fused tier vs the kept
//! naive oracles.
//!
//! Reports GFLOP/s (matmuls) and GB/s (gathers) plus the
//! vectorized-over-naive speedup per kernel:
//!
//! * `matmul` fwd (`x@w`), bwd-input (`g@w^T`), bwd-weight (`x^T@g`)
//! * embedding gather — the fused gather+concat (`embed_concat_fwd`)
//!   vs gather-then-copy through a staging buffer
//! * fused gather+dequantize (`QuantizedTable::row_into` per row) vs
//!   dequantize-everything-then-gather
//!
//! For peak numbers run with the machine's full SIMD set:
//! `RUSTFLAGS="-C target-cpu=native" cargo bench --bench kernels`.
//! `-- --smoke` shrinks every shape to a compile+run CI gate.

use cowclip::reference::layers::{embed_concat_fwd, embed_fwd};
use cowclip::reference::linalg::{self, naive};
use cowclip::serve::quant::QuantizedTable;
use cowclip::util::bench::bench;
use cowclip::util::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian() as f32).collect()
}

fn gflops(flops: f64, mean_ms: f64) -> f64 {
    flops / (mean_ms * 1e-3) / 1e9
}

fn gbps(bytes: f64, mean_ms: f64) -> f64 {
    bytes / (mean_ms * 1e-3) / 1e9
}

fn matmul_arm(smoke: bool) {
    let (b, m, n) = if smoke { (64, 48, 32) } else { (1024, 336, 128) };
    let (warm, reps) = if smoke { (1, 3) } else { (3, 15) };
    let mut rng = Rng::new(0xBE7C);
    let x = rand_vec(&mut rng, b * m);
    let w = rand_vec(&mut rng, m * n);
    let g = rand_vec(&mut rng, b * n);
    let flops = 2.0 * b as f64 * m as f64 * n as f64;

    println!("== kernels: matmul tier ({b}x{m} @ {m}x{n}) ==");
    let mut y = vec![0.0f32; b * n];
    let fwd_v = bench("matmul fwd (vectorized, into)", warm, reps, || {
        linalg::matmul_into(&x, &w, &mut y, b, m, n);
    });
    let fwd_n = bench("matmul fwd (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul(&x, &w, b, m, n));
    });
    let mut dx = vec![0.0f32; b * m];
    let nt_v = bench("matmul bwd-input g@w^T (vectorized)", warm, reps, || {
        linalg::matmul_nt_into(&g, &w, &mut dx, b, m, n);
    });
    let nt_n = bench("matmul bwd-input (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul_nt(&g, &w, b, m, n));
    });
    let mut dw = vec![0.0f32; m * n];
    let tn_v = bench("matmul bwd-weight x^T@g (vectorized)", warm, reps, || {
        linalg::matmul_tn_into(&x, &g, &mut dw, b, m, n);
    });
    let tn_n = bench("matmul bwd-weight (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul_tn(&x, &g, b, m, n));
    });
    std::hint::black_box((&y, &dx, &dw));

    println!("\n{:>26} {:>12} {:>12} {:>9}", "kernel", "vec GF/s", "naive GF/s", "speedup");
    for (name, v, nv) in [
        ("matmul fwd", &fwd_v, &fwd_n),
        ("matmul bwd-input", &nt_v, &nt_n),
        ("matmul bwd-weight", &tn_v, &tn_n),
    ] {
        println!(
            "{:>26} {:>12.2} {:>12.2} {:>8.2}x",
            name,
            gflops(flops, v.mean_ms()),
            gflops(flops, nv.mean_ms()),
            nv.mean_ms() / v.mean_ms()
        );
    }
    println!();
}

fn gather_arm(smoke: bool) {
    // Criteo-synth-shaped: 26 fields, d=16, plus 13 dense features
    let (vocab, b) = if smoke { (5_000, 256) } else { (200_000, 4096) };
    let (warm, reps) = if smoke { (1, 3) } else { (3, 15) };
    let (f, d, nd) = (26usize, 16usize, 13usize);
    let d0 = f * d + nd;
    let mut rng = Rng::new(0x6A7E);
    let table = rand_vec(&mut rng, vocab * d);
    let dense = rand_vec(&mut rng, b * nd);
    let ids: Vec<i32> = (0..b * f).map(|_| rng.below(vocab as u64) as i32).collect();
    let bytes = (b * f * d * 4) as f64; // embed payload moved per call

    println!("== kernels: embedding gather (b={b}, F={f}, d={d}, V={vocab}) ==");
    let mut x0 = vec![0.0f32; b * d0];
    let fused = bench("gather+concat (fused, one pass)", warm, reps, || {
        embed_concat_fwd(&table, &ids, &dense, b, f, d, nd, &mut x0);
    });
    let staged = bench("gather then copy (staging buffer)", warm, reps, || {
        let embeds = embed_fwd(&table, &ids, b, f, d);
        for i in 0..b {
            x0[i * d0..i * d0 + f * d].copy_from_slice(&embeds[i * f * d..(i + 1) * f * d]);
            x0[i * d0 + f * d..(i + 1) * d0].copy_from_slice(&dense[i * nd..(i + 1) * nd]);
        }
    });
    std::hint::black_box(&x0);
    println!(
        "\n  fused {:.2} GB/s vs staged {:.2} GB/s -> {:.2}x\n",
        gbps(bytes, fused.mean_ms()),
        gbps(bytes, staged.mean_ms()),
        staged.mean_ms() / fused.mean_ms()
    );

    // fused gather+dequantize (the quantized serving path)
    let fields: Vec<(usize, usize)> = (0..f).map(|j| (j * (vocab / f), vocab / f)).collect();
    let table_q: Vec<f32> = table[..(vocab / f) * f * d].to_vec();
    let q = QuantizedTable::quantize(&table_q, d, &fields).unwrap();
    let rows = vocab / f * f;
    let qids: Vec<usize> = (0..b * f).map(|_| rng.below(rows as u64) as usize).collect();
    let field_of = |id: usize| (id / (vocab / f)).min(f - 1);

    println!("== kernels: fused gather+dequantize (u16 codes -> f32 rows) ==");
    let mut out = vec![0.0f32; b * f * d];
    let fused_q = bench("gather+dequant (fused, per row)", warm, reps, || {
        for (slot, &id) in qids.iter().enumerate() {
            q.row_into(id, field_of(id), &mut out[slot * d..(slot + 1) * d]);
        }
    });
    let staged_q = bench("dequantize-all then gather", warm, reps, || {
        let full = q.dequantize_all();
        for (slot, &id) in qids.iter().enumerate() {
            out[slot * d..(slot + 1) * d].copy_from_slice(&full[id * d..(id + 1) * d]);
        }
    });
    std::hint::black_box(&out);
    println!(
        "\n  fused {:.2} GB/s vs staged {:.2} GB/s -> {:.2}x\n",
        gbps(bytes, fused_q.mean_ms()),
        gbps(bytes, staged_q.mean_ms()),
        staged_q.mean_ms() / fused_q.mean_ms()
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    matmul_arm(smoke);
    gather_arm(smoke);
}
