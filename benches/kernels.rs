//! Kernel microbenchmarks: the runtime-dispatched SIMD tier vs the
//! scalar blocked tier vs the kept naive oracles.
//!
//! Three tiers race on every kernel:
//!
//! * **simd** — whatever `reference::simd::active()` resolved on this
//!   host (AVX2+FMA, NEON, or scalar; pin with `COWCLIP_KERNEL=`),
//! * **scalar** — the portable blocked kernels behind the scalar
//!   vtable (the speedup denominator),
//! * **naive** — the original scalar loops (`linalg::naive`), kept as
//!   the correctness oracle.
//!
//! Covered: `matmul` fwd (`x@w`), bwd-input (`g@w^T`), bwd-weight
//! (`x^T@g`), the fused gather+concat (`embed_concat_fwd`), and the
//! fused serving gather+dequantize (`dequant_row` per gathered row).
//!
//! Reports GFLOP/s (matmuls) and GB/s (gathers) plus the
//! simd-over-scalar speedup per kernel, and writes the same numbers —
//! with the host arch, the detected CPU features and the active kernel
//! tier — to `BENCH_kernels.json` for the CI artifact trail. No
//! `RUSTFLAGS` needed: dispatch is resolved at startup from runtime
//! feature detection. `-- --smoke` shrinks every shape to a
//! compile+run CI gate.

use cowclip::obs::{bench_report, obj, write_json_report};
use cowclip::reference::layers::embed_fwd;
use cowclip::reference::linalg::naive;
use cowclip::reference::simd::{self, scalar};
use cowclip::serve::quant::QuantizedTable;
use cowclip::util::bench::bench;
use cowclip::util::json::Json;
use cowclip::util::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian() as f32).collect()
}

fn gflops(flops: f64, mean_ms: f64) -> f64 {
    flops / (mean_ms * 1e-3) / 1e9
}

fn gbps(bytes: f64, mean_ms: f64) -> f64 {
    bytes / (mean_ms * 1e-3) / 1e9
}

fn label(op: &str, tier: &str) -> String {
    format!("{op} ({tier})")
}

/// CPU features relevant to the kernel tiers, detected at runtime.
fn cpu_features() -> Vec<&'static str> {
    #[allow(unused_mut)]
    let mut out: Vec<&'static str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            out.push("avx2");
        }
        if is_x86_feature_detected!("fma") {
            out.push("fma");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            out.push("neon");
        }
    }
    out
}

/// One machine-readable result row for `BENCH_kernels.json`, built on
/// the shared `obs::snapshot` serializer so every BENCH artifact
/// carries the same `cowclip-bench-v1` schema.
fn rec(name: &str, tier: &str, shape: &str, ms: f64, rate: f64, unit: &str, spd: f64) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("tier", Json::Str(tier.to_string())),
        ("shape", Json::Str(shape.to_string())),
        ("mean_ms", Json::Num(ms)),
        (unit, Json::Num(rate)),
        ("speedup_vs_scalar", Json::Num(spd)),
    ])
}

fn matmul_arm(smoke: bool, recs: &mut Vec<Json>) {
    let (b, m, n) = if smoke { (64, 48, 32) } else { (1024, 336, 128) };
    let (warm, reps) = if smoke { (1, 3) } else { (3, 15) };
    let mut rng = Rng::new(0xBE7C);
    let x = rand_vec(&mut rng, b * m);
    let w = rand_vec(&mut rng, m * n);
    let g = rand_vec(&mut rng, b * n);
    let flops = 2.0 * b as f64 * m as f64 * n as f64;
    let shape = format!("{b}x{m}x{n}");
    let k = simd::active();
    let sc = scalar();

    println!("== kernels: matmul tier ({b}x{m} @ {m}x{n}) ==");
    let mut y = vec![0.0f32; b * n];
    let fwd_a = bench(&label("matmul_fwd", k.name), warm, reps, || {
        (k.matmul_into)(&x, &w, &mut y, b, m, n);
    });
    let fwd_s = bench("matmul_fwd (scalar)", warm, reps, || {
        (sc.matmul_into)(&x, &w, &mut y, b, m, n);
    });
    let fwd_n = bench("matmul_fwd (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul(&x, &w, b, m, n));
    });
    let mut dx = vec![0.0f32; b * m];
    let nt_a = bench(&label("matmul_bwd_input", k.name), warm, reps, || {
        (k.matmul_nt_into)(&g, &w, &mut dx, b, m, n);
    });
    let nt_s = bench("matmul_bwd_input (scalar)", warm, reps, || {
        (sc.matmul_nt_into)(&g, &w, &mut dx, b, m, n);
    });
    let nt_n = bench("matmul_bwd_input (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul_nt(&g, &w, b, m, n));
    });
    let mut dw = vec![0.0f32; m * n];
    let tn_a = bench(&label("matmul_bwd_weight", k.name), warm, reps, || {
        (k.matmul_tn_into)(&x, &g, &mut dw, b, m, n);
    });
    let tn_s = bench("matmul_bwd_weight (scalar)", warm, reps, || {
        (sc.matmul_tn_into)(&x, &g, &mut dw, b, m, n);
    });
    let tn_n = bench("matmul_bwd_weight (naive oracle)", warm, reps, || {
        std::hint::black_box(naive::matmul_tn(&x, &g, b, m, n));
    });
    std::hint::black_box((&y, &dx, &dw));

    println!(
        "\n{:>20} {:>12} {:>12} {:>12} {:>9}",
        "kernel", "simd GF/s", "scalar GF/s", "naive GF/s", "speedup"
    );
    for (name, a, s, nv) in [
        ("matmul_fwd", &fwd_a, &fwd_s, &fwd_n),
        ("matmul_bwd_input", &nt_a, &nt_s, &nt_n),
        ("matmul_bwd_weight", &tn_a, &tn_s, &tn_n),
    ] {
        let a_gf = gflops(flops, a.mean_ms());
        let s_gf = gflops(flops, s.mean_ms());
        let n_gf = gflops(flops, nv.mean_ms());
        let spd = s.mean_ms() / a.mean_ms();
        let n_spd = s.mean_ms() / nv.mean_ms();
        println!("{:>20} {:>12.2} {:>12.2} {:>12.2} {:>8.2}x", name, a_gf, s_gf, n_gf, spd);
        recs.push(rec(name, k.name, &shape, a.mean_ms(), a_gf, "gflops", spd));
        recs.push(rec(name, "scalar", &shape, s.mean_ms(), s_gf, "gflops", 1.0));
        recs.push(rec(name, "naive", &shape, nv.mean_ms(), n_gf, "gflops", n_spd));
    }
    println!();
}

fn gather_arm(smoke: bool, recs: &mut Vec<Json>) {
    // Criteo-synth-shaped: 26 fields, d=16, plus 13 dense features
    let (vocab, b) = if smoke { (5_000, 256) } else { (200_000, 4096) };
    let (warm, reps) = if smoke { (1, 3) } else { (3, 15) };
    let (f, d, nd) = (26usize, 16usize, 13usize);
    let d0 = f * d + nd;
    let mut rng = Rng::new(0x6A7E);
    let table = rand_vec(&mut rng, vocab * d);
    let dense = rand_vec(&mut rng, b * nd);
    let ids: Vec<i32> = (0..b * f).map(|_| rng.below(vocab as u64) as i32).collect();
    let bytes = (b * f * d * 4) as f64; // embed payload moved per call
    let gshape = format!("b={b} F={f} d={d}");
    let k = simd::active();
    let sc = scalar();

    println!("== kernels: embedding gather (b={b}, F={f}, d={d}, V={vocab}) ==");
    let mut x0 = vec![0.0f32; b * d0];
    let fused_a = bench(&label("gather+concat", k.name), warm, reps, || {
        (k.embed_concat_fwd)(&table, &ids, &dense, b, f, d, nd, &mut x0);
    });
    let fused_s = bench("gather+concat (scalar)", warm, reps, || {
        (sc.embed_concat_fwd)(&table, &ids, &dense, b, f, d, nd, &mut x0);
    });
    let staged = bench("gather then copy (staging buffer)", warm, reps, || {
        let embeds = embed_fwd(&table, &ids, b, f, d);
        for i in 0..b {
            x0[i * d0..i * d0 + f * d].copy_from_slice(&embeds[i * f * d..(i + 1) * f * d]);
            x0[i * d0 + f * d..(i + 1) * d0].copy_from_slice(&dense[i * nd..(i + 1) * nd]);
        }
    });
    std::hint::black_box(&x0);
    let spd = fused_s.mean_ms() / fused_a.mean_ms();
    println!(
        "\n  {} {:.2} GB/s vs scalar {:.2} GB/s vs staged {:.2} GB/s -> {:.2}x vs scalar\n",
        k.name,
        gbps(bytes, fused_a.mean_ms()),
        gbps(bytes, fused_s.mean_ms()),
        gbps(bytes, staged.mean_ms()),
        spd
    );
    let a_r = gbps(bytes, fused_a.mean_ms());
    let s_r = gbps(bytes, fused_s.mean_ms());
    recs.push(rec("embed_concat_fwd", k.name, &gshape, fused_a.mean_ms(), a_r, "gbps", spd));
    recs.push(rec("embed_concat_fwd", "scalar", &gshape, fused_s.mean_ms(), s_r, "gbps", 1.0));

    // fused gather+dequantize (the quantized serving path), routed
    // through the same vtable entry the serve scoring pass uses
    let fields: Vec<(usize, usize)> = (0..f).map(|j| (j * (vocab / f), vocab / f)).collect();
    let table_q: Vec<f32> = table[..(vocab / f) * f * d].to_vec();
    let q = QuantizedTable::quantize(&table_q, d, &fields).unwrap();
    let rows = vocab / f * f;
    let qids: Vec<usize> = (0..b * f).map(|_| rng.below(rows as u64) as usize).collect();
    let field_of = |id: usize| (id / (vocab / f)).min(f - 1);

    println!("== kernels: fused gather+dequantize (u16 codes -> f32 rows) ==");
    let mut out = vec![0.0f32; b * f * d];
    let fused_qa = bench(&label("gather+dequant", k.name), warm, reps, || {
        for (slot, &id) in qids.iter().enumerate() {
            let (min, step) = q.affine(field_of(id));
            (k.dequant_row)(q.row_codes(id), min, step, &mut out[slot * d..(slot + 1) * d]);
        }
    });
    let fused_qs = bench("gather+dequant (scalar)", warm, reps, || {
        for (slot, &id) in qids.iter().enumerate() {
            let (min, step) = q.affine(field_of(id));
            (sc.dequant_row)(q.row_codes(id), min, step, &mut out[slot * d..(slot + 1) * d]);
        }
    });
    let staged_q = bench("dequantize-all then gather", warm, reps, || {
        let full = q.dequantize_all();
        for (slot, &id) in qids.iter().enumerate() {
            out[slot * d..(slot + 1) * d].copy_from_slice(&full[id * d..(id + 1) * d]);
        }
    });
    std::hint::black_box(&out);
    let qspd = fused_qs.mean_ms() / fused_qa.mean_ms();
    println!(
        "\n  {} {:.2} GB/s vs scalar {:.2} GB/s vs staged {:.2} GB/s -> {:.2}x vs scalar\n",
        k.name,
        gbps(bytes, fused_qa.mean_ms()),
        gbps(bytes, fused_qs.mean_ms()),
        gbps(bytes, staged_q.mean_ms()),
        qspd
    );
    let qa_r = gbps(bytes, fused_qa.mean_ms());
    let qs_r = gbps(bytes, fused_qs.mean_ms());
    recs.push(rec("dequant_row", k.name, &gshape, fused_qa.mean_ms(), qa_r, "gbps", qspd));
    recs.push(rec("dequant_row", "scalar", &gshape, fused_qs.mean_ms(), qs_r, "gbps", 1.0));
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let k = simd::active();
    let features = cpu_features();
    println!(
        "simd kernels: {} (arch {}, features [{}])\n",
        k.name,
        std::env::consts::ARCH,
        features.join(" ")
    );
    let mut recs: Vec<Json> = Vec::new();
    matmul_arm(smoke, &mut recs);
    gather_arm(smoke, &mut recs);

    let n_rows = recs.len();
    let report = bench_report(
        "kernels",
        smoke,
        &[
            ("cpu_features", Json::Arr(features.iter().map(|f| Json::Str(f.to_string())).collect())),
            ("kernel", Json::Str(k.name.to_string())),
        ],
        recs,
    );
    write_json_report("BENCH_kernels.json", &report);
    println!("({n_rows} kernel rows)");
}
