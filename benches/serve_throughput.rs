//! Serving throughput: batched vs unbatched micro-batching, f32 vs
//! quantized tables.
//!
//! An open-loop driver pre-enqueues a fixed request load (drawn from the
//! training synthesizer's Zipf id model, so the embedding gather sees
//! production-shaped skew) and the table reports, per configuration:
//! achieved QPS, p50/p99 request latency (enqueue → scored) from the
//! shared `metrics::LatencyHistogram`, and the mean coalesced batch
//! size. The batched rows should beat `max_batch = 1` on QPS by roughly
//! the per-forward fixed-cost amortization; the quantized rows show the
//! ~2x table-memory cut at near-identical throughput.
//!
//! `-- --smoke` runs a small config (CI compile+run gate).

use std::sync::Arc;
use std::time::Duration;

use cowclip::data::schema::criteo_synth;
use cowclip::data::synth::{RowSampler, SynthConfig};
use cowclip::model::init::{init_params, InitConfig};
use cowclip::reference::step::build_spec;
use cowclip::reference::{ModelKind, ReferenceModel};
use cowclip::serve::{score_all, Request, ServeConfig, ServeModel, Server};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_requests = if smoke { 2_000 } else { 20_000 };

    let schema = criteo_synth();
    let model = ReferenceModel::new(ModelKind::DeepFm, schema.clone(), 10, vec![64, 64], 2);
    let spec = build_spec(model.kind, &schema, model.embed_dim, &model.hidden, model.n_cross);
    let params = init_params(&spec, &InitConfig { seed: 7, embed_sigma: 0.02 });

    let mut sampler = RowSampler::new(&schema, &SynthConfig { seed: 99, ..Default::default() });
    let reqs: Vec<Request> = (0..n_requests)
        .map(|i| {
            let (cat, dense) = sampler.next_row();
            Request { id: i as u64, cat, dense }
        })
        .collect();

    println!("== serve_throughput: {n_requests} open-loop requests, DeepFM/criteo_synth ==");
    println!(
        "{:>6} {:>12} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "quant", "mode", "table MiB", "QPS", "p50 ms", "p99 ms", "mean ms", "batch"
    );
    let mut qps_unbatched = 0.0f64;
    for quant in [false, true] {
        let frozen =
            Arc::new(ServeModel::from_params(model.clone(), params.clone(), quant).unwrap());
        for (mode, max_batch) in [("unbatched", 1usize), ("batched-64", 64)] {
            let cfg = ServeConfig {
                max_batch,
                max_delay: Duration::from_micros(500),
                threads: 2,
                max_queue: 0,
            };
            let server = Server::start(Arc::clone(&frozen), cfg);
            let client = server.client();
            let scored = score_all(&client, reqs.clone()).unwrap();
            assert_eq!(scored.len(), reqs.len());
            let stats = server.shutdown().unwrap();
            let (p50, _p90, p99, mean) = stats.latency.summary();
            println!(
                "{:>6} {:>12} {:>10.1} {:>10.0} {:>10.3} {:>10.3} {:>10.3} {:>10.1}",
                quant,
                mode,
                frozen.table_bytes() as f64 / (1 << 20) as f64,
                stats.qps(),
                p50,
                p99,
                mean,
                stats.mean_batch()
            );
            if !quant && max_batch == 1 {
                qps_unbatched = stats.qps();
            } else if !quant && qps_unbatched > 0.0 {
                println!(
                    "{:>6} {:>12} batching speedup vs unbatched: {:.2}x",
                    "", "", stats.qps() / qps_unbatched
                );
            }
        }
    }
    let f32_model = ServeModel::from_params(model.clone(), params.clone(), false).unwrap();
    let q_model = ServeModel::from_params(model, params, true).unwrap();
    println!(
        "table memory: {:.1} MiB f32 -> {:.1} MiB quantized ({:.2}x)",
        f32_model.table_bytes() as f64 / (1 << 20) as f64,
        q_model.table_bytes() as f64 / (1 << 20) as f64,
        f32_model.table_bytes() as f64 / q_model.table_bytes() as f64
    );
}
