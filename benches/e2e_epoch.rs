//! End-to-end epoch bench (Table 6's measured side): full training
//! epochs per batch size, reporting wall time and the speedup series.

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::{criteo_preset, paper_label};
use cowclip::scaling::rules::ScalingRule;

fn main() {
    let runtime = match Runtime::open_default() {
        Ok(r) => std::sync::Arc::new(r),
        Err(e) => {
            eprintln!("SKIP e2e_epoch: {e:#}");
            return;
        }
    };
    let schema = runtime.manifest().schema("criteo_synth").unwrap();
    let n = 40_000;
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let preset = criteo_preset();

    println!("== e2e_epoch: DeepFM+CowClip, one epoch of {} rows ==", train.n());
    println!(
        "{:>8} {:>8} {:>10} {:>10} {:>10} {:>9}",
        "batch", "paper", "steps", "epoch s", "speedup", "AUC %"
    );
    let mut base = 0.0f64;
    for batch in [64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
        if batch > train.n() {
            break;
        }
        let engine =
            Engine::hlo(runtime.clone(), ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)
                .unwrap();
        let cfg = TrainConfig {
            batch,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers: 1,
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: 1234,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mut trainer = Trainer::new(engine, cfg).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        let t = report.seconds("step");
        if base == 0.0 {
            base = t;
        }
        println!(
            "{:>8} {:>8} {:>10} {:>10.1} {:>9.2}x {:>9.2}",
            batch,
            paper_label(batch).unwrap_or("-"),
            report.steps,
            t,
            base / t,
            report.final_auc * 100.0
        );
    }
}
