//! End-to-end epoch bench (Table 6's measured side).
//!
//! Arm 1 (always runs): the pure-Rust reference engine, sparse
//! touched-rows embedding path vs the legacy dense O(V·d) path — the
//! speedup the coordinator refactor buys on the optimizer side.
//!
//! Arm 2 (always runs): the threaded execution engine — 4 logical
//! workers fanned out over 1/2/4 threads with reduce-as-ready merging
//! and the prefetching data pipeline, reporting step-throughput speedup
//! over the sequential baseline (target: ≥1.5x at 4 workers).
//!
//! Arm 3 (always runs): the shard-owned apply stage — `clip → L2 → Adam`
//! over 1/2/4/8 parameter shards, reporting the apply-phase and
//! full-step speedup vs the leader-serial path (target: apply > 1x at
//! ≥4 shards; results are bitwise identical across rows, gated by
//! `rust/tests/shard_parity.rs`).
//!
//! Arm 4 (always runs): the zero-allocation hot path — single-worker
//! single-thread full steps on the PR-5 vectorized kernels + scratch
//! arenas, reporting absolute step throughput (steps/s and rows/s).
//! This is the number to compare against the PR-4 baseline build: same
//! config, same batches, only the kernel/memory tier changed (the
//! parity suites pin the math).
//!
//! Arm 5 (always runs): the multi-process all-reduce path — 2 ranks
//! over a framed Unix-socket transport (run in-process on threads so
//! the bench binary stays self-contained), lossless vs u8-quantized
//! sparse gradients with error feedback, reporting rows/s, on-wire
//! bytes per step, and the sparse compression ratio. Written to
//! `BENCH_dist.json` for the CI artifact trail.
//!
//! Arm 6 (needs `make artifacts` + the `pjrt` feature): full training
//! epochs through the AOT/PJRT path per batch size, reporting wall time
//! and the speedup series.
//!
//! `-- --smoke` runs tiny threaded-arm, sharded-arm, hot-path and
//! distributed configs (CI compile+run gate, a few seconds).
//!
//! The hot-path arm's numbers are also written to `BENCH_e2e.json` —
//! tagged with the host arch and the active SIMD kernel tier (see
//! `reference::simd`) — so CI can archive the throughput trajectory
//! alongside `BENCH_kernels.json` and `BENCH_dist.json`.

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::obs::{bench_report, obj, write_json_report};
use cowclip::util::json::Json;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::{ModelKind, ReferenceEngine, ReferenceModel};
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::{criteo_preset, paper_label};
use cowclip::scaling::rules::ScalingRule;

fn reference_cfg(batch: usize) -> TrainConfig {
    let preset = criteo_preset();
    TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 1.0,
        workers: 1,
        threads: 1,
        param_shards: 1,
        warmup_steps: 0,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    }
}

fn reference_engine(schema: &cowclip::data::Schema) -> Engine {
    Engine::Reference(ReferenceEngine::new(
        ReferenceModel::new(ModelKind::DeepFm, schema.clone(), 10, vec![64, 64], 2),
        ClipMode::CowClip,
    ))
}

/// Threaded arm: 4 logical workers, sequential vs 2 vs 4 threads. The
/// same batches, the same rank-ordered merges — only the overlap of
/// shard gradients, reduction, and batch prefetch changes.
fn reference_threaded_speedup(smoke: bool) {
    let schema = cowclip::data::schema::criteo_synth();
    let n = if smoke { 6_000 } else { 20_000 };
    let batch = if smoke { 512 } else { 2048 };
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    println!("== e2e_epoch (reference engine): threaded workers vs sequential ==");
    println!(
        "{:>8} {:>8} {:>8} {:>10} {:>10} {:>10} {:>9}",
        "batch", "workers", "threads", "steps", "step s", "data s", "speedup"
    );
    let mut base = 0.0f64;
    for threads in [1usize, 2, 4] {
        let mut cfg = reference_cfg(batch);
        cfg.workers = 4;
        cfg.threads = threads;
        let mut trainer = Trainer::new(reference_engine(&schema), cfg).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        let t = report.seconds("step").max(1e-9);
        if threads == 1 {
            base = t;
        }
        println!(
            "{:>8} {:>8} {:>8} {:>10} {:>10.2} {:>10.2} {:>8.2}x",
            batch,
            4,
            threads,
            report.steps,
            t,
            report.seconds("data"),
            base / t
        );
    }
    println!(
        "(speedup = sequential step time / threaded step time; batches and \
         results are identical across rows — see rust/tests/parallel_parity.rs)\n"
    );
}

/// Sharded-apply arm: same batches, same math (bitwise — see
/// `shard_parity.rs`), only the number of apply-stage parameter shards
/// changes. Reports the apply-phase speedup the shard-owned store buys
/// over the leader-serial path, and the full-step speedup it implies.
fn reference_sharded_apply_speedup(smoke: bool) {
    let schema = cowclip::data::schema::criteo_synth();
    let n = if smoke { 6_000 } else { 20_000 };
    let batch = if smoke { 512 } else { 2048 };
    let shard_ladder: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    println!("== e2e_epoch (reference engine): sharded apply vs leader-serial ==");
    println!(
        "{:>8} {:>8} {:>10} {:>10} {:>10} {:>12} {:>11}",
        "batch", "shards", "steps", "apply s", "step s", "apply spdup", "step spdup"
    );
    let mut base_apply = 0.0f64;
    let mut base_step = 0.0f64;
    for &shards in shard_ladder {
        let mut cfg = reference_cfg(batch);
        cfg.workers = 1; // isolate the apply stage from the fan-out
        cfg.threads = 0; // auto threads for the shard fan-out
        cfg.param_shards = shards;
        let mut trainer = Trainer::new(reference_engine(&schema), cfg).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        let apply = report.seconds("apply").max(1e-9);
        let step = report.seconds("step").max(1e-9);
        if shards == 1 {
            base_apply = apply;
            base_step = step;
        }
        println!(
            "{:>8} {:>8} {:>10} {:>10.2} {:>10.2} {:>11.2}x {:>10.2}x",
            batch,
            trainer.store.n_shards(),
            report.steps,
            apply,
            step,
            base_apply / apply,
            base_step / step
        );
    }
    println!(
        "(apply spdup = serial apply time / sharded apply time; params, \
         moments and losses are identical across rows)\n"
    );
}

/// Hot-path arm: absolute full-step throughput of the tuned
/// single-worker loop (vectorized kernels, fused gather+concat, scratch
/// arenas, tree reduce, deferred-merge apply). Print-and-compare across
/// PR builds — the parity gates guarantee the math is unchanged, so any
/// delta here is pure systems speedup.
fn reference_hot_path_throughput(smoke: bool) -> Vec<Json> {
    let schema = cowclip::data::schema::criteo_synth();
    let n = if smoke { 6_000 } else { 20_000 };
    let batches: &[usize] = if smoke { &[512] } else { &[512, 2048] };
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    println!("== e2e_epoch (reference engine): zero-alloc hot path, absolute throughput ==");
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>12}",
        "batch", "steps", "step s", "steps/s", "rows/s"
    );
    let mut rows = Vec::new();
    for &batch in batches {
        let mut trainer = Trainer::new(reference_engine(&schema), reference_cfg(batch)).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        let steps = report.steps;
        let t = report.seconds("step").max(1e-9);
        let steps_s = steps as f64 / t;
        let rows_s = (steps * batch) as f64 / t;
        println!("{batch:>8} {steps:>10} {t:>10.2} {steps_s:>10.1} {rows_s:>12.0}");
        rows.push(obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("steps", Json::Num(steps as f64)),
            ("step_s", Json::Num(t)),
            ("steps_per_s", Json::Num(steps_s)),
            ("rows_per_s", Json::Num(rows_s)),
        ]));
    }
    println!(
        "(compare across PR builds at fixed config: the kernel/memory tier \
         is the only variable — see benches/kernels.rs for per-kernel numbers)\n"
    );
    rows
}

/// Machine-readable mirror of the hot-path arm, tagged with the host
/// arch and the active SIMD kernel tier — shares the `cowclip-bench-v1`
/// schema (via `obs::snapshot`) with `BENCH_kernels.json` and
/// `BENCH_dist.json`.
fn write_bench_json(smoke: bool, rows: Vec<Json>) {
    let kernel = cowclip::reference::simd::active().name;
    let report = bench_report(
        "e2e_epoch",
        smoke,
        &[("kernel", Json::Str(kernel.to_string()))],
        rows,
    );
    write_json_report("BENCH_e2e.json", &report);
}

/// Distributed arm: 2 ranks exchanging sparse contributions over a
/// framed Unix socket (coordinator + workers on threads of this
/// process — the protocol is identical to the multi-process CLI path).
/// Lossless vs u8-quantized uplink; the parity and AUC gates live in
/// `rust/tests/dist_parity.rs`, this arm measures throughput + traffic.
fn reference_distributed(smoke: bool) -> Vec<Json> {
    use cowclip::coordinator::{coordinate, dist_worker, DistOptions, Endpoint};
    use cowclip::wire::Compression;

    let schema = cowclip::data::schema::criteo_synth();
    let n = if smoke { 6_000 } else { 20_000 };
    let batch = if smoke { 512 } else { 2048 };
    let ranks = 2usize;
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    println!("== e2e_epoch: 2-rank socket all-reduce (framed unix transport) ==");
    println!(
        "{:>8} {:>9} {:>8} {:>8} {:>12} {:>13} {:>7}",
        "batch", "compress", "steps", "wall s", "rows/s", "wire B/step", "ratio"
    );
    let mut rows = Vec::new();
    for compress in [Compression::None, Compression::U8] {
        let sock = std::env::temp_dir().join(format!(
            "cowclip_bench_dist_{}_{compress}.sock",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&sock);
        let mut cfg = reference_cfg(batch);
        cfg.workers = ranks;
        let opts = DistOptions::new(
            ranks,
            Endpoint::Unix(sock.clone()),
            compress,
            std::time::Duration::from_secs(60),
        );
        let report = std::thread::scope(|s| {
            let (schema, cfg, opts, train) = (&schema, &cfg, &opts, &train);
            let handles: Vec<_> = (0..ranks)
                .map(|rank| {
                    s.spawn(move || {
                        let engine = reference_engine(schema);
                        dist_worker(&engine, cfg, train, rank, opts)
                    })
                })
                .collect();
            let engine = reference_engine(schema);
            let (report, _store) = coordinate(&engine, cfg, train, &test, opts).unwrap();
            for h in handles {
                h.join().unwrap().unwrap();
            }
            report
        });
        let _ = std::fs::remove_file(&sock);
        let steps = report.steps.max(1);
        let rows_s = (steps * batch) as f64 / report.wall_seconds.max(1e-9);
        let wire_per_step = report.stats.wire_bytes / steps as u64;
        let ratio = report.stats.compression_ratio();
        println!(
            "{:>8} {:>9} {:>8} {:>8.2} {:>12.0} {:>13} {:>6.2}x",
            batch, compress, steps, report.wall_seconds, rows_s, wire_per_step, ratio
        );
        rows.push(obj(vec![
            ("ranks", Json::Num(ranks as f64)),
            ("compress", Json::Str(compress.to_string())),
            ("batch", Json::Num(batch as f64)),
            ("steps", Json::Num(steps as f64)),
            ("wall_s", Json::Num(report.wall_seconds)),
            ("rows_per_s", Json::Num(rows_s)),
            ("wire_bytes_per_step", Json::Num(wire_per_step as f64)),
            ("compression_ratio", Json::Num(ratio)),
        ]));
    }
    println!(
        "(rows/s includes the final eval; wire B/step sums both ranks' uplink \
         frames; ratio covers the sparse sections only — dense MLP grads and \
         the lossless broadcast are never quantized)\n"
    );
    rows
}

/// Machine-readable mirror of the distributed arm (`BENCH_dist.json`),
/// on the same shared `cowclip-bench-v1` schema.
fn write_dist_json(smoke: bool, rows: Vec<Json>) {
    let report = bench_report("dist_allreduce", smoke, &[], rows);
    write_json_report("BENCH_dist.json", &report);
}

fn reference_sparse_vs_dense() {
    let schema = cowclip::data::schema::criteo_synth();
    let n = 20_000;
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    println!("== e2e_epoch (reference engine): sparse vs dense embedding path ==");
    println!(
        "{:>8} {:>10} {:>12} {:>12} {:>9}",
        "batch", "steps", "dense s", "sparse s", "speedup"
    );
    for batch in [512usize, 2048] {
        let mut times = [0.0f64; 2];
        for (arm, dense) in [(0usize, true), (1, false)] {
            let engine = Engine::Reference(
                ReferenceEngine::new(
                    ReferenceModel::new(
                        ModelKind::DeepFm,
                        schema.clone(),
                        10,
                        vec![64, 64],
                        2,
                    ),
                    ClipMode::CowClip,
                )
                .with_dense_grads(dense),
            );
            let mut trainer = Trainer::new(engine, reference_cfg(batch)).unwrap();
            let report = trainer.train(&train, &test).unwrap();
            times[arm] = report.seconds("step");
            if arm == 1 {
                println!(
                    "{:>8} {:>10} {:>12.2} {:>12.2} {:>8.2}x",
                    batch,
                    report.steps,
                    times[0],
                    times[1],
                    times[0] / times[1]
                );
            }
        }
    }
    println!(
        "(speedup reflects grad densification + dense accumulate/clip/Adam \
         vs the touched-rows path; the model forward/backward is shared)\n"
    );
}

fn hlo_epochs() {
    let runtime = match Runtime::open_default() {
        Ok(r) => std::sync::Arc::new(r),
        Err(e) => {
            eprintln!("SKIP hlo arm of e2e_epoch: {e:#}");
            return;
        }
    };
    let schema = runtime.manifest().schema("criteo_synth").unwrap();
    let n = 40_000;
    let ds = generate(&schema, &SynthConfig { n, seed: 2, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let preset = criteo_preset();

    println!("== e2e_epoch: DeepFM+CowClip, one epoch of {} rows ==", train.n());
    println!(
        "{:>8} {:>8} {:>10} {:>10} {:>10} {:>9}",
        "batch", "paper", "steps", "epoch s", "speedup", "AUC %"
    );
    let mut base = 0.0f64;
    for batch in [64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
        if batch > train.n() {
            break;
        }
        let engine =
            Engine::hlo(runtime.clone(), ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)
                .unwrap();
        let cfg = TrainConfig {
            batch,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers: 1,
            threads: 1,
            param_shards: 1,
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: 1234,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mut trainer = Trainer::new(engine, cfg).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        let t = report.seconds("step");
        if base == 0.0 {
            base = t;
        }
        println!(
            "{:>8} {:>8} {:>10} {:>10.1} {:>9.2}x {:>9.2}",
            batch,
            paper_label(batch).unwrap_or("-"),
            report.steps,
            t,
            base / t,
            report.final_auc * 100.0
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        let rows = reference_hot_path_throughput(true);
        reference_threaded_speedup(true);
        reference_sharded_apply_speedup(true);
        let dist_rows = reference_distributed(true);
        write_bench_json(true, rows);
        write_dist_json(true, dist_rows);
        return;
    }
    let rows = reference_hot_path_throughput(false);
    reference_sparse_vs_dense();
    reference_threaded_speedup(false);
    reference_sharded_apply_speedup(false);
    let dist_rows = reference_distributed(false);
    hlo_epochs();
    write_bench_json(false, rows);
    write_dist_json(false, dist_rows);
}
