//! Bench for paper Figure 1: one-optimizer-step time vs batch size.
//! Prints per-batch step time and the relative-time series.

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::batcher::Batcher;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::{criteo_preset, paper_label};
use cowclip::scaling::rules::ScalingRule;
use cowclip::util::bench::bench;

fn main() {
    let runtime = match Runtime::open_default() {
        Ok(r) => std::sync::Arc::new(r),
        Err(e) => {
            eprintln!("SKIP fig1_step_time: {e:#}");
            return;
        }
    };
    let schema = runtime.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 20_000, seed: 1, ..Default::default() });
    let preset = criteo_preset();

    println!("== fig1_step_time: DeepFM optimizer-step latency vs batch ==");
    let mut base = 0.0;
    for batch in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let engine =
            Engine::hlo(runtime.clone(), ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)
                .unwrap();
        let cfg = TrainConfig {
            batch,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers: 1,
            threads: 1,      // sequential: this bench times the raw step
            param_shards: 1, // serial apply for the same reason
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: 1,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mut trainer = Trainer::new(engine, cfg).unwrap();
        let mut batcher = Batcher::new(&ds, batch, 0);
        let reps = if batch <= 512 { 8 } else { 3 };
        let r = bench(
            &format!("train_step b={batch} ({})", paper_label(batch).unwrap_or("-")),
            1,
            reps,
            || {
                let b = batcher.next_batch();
                trainer.train_step(&b).unwrap();
            },
        );
        if base == 0.0 {
            base = r.mean_ms();
        }
        println!("    relative: {:.2}x", r.mean_ms() / base);
    }
}
