//! Data-pipeline throughput: synthesis, batching, top-k transform, and
//! the double-buffered prefetcher. The coordinator's data phase must
//! stay <10% of step time (EXPERIMENTS.md §Perf); the prefetch arms
//! measure how much of it the background thread hides when the consumer
//! is busy (as the trainer is).

use cowclip::data::batcher::{Batch, Batcher};
use cowclip::data::prefetch::Prefetch;
use cowclip::data::schema::criteo_synth;
use cowclip::data::stream::StreamReader;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::data::transform::topk_collapse;
use cowclip::data::Dataset;
use cowclip::util::bench::{bench, throughput};

/// Stand-in for a training step: consume the batch (touched-id sort plus
/// a dense checksum) so the producer thread has something to overlap.
fn consume(b: &Batch) -> f64 {
    let (ids, counts) = b.touched().unwrap();
    let mut acc = ids.len() as f64;
    for c in counts {
        acc += c as f64;
    }
    for &x in b.x_dense.as_f32().unwrap() {
        acc += x as f64;
    }
    acc
}

/// Time one batch source inline vs behind a depth-2 [`Prefetch`] (whose
/// producer also warms the touched cache), and print the overlap win.
/// `mk` must yield the same sequence on every call.
fn overlap_arm<I, F>(what: &str, mk: F)
where
    F: Fn() -> I,
    I: Iterator<Item = Batch> + Send,
{
    let t0 = std::time::Instant::now();
    let mut inline_sink = 0.0f64;
    for b in mk() {
        inline_sink += consume(&b);
    }
    let inline_s = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let prefetched_sink = std::thread::scope(|s| {
        let feed = Prefetch::spawn(
            s,
            mk().map(|b| {
                let _ = b.touched(); // warm the cache on the producer
                b
            }),
            2,
        );
        let mut acc = 0.0f64;
        while let Some(b) = feed.recv() {
            acc += consume(&b);
        }
        acc
    });
    let prefetch_s = t0.elapsed().as_secs_f64();
    assert_eq!(inline_sink, prefetched_sink, "{what}: prefetch changed the data");
    println!(
        "    {what}: inline {:.3}s   prefetched {:.3}s   speedup {:.2}x",
        inline_s,
        prefetch_s,
        inline_s / prefetch_s.max(1e-9)
    );
    std::hint::black_box(inline_sink);
}

fn prefetch_arms(ds: &Dataset) {
    let batch = 4096usize;
    let steps = 40usize;
    println!("  -- prefetch overlap (batch {batch}) --");

    overlap_arm("in-memory batcher ", || {
        let mut b = Batcher::new(ds, batch, 7);
        (0..steps).map(move |_| b.next_batch())
    });

    let dir = std::env::temp_dir().join(format!("ctr_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipeline.ctr");
    ds.save(&path).unwrap();
    let r = StreamReader::open(&path).unwrap();
    overlap_arm("streamed from disk", || r.epoch(batch, 3).map(|b| b.unwrap()));
    std::fs::remove_dir_all(&dir).ok();
}

fn main() {
    println!("== data_pipeline ==");
    let schema = criteo_synth();

    let r = bench("synthesize 20k rows", 1, 3, || {
        std::hint::black_box(generate(
            &schema,
            &SynthConfig { n: 20_000, seed: 9, ..Default::default() },
        ));
    });
    println!("    rows/s: {:.0}k", throughput(&r, 20_000) / 1e3);

    let ds = generate(&schema, &SynthConfig { n: 50_000, seed: 9, ..Default::default() });
    for batch in [64usize, 512, 4096] {
        let mut batcher = Batcher::new(&ds, batch, 0);
        let r = bench(&format!("next_batch b={batch}"), 10, 50, || {
            std::hint::black_box(batcher.next_batch());
        });
        println!("    rows/s: {:.1}M", throughput(&r, batch) / 1e6);
    }

    prefetch_arms(&ds);

    let r = bench("topk_collapse k=3 (50k rows)", 1, 3, || {
        std::hint::black_box(topk_collapse(&ds, 3));
    });
    println!("    rows/s: {:.0}k", throughput(&r, 50_000) / 1e3);
}
