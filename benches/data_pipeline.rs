//! Data-pipeline throughput: synthesis, batching, top-k transform. The
//! coordinator's data phase must stay <10% of step time (EXPERIMENTS.md
//! §Perf).

use cowclip::data::batcher::Batcher;
use cowclip::data::schema::criteo_synth;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::data::transform::topk_collapse;
use cowclip::util::bench::{bench, throughput};

fn main() {
    println!("== data_pipeline ==");
    let schema = criteo_synth();

    let r = bench("synthesize 20k rows", 1, 3, || {
        std::hint::black_box(generate(
            &schema,
            &SynthConfig { n: 20_000, seed: 9, ..Default::default() },
        ));
    });
    println!("    rows/s: {:.0}k", throughput(&r, 20_000) / 1e3);

    let ds = generate(&schema, &SynthConfig { n: 50_000, seed: 9, ..Default::default() });
    for batch in [64usize, 512, 4096] {
        let mut batcher = Batcher::new(&ds, batch, 0);
        let r = bench(&format!("next_batch b={batch}"), 10, 50, || {
            std::hint::black_box(batcher.next_batch());
        });
        println!("    rows/s: {:.1}M", throughput(&r, batch) / 1e6);
    }

    let r = bench("topk_collapse k=3 (50k rows)", 1, 3, || {
        std::hint::black_box(topk_collapse(&ds, 3));
    });
    println!("    rows/s: {:.0}k", throughput(&r, 50_000) / 1e3);
}
