//! AUC / logloss throughput: the eval path must not bottleneck the
//! trainer (the paper evaluates 4.5M test rows per epoch at full scale).

use cowclip::metrics::{auc, logloss_from_logits};
use cowclip::util::bench::{bench, throughput};
use cowclip::util::Rng;

fn main() {
    println!("== metrics_auc ==");
    for n in [10_000usize, 100_000, 1_000_000] {
        let mut rng = Rng::new(1);
        let scores: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        let labels: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.26) as u8).collect();
        let r = bench(&format!("auc n={n}"), 1, 5, || {
            std::hint::black_box(auc(&scores, &labels));
        });
        println!("    rows/s: {:.1}M", throughput(&r, n) / 1e6);
        let r = bench(&format!("logloss n={n}"), 1, 5, || {
            std::hint::black_box(logloss_from_logits(&scores, &labels));
        });
        println!("    rows/s: {:.1}M", throughput(&r, n) / 1e6);
    }
}
