//! Clipping-strategy throughput over the [V, d] gradient table (host
//! reference implementations) — the L1 hot-spot's CPU twin.
//!
//! Two arms per mode:
//! * **dense** — the O(V·d) full-table pass the seed shipped;
//! * **sparse** — the touched-rows pass over a Criteo-like skewed batch
//!   (batch ids ≪ vocab), which is what the trainer actually runs.
//!
//! The printed `speedup vs dense` column is the acceptance number: with
//! a realistic batch touching a few hundred of ~48k rows it lands well
//! above 10x for every mode except AdaField (whose adaptive threshold
//! reads the full per-field ||w||; see clip/variants.rs).

use cowclip::clip::{
    clip_embedding_grads, clip_embedding_grads_sparse, ClipMode, ClipParams,
};
use cowclip::data::batcher::Batch;
use cowclip::data::schema::criteo_synth;
use cowclip::tensor::{SparseRows, Tensor};
use cowclip::util::bench::{bench, throughput};
use cowclip::util::Rng;

fn main() {
    let schema = criteo_synth();
    let v = schema.total_vocab();
    let d = 10;
    let mut rng = Rng::new(7);
    let g0: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32).collect();
    let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.01).collect();
    let counts: Vec<f32> = (0..v).map(|_| rng.below(4) as f32).collect();
    let p = ClipParams::default();

    println!("== clip_throughput: host reference, V={v} d={d} ==");
    let mut dense_ms = Vec::with_capacity(ClipMode::ALL.len());
    for mode in ClipMode::ALL {
        let mut g = g0.clone();
        let r = bench(&format!("dense  clip mode={mode}"), 2, 10, || {
            g.copy_from_slice(&g0);
            clip_embedding_grads(mode, &mut g, &w, &counts, &schema, d, &p);
        });
        println!("    rows/s: {:.1}M", throughput(&r, v) / 1e6);
        dense_ms.push(r.mean_ms());
    }

    // sparse arm: a skewed batch touches a tiny fraction of the vocab.
    // Per field, 90% of draws land on the 10 hottest ids (Fig. 4 shape).
    let batch_rows = 1024usize;
    let mut batch_ids: Vec<i32> = Vec::with_capacity(batch_rows * schema.n_cat());
    for _ in 0..batch_rows {
        for (off, vs) in schema.fields() {
            let head = (vs as u64).min(10);
            let local = if rng.below(10) < 9 {
                rng.below(head)
            } else {
                rng.below(vs as u64)
            };
            batch_ids.push((off as u64 + local) as i32);
        }
    }
    // derive the touched-id support exactly the way the trainer does
    let batch = Batch::new(
        Tensor::i32(vec![batch_rows, schema.n_cat()], batch_ids),
        Tensor::f32(vec![batch_rows, 0], vec![]),
        Tensor::f32(vec![batch_rows], vec![0.0; batch_rows]),
        batch_rows,
    );
    let (ids, sparse_counts) = batch.touched().unwrap();
    let touched = ids.len();
    let g_sparse0 = SparseRows::gather(&g0, v, d, ids);
    println!(
        "\n== sparse arm: batch {batch_rows} touches {touched} / {v} rows \
         ({:.2}%) ==",
        100.0 * touched as f64 / v as f64
    );
    for (mode, &dense_mean) in ClipMode::ALL.into_iter().zip(&dense_ms) {
        let mut gs = g_sparse0.clone();
        let r = bench(&format!("sparse clip mode={mode}"), 2, 50, || {
            gs.vals_mut().copy_from_slice(g_sparse0.vals());
            clip_embedding_grads_sparse(mode, &mut gs, &w, &sparse_counts, &schema, &p);
        });
        println!(
            "    touched rows/s: {:.1}M   speedup vs dense: {:.0}x",
            throughput(&r, touched) / 1e6,
            dense_mean / r.mean_ms()
        );
    }
}
