//! Clipping-strategy throughput over the [V, d] gradient table (host
//! reference implementations) — the L1 hot-spot's CPU twin, plus a
//! sweep of the CowClip kernel cost through the full HLO apply program.

use cowclip::clip::{clip_embedding_grads, ClipMode, ClipParams};
use cowclip::data::schema::criteo_synth;
use cowclip::util::bench::{bench, throughput};
use cowclip::util::Rng;

fn main() {
    let schema = criteo_synth();
    let v = schema.total_vocab();
    let d = 10;
    let mut rng = Rng::new(7);
    let g0: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32).collect();
    let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.01).collect();
    let counts: Vec<f32> = (0..v).map(|_| rng.below(4) as f32).collect();
    let p = ClipParams::default();

    println!("== clip_throughput: host reference, V={v} d={d} ==");
    for mode in ClipMode::ALL {
        let mut g = g0.clone();
        let r = bench(&format!("clip mode={mode}"), 2, 10, || {
            g.copy_from_slice(&g0);
            clip_embedding_grads(mode, &mut g, &w, &counts, &schema, d, &p);
        });
        println!("    rows/s: {:.1}M", throughput(&r, v) / 1e6);
    }
}
