//! Quickstart: generate a small synthetic Criteo-like dataset, train
//! DeepFM with CowClip at 8x the base batch through the AOT/PJRT path,
//! and print the test AUC.
//!
//!     make artifacts && cargo run --release --example quickstart

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::Result;

fn main() -> Result<()> {
    // 1. open the AOT artifacts (built once by `make artifacts`)
    let runtime = std::sync::Arc::new(Runtime::open_default()?);
    println!("platform: {}", runtime.platform());

    // 2. synthesize a Criteo-shaped dataset (Zipf ids + hidden teacher)
    let schema = runtime.manifest().schema("criteo_synth")?;
    let ds = generate(&schema, &SynthConfig { n: 20_000, seed: 42, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    println!("dataset: {} train / {} test rows, CTR {:.3}", train.n(), test.n(), ds.ctr());

    // 3. train DeepFM with the CowClip algorithm + scaling rule at 8x batch
    let preset = criteo_preset();
    let engine = Engine::hlo(runtime, ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)?;
    let cfg = TrainConfig {
        batch: preset.base_batch * 8,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 2.0,
        workers: 1,
        threads: 0,
        param_shards: 0,
        warmup_steps: train.n() / (preset.base_batch * 8),
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 1,
        verbose: true,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let report = trainer.train(&train, &test)?;

    println!(
        "\nfinal: AUC {:.2}%  logloss {:.4}  in {:.1}s ({} steps)",
        report.final_auc * 100.0,
        report.final_logloss,
        report.wall_seconds,
        report.steps
    );
    Ok(())
}
