//! Scaling-rule sweep: the paper's core diagnosis in one binary.
//!
//! Trains DeepFM at 1x/4x/8x the base batch under No/Sqrt/Linear/CowClip
//! scaling and prints the AUC grid — a compact live version of Tables
//! 2/4.
//!
//!     cargo run --release --example scaling_sweep

use cowclip::experiments::common::{fmt_auc, run_one, DataVariant, ExpContext, RunSpec};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::rules::ScalingRule;
use cowclip::Result;

fn main() -> Result<()> {
    let runtime = std::sync::Arc::new(Runtime::open_default()?);
    let ctx = ExpContext::new(Some(runtime), 20_000, 2.0, 1234);

    let batches = [64usize, 256, 512];
    let rules = [
        ScalingRule::NoScale,
        ScalingRule::Sqrt,
        ScalingRule::Linear,
        ScalingRule::CowClip,
    ];
    println!("{:<22} {:>8} {:>8} {:>8}", "rule \\ batch", 64, 256, 512);
    for rule in rules {
        print!("{:<22}", rule.label());
        for batch in batches {
            let spec = if rule == ScalingRule::CowClip {
                RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, batch)
            } else {
                RunSpec::baseline(ModelKind::DeepFm, DataVariant::Criteo, batch, rule)
            };
            let r = run_one(&ctx, &spec)?;
            print!(" {:>8}", fmt_auc(r.auc));
        }
        println!();
    }
    println!("\n(AUC %; paper shape: top rows degrade to the right, CowClip row stays flat)");
    Ok(())
}
