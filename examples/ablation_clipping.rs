//! Clipping-design ablation (live mini Table 7): run every clipping
//! variant at a large batch and compare AUC + the clip behaviour stats.
//!
//!     cargo run --release --example ablation_clipping

use cowclip::clip::ClipMode;
use cowclip::experiments::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::Result;

fn main() -> Result<()> {
    let runtime = std::sync::Arc::new(Runtime::open_default()?);
    let ctx = ExpContext::new(Some(runtime), 20_000, 2.0, 1234);
    let batch = 512; // paper-8K label

    println!("clipping design ablation @ batch {batch} (DeepFM, criteo_synth)\n");
    println!("{:<36} {:>8} {:>9}", "design", "AUC %", "logloss");
    for (label, clip) in [
        ("no clipping", ClipMode::None),
        ("global GC", ClipMode::Global),
        ("field-wise GC", ClipMode::Field),
        ("column-wise GC", ClipMode::Column),
        ("adaptive field-wise GC", ClipMode::AdaField),
        ("adaptive column-wise GC (CowClip)", ClipMode::CowClip),
    ] {
        let mut spec = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, batch);
        spec.clip = clip;
        let r = run_one(&ctx, &spec)?;
        println!("{label:<36} {:>8} {:>9}", fmt_auc(r.auc), fmt_logloss(r.logloss));
    }
    println!("\n(paper Table 7 shape: column-wise > field-wise > global; adaptive column-wise best)");
    Ok(())
}
