//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real small workload:
//! synthesize a 100k-row Criteo-like dataset, train DeepFM for several
//! hundred optimizer steps through the AOT HLO path (Pallas CowClip
//! kernel inside the apply program), with 4 simulated data-parallel
//! workers and tree all-reduce, logging the loss curve and per-epoch
//! test AUC/logloss. The output of this run is recorded in
//! EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example train_e2e

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::Result;

fn main() -> Result<()> {
    let t0 = std::time::Instant::now();
    let runtime = std::sync::Arc::new(Runtime::open_default()?);
    let schema = runtime.manifest().schema("criteo_synth")?;

    println!("[1/3] synthesizing 100k-row criteo_synth dataset...");
    let ds = generate(&schema, &SynthConfig { n: 100_000, seed: 7, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    println!(
        "      {} train / {} test rows, {} cat fields (vocab {}), {} dense, CTR {:.3}",
        train.n(),
        test.n(),
        schema.n_cat(),
        schema.total_vocab(),
        schema.n_dense,
        ds.ctr()
    );

    println!("[2/3] training DeepFM + CowClip, batch 512 (paper 8K), 4 workers...");
    let preset = criteo_preset();
    let batch = 512;
    let engine = Engine::hlo(runtime, ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)?;
    let cfg = TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 3.0,
        workers: 4,
        threads: 0,
        param_shards: 0,
        warmup_steps: train.n() / batch,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 1,
        verbose: true,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let report = trainer.train(&train, &test)?;

    println!("[3/3] results");
    println!("      steps: {} (loss curve below)", report.steps);
    // compact loss curve: every ~20th step
    let stride = (report.train_loss_curve.len() / 25).max(1);
    for (i, loss) in report.train_loss_curve.iter().enumerate().step_by(stride) {
        let bar_len = ((loss / 0.7) * 48.0) as usize;
        println!("      step {i:>4}  loss {loss:.4}  {}", "*".repeat(bar_len.min(60)));
    }
    for e in &report.epoch_evals {
        println!(
            "      epoch {}  train_loss {:.4}  test AUC {:.4}%  logloss {:.4}",
            e.epoch,
            e.train_loss,
            e.test_auc * 100.0,
            e.test_logloss
        );
    }
    println!(
        "      all-reduce: {} workers, {} rounds, {:.1} MiB total traffic",
        report.reduce_stats.workers,
        report.reduce_stats.rounds,
        report.reduce_stats.bytes_moved as f64 / (1 << 20) as f64
    );
    for (phase, secs) in &report.phase_seconds {
        println!("      phase {phase:<5} {secs:>7.2}s");
    }
    println!(
        "      FINAL: test AUC {:.2}%  logloss {:.4}  wall {:.1}s (total {:.1}s)",
        report.final_auc * 100.0,
        report.final_logloss,
        report.wall_seconds,
        t0.elapsed().as_secs_f64()
    );
    assert!(!report.diverged, "e2e run must not diverge");
    assert!(report.final_auc > 0.6, "e2e run must clearly beat chance");
    println!("      E2E OK");
    Ok(())
}
