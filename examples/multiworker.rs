//! Data-parallel training on the threaded execution engine: scale the
//! logical worker count, fan the shards out over real threads, and watch
//! the all-reduce traffic grow while the math stays identical — the
//! paper's "easily extended to multi-node" claim, made measurable.
//!
//!     cargo run --release --example multiworker -- [--threads T] [--n N]
//!
//! `--threads 0` (default) uses one thread per core; `--threads 1` runs
//! the seed's sequential path. Either way the learned weights match the
//! 1-worker run to f32 tolerance: shards merge in rank order no matter
//! which thread finishes first.

use cowclip::cli::Args;
use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::schema::criteo_synth;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let threads = args.usize_or("threads", 0)?;
    let n = args.usize_or("n", 16_000)?;

    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n, seed: 3, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let preset = criteo_preset();

    println!(
        "{:>8} {:>8} {:>10} {:>9} {:>12} {:>8} {:>9}",
        "workers", "threads", "AUC %", "steps", "reduce MiB", "merges", "wall s"
    );
    let mut reference_embed: Option<Vec<f32>> = None;
    for workers in [1usize, 2, 4, 8] {
        let engine = Engine::reference(
            ModelKind::DeepFm,
            schema.clone(),
            10,
            vec![64, 64],
            2,
            ClipMode::CowClip,
        );
        let cfg = TrainConfig {
            batch: 512,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers,
            threads,
            param_shards: 0,
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: 1234,
            eval_every_epochs: 0,
            verbose: false,
        };
        let used = cfg.threads_for(workers);
        let mut trainer = Trainer::new(engine, cfg)?;
        let report = trainer.train(&train, &test)?;
        println!(
            "{:>8} {:>8} {:>10.2} {:>9} {:>12.1} {:>8} {:>9.1}",
            workers,
            used,
            report.final_auc * 100.0,
            report.steps,
            report.reduce_stats.bytes_moved as f64 / (1 << 20) as f64,
            report.reduce_stats.rounds,
            report.wall_seconds
        );
        // sharding + threading must not change the learned weights
        let embed = trainer.params().tensors[0].as_f32()?.to_vec();
        if let Some(reference) = &reference_embed {
            let max_diff = embed
                .iter()
                .zip(reference)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!("         max |Δembed| vs 1 worker: {max_diff:.2e}");
        } else {
            reference_embed = Some(embed);
        }
    }
    println!(
        "\n(identical AUC across rows; W workers cost W-1 rank-ordered merges \
         per step, overlapped with the shard gradients)"
    );
    Ok(())
}
