//! Simulated data-parallel training: scale the logical worker count and
//! watch the all-reduce traffic grow while the math stays identical —
//! the paper's "easily extended to multi-node" claim, made measurable.
//!
//!     cargo run --release --example multiworker

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::Result;

fn main() -> Result<()> {
    let runtime = std::sync::Arc::new(Runtime::open_default()?);
    let schema = runtime.manifest().schema("criteo_synth")?;
    let ds = generate(&schema, &SynthConfig { n: 16_000, seed: 3, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let preset = criteo_preset();

    println!(
        "{:>8} {:>10} {:>9} {:>12} {:>10} {:>9}",
        "workers", "AUC %", "steps", "reduce MiB", "rounds", "wall s"
    );
    let mut reference_embed: Option<Vec<f32>> = None;
    for workers in [1usize, 2, 4, 8] {
        let engine =
            Engine::hlo(runtime.clone(), ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip)?;
        let cfg = TrainConfig {
            batch: 512,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers,
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: 1234,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mut trainer = Trainer::new(engine, cfg)?;
        let report = trainer.train(&train, &test)?;
        println!(
            "{:>8} {:>10.2} {:>9} {:>12.1} {:>10} {:>9.1}",
            workers,
            report.final_auc * 100.0,
            report.steps,
            report.reduce_stats.bytes_moved as f64 / (1 << 20) as f64,
            report.reduce_stats.rounds,
            report.wall_seconds
        );
        // sharding must not change the learned weights (f32 tolerance)
        let embed = trainer.params.tensors[0].as_f32()?.to_vec();
        if let Some(reference) = &reference_embed {
            let max_diff = embed
                .iter()
                .zip(reference)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!("         max |Δembed| vs 1 worker: {max_diff:.2e}");
        } else {
            reference_embed = Some(embed);
        }
    }
    println!("\n(identical AUC across rows; traffic grows ~log2(workers) per step)");
    Ok(())
}
