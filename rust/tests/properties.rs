//! Property-based tests (in-tree deterministic random search — the build
//! environment has no proptest crate; the loops below shrink nothing but
//! sweep hundreds of randomized cases per property, which catches the
//! same class of bugs for these invariants).

use cowclip::clip::{
    clip_embedding_grads, clip_embedding_grads_sparse, ClipMode, ClipParams,
};
use cowclip::coordinator::allreduce::{tree_allreduce, Contribution, TreeReducer};
use cowclip::data::schema::Schema;
use cowclip::metrics::auc;
use cowclip::scaling::rules::{HyperSet, ScalingRule};
use cowclip::tensor::{GradTensor, SparseRows, Tensor};
use cowclip::util::Rng;

fn rand_schema(rng: &mut Rng) -> Schema {
    let n_fields = 1 + rng.below(5) as usize;
    let vocab_sizes: Vec<usize> = (0..n_fields).map(|_| 1 + rng.below(12) as usize).collect();
    Schema { name: "p".into(), n_dense: rng.below(3) as usize, vocab_sizes }
}

fn norm(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// Invariant: no clipping mode ever *increases* a row norm, and CowClip
/// respects its per-row bound exactly.
#[test]
fn prop_clipping_norm_bounds() {
    let mut rng = Rng::new(0xC11F);
    for case in 0..300 {
        let schema = rand_schema(&mut rng);
        let v = schema.total_vocab();
        let d = 1 + rng.below(6) as usize;
        let mode = ClipMode::ALL[rng.below(6) as usize];
        let g0: Vec<f32> = (0..v * d)
            .map(|_| (rng.next_gaussian() * 10.0f64.powi(rng.below(4) as i32 - 2)) as f32)
            .collect();
        let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        let counts: Vec<f32> = (0..v).map(|_| rng.below(5) as f32).collect();
        let p = ClipParams {
            r: [0.1, 1.0, 10.0][rng.below(3) as usize],
            zeta: [0.0, 1e-5, 1e-3][rng.below(3) as usize],
            clip_t: [0.01, 1.0, 100.0][rng.below(3) as usize],
        };
        let mut g = g0.clone();
        clip_embedding_grads(mode, &mut g, &w, &counts, &schema, d, &p);

        for (i, (row, row0)) in g.chunks(d).zip(g0.chunks(d)).enumerate() {
            let n = norm(row);
            let n0 = norm(row0);
            assert!(
                n <= n0 * (1.0 + 1e-5) + 1e-7,
                "case {case} {mode}: row {i} grew {n0} -> {n}"
            );
            // direction preserved: row is a nonnegative multiple of row0
            let dot: f32 = row.iter().zip(row0).map(|(a, b)| a * b).sum();
            assert!(dot >= -1e-6, "case {case} {mode}: row {i} flipped direction");
            if mode == ClipMode::CowClip {
                let wnorm = norm(&w[i * d..(i + 1) * d]);
                let bound = counts[i] * (p.r * wnorm).max(p.zeta);
                assert!(
                    n <= bound * (1.0 + 1e-4) + 1e-6,
                    "case {case}: cowclip bound violated: {n} > {bound}"
                );
            }
        }
    }
}

/// Invariant: clipping is idempotent — applying twice equals once.
#[test]
fn prop_clipping_idempotent() {
    let mut rng = Rng::new(0x1DE9);
    for _ in 0..200 {
        let schema = rand_schema(&mut rng);
        let v = schema.total_vocab();
        let d = 1 + rng.below(4) as usize;
        let mode = ClipMode::ALL[rng.below(6) as usize];
        let mut g: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 3.0).collect();
        let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        let counts: Vec<f32> = (0..v).map(|_| rng.below(4) as f32).collect();
        let p = ClipParams::default();
        clip_embedding_grads(mode, &mut g, &w, &counts, &schema, d, &p);
        let once = g.clone();
        clip_embedding_grads(mode, &mut g, &w, &counts, &schema, d, &p);
        for (a, b) in g.iter().zip(&once) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-6), "not idempotent: {a} vs {b}");
        }
    }
}

/// Invariant: the sparse clip twin is elementwise-exact vs the dense
/// implementation on any random touched-row support, for every mode.
#[test]
fn prop_sparse_clip_matches_dense() {
    let mut rng = Rng::new(0x5BA6);
    for case in 0..300 {
        let schema = rand_schema(&mut rng);
        let v = schema.total_vocab();
        let d = 1 + rng.below(6) as usize;
        let mode = ClipMode::ALL[rng.below(6) as usize];
        let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        // random subset of touched rows with random counts >= 1
        let mut ids: Vec<u32> = (0..v as u32).filter(|_| rng.bernoulli(0.4)).collect();
        if ids.is_empty() {
            ids.push(rng.below(v as u64) as u32);
        }
        let sparse_counts: Vec<f32> = ids.iter().map(|_| 1.0 + rng.below(4) as f32).collect();
        let vals: Vec<f32> = (0..ids.len() * d)
            .map(|_| (rng.next_gaussian() * 3.0) as f32)
            .collect();
        let p = ClipParams {
            r: [0.1, 1.0, 10.0][rng.below(3) as usize],
            zeta: [0.0, 1e-5, 1e-3][rng.below(3) as usize],
            clip_t: [0.01, 1.0, 100.0][rng.below(3) as usize],
        };

        let mut sg = SparseRows::new(v, d, ids.clone(), vals);
        let mut dense = sg.to_dense();
        let mut dense_counts = vec![0.0f32; v];
        for (&id, &c) in ids.iter().zip(&sparse_counts) {
            dense_counts[id as usize] = c;
        }
        clip_embedding_grads(mode, &mut dense, &w, &dense_counts, &schema, d, &p);
        clip_embedding_grads_sparse(mode, &mut sg, &w, &sparse_counts, &schema, &p);
        for (i, (a, b)) in sg.to_dense().iter().zip(&dense).enumerate() {
            assert!(
                (a - b).abs() <= 1e-6,
                "case {case} {mode}: elem {i}: sparse {a} vs dense {b}"
            );
        }
    }
}

/// Invariant: tree all-reduce equals the sequential sum, regardless of
/// worker count (f32 tolerance).
#[test]
fn prop_allreduce_matches_sequential_sum() {
    let mut rng = Rng::new(0xA11D);
    for _ in 0..200 {
        let workers = 1 + rng.below(9) as usize;
        let len = 1 + rng.below(40) as usize;
        let vocab = 1 + rng.below(10) as usize;
        let mut contributions = Vec::new();
        let mut want = vec![0.0f64; len];
        let mut want_counts = vec![0.0f64; vocab];
        for _ in 0..workers {
            let g: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let c: Vec<f32> = (0..vocab).map(|_| rng.below(3) as f32).collect();
            for (wv, &x) in want.iter_mut().zip(&g) {
                *wv += x as f64;
            }
            for (wv, &x) in want_counts.iter_mut().zip(&c) {
                *wv += x as f64;
            }
            contributions.push(Contribution {
                grads: vec![GradTensor::Dense(Tensor::f32(vec![len], g))],
                counts: SparseRows::from_dense(&c, vocab, 1),
                loss_weighted: 0.5 / workers as f32,
                weight: 1.0 / workers as f32,
            });
        }
        let (total, stats) = tree_allreduce(contributions).unwrap();
        assert_eq!(stats.workers, workers);
        assert!(stats.rounds <= (workers as f64).log2().ceil() as usize + 1);
        let total_grad = total.grads[0].to_tensor();
        for (got, want) in total_grad.as_f32().unwrap().iter().zip(&want) {
            assert!((*got as f64 - want).abs() < 1e-4, "{got} vs {want}");
        }
        for (got, want) in total.counts.to_dense().iter().zip(&want_counts) {
            assert_eq!(*got as f64, *want);
        }
    }
}

/// Invariant: the streaming tree reducer is **bitwise** arrival-order
/// invariant — the fixed rank-range pairing alone defines the result —
/// and its sparse totals match the dense sequential sum within f32
/// association tolerance.
#[test]
fn prop_tree_reducer_is_arrival_order_invariant_bitwise() {
    let mut rng = Rng::new(0x7EE5);
    for _ in 0..100 {
        let workers = 1 + rng.below(9) as usize;
        let len = 1 + rng.below(24) as usize;
        let contributions: Vec<Contribution> = (0..workers)
            .map(|_| {
                let g: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
                let c: Vec<f32> = (0..4).map(|_| rng.below(3) as f32).collect();
                Contribution {
                    grads: vec![GradTensor::Dense(Tensor::f32(vec![len], g))],
                    counts: SparseRows::from_dense(&c, 4, 1),
                    loss_weighted: 0.5 / workers as f32,
                    weight: 1.0 / workers as f32,
                }
            })
            .collect();

        let mut reference: Option<(Vec<f32>, usize, u64)> = None;
        for trial in 0..3 {
            // deterministic pseudo-shuffle of the arrival order
            let mut order: Vec<usize> = (0..workers).collect();
            for i in (1..workers).rev() {
                let j = rng.below((i + 1) as u64) as usize;
                order.swap(i, j);
            }
            let mut r = TreeReducer::new(workers);
            for rank in order {
                r.push(rank, contributions[rank].clone()).unwrap();
            }
            let (total, stats) = r.finish().unwrap();
            assert_eq!(stats.rounds, workers - 1);
            let got = total.grads[0].to_tensor().as_f32().unwrap().to_vec();
            match &reference {
                None => reference = Some((got, stats.rounds, stats.bytes_moved)),
                Some((want, rounds, bytes)) => {
                    assert_eq!(&got, want, "trial {trial}: arrival order changed the bits");
                    assert_eq!(stats.rounds, *rounds);
                    assert_eq!(stats.bytes_moved, *bytes, "traffic accounting must be fixed");
                }
            }
        }
    }
}

/// Invariant: AUC is invariant under strictly monotone score transforms
/// and flips to 1-AUC under negation.
#[test]
fn prop_auc_rank_invariance() {
    let mut rng = Rng::new(0xAE0C);
    for _ in 0..150 {
        let n = 2 + rng.below(200) as usize;
        let scores: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        let labels: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.3) as u8).collect();
        let a = auc(&scores, &labels);
        assert!((0.0..=1.0).contains(&a));
        // strictly monotone affine transform (tanh would saturate f32
        // and introduce ties, which legitimately change AUC)
        let t: Vec<f32> = scores.iter().map(|&s| 2.0 * s + 1.0).collect();
        assert!((auc(&t, &labels) - a).abs() < 1e-9);
        // negation
        let neg: Vec<f32> = scores.iter().map(|&s| -s).collect();
        let has_both = labels.iter().any(|&y| y == 1) && labels.iter().any(|&y| y == 0);
        if has_both {
            assert!((auc(&neg, &labels) - (1.0 - a)).abs() < 1e-9);
        }
    }
}

/// Invariant: every scaling rule is multiplicative in s — applying the
/// rule at s1*s2 equals applying at s1 then rebasing at s2.
#[test]
fn prop_scaling_rules_compose() {
    let mut rng = Rng::new(0x5CA1);
    let base = HyperSet {
        lr_dense: 1e-4,
        lr_embed: 1e-4,
        l2_embed: 1e-4,
        clip_r: 1.0,
        clip_zeta: 1e-5,
        clip_t: 1.0,
    };
    for _ in 0..100 {
        let rule = ScalingRule::ALL[rng.below(6) as usize];
        let s1 = 2f64.powi(rng.below(4) as i32);
        let s2 = 2f64.powi(rng.below(4) as i32);
        let direct = rule.apply(&base, s1 * s2);
        let staged = rule.apply(&rule.apply(&base, s1), s2);
        for (a, b) in [
            (direct.lr_dense, staged.lr_dense),
            (direct.lr_embed, staged.lr_embed),
            (direct.l2_embed, staged.l2_embed),
            (direct.clip_t, staged.clip_t),
        ] {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-12), "{rule}: {a} vs {b}");
        }
    }
}

/// Invariant: the dataset binary format roundtrips arbitrary valid data.
#[test]
fn prop_dataset_roundtrip() {
    use cowclip::data::dataset::Dataset;
    let mut rng = Rng::new(0xD474);
    let dir = std::env::temp_dir().join(format!("cowclip_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..25 {
        let schema = rand_schema(&mut rng);
        let n = rng.below(50) as usize;
        let offs = schema.offsets();
        let mut ds = Dataset::with_capacity(schema.clone(), n);
        for _ in 0..n {
            for (f, &vs) in schema.vocab_sizes.iter().enumerate() {
                ds.x_cat.push((offs[f] + rng.below(vs as u64) as usize) as i32);
            }
            for _ in 0..schema.n_dense {
                ds.x_dense.push(rng.next_gaussian() as f32);
            }
            ds.y.push(rng.bernoulli(0.5) as u8);
            ds.ts.push(rng.below(1 << 20) as u32);
        }
        let path = dir.join(format!("p{case}.ctr"));
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.x_cat, ds.x_cat);
        assert_eq!(back.x_dense, ds.x_dense);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.ts, ds.ts);
    }
    std::fs::remove_dir_all(&dir).ok();
}
