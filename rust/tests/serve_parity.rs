//! Serving-tier parity gates.
//!
//! * Scores returned through the micro-batching server — any queue
//!   arrival order, any scoring-thread count, any batching trigger mix —
//!   match the offline reference forward pass ≤ 1e-6 in f32 mode.
//! * In quantized mode, served scores match the offline forward over
//!   the **dequantized** tables ≤ 1e-6, and every dequantized weight of
//!   a trained model sits within the documented per-field round-trip
//!   bound (`serve::quant` module docs); AUC on a synthetic eval set
//!   moves < 1e-3 under quantization.
//! * The latency-deadline trigger flushes partial batches, so a lone
//!   request is never stranded behind an unfilled `max_batch`.

use std::sync::Arc;
use std::time::Duration;

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::batcher::Batch;
use cowclip::data::schema::Schema;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, RowSampler, SynthConfig};
use cowclip::metrics::auc;
use cowclip::model::init::{init_params, InitConfig};
use cowclip::model::params::ParamSet;
use cowclip::reference::step::build_spec;
use cowclip::reference::{ModelKind, ReferenceModel};
use cowclip::scaling::rules::{HyperSet, ScalingRule};
use cowclip::serve::{Overloaded, Request, ServeConfig, ServeModel, Server};
use cowclip::tensor::Tensor;
use cowclip::util::Rng;

fn tiny_schema() -> Schema {
    Schema { name: "serve_tiny".into(), n_dense: 3, vocab_sizes: vec![40, 30, 20, 6] }
}

fn tiny_model(kind: ModelKind) -> ReferenceModel {
    ReferenceModel::new(kind, tiny_schema(), 4, vec![16, 16], 2)
}

fn tiny_params(model: &ReferenceModel, seed: u64) -> ParamSet {
    let spec = build_spec(model.kind, &model.schema, model.embed_dim, &model.hidden, model.n_cross);
    init_params(&spec, &InitConfig { seed, embed_sigma: 0.05 })
}

/// N requests drawn from the synthesizer's id model.
fn requests(schema: &Schema, n: usize, seed: u64) -> Vec<Request> {
    let mut sampler = RowSampler::new(schema, &SynthConfig { seed, ..Default::default() });
    (0..n)
        .map(|i| {
            let (cat, dense) = sampler.next_row();
            Request { id: i as u64, cat, dense }
        })
        .collect()
}

/// Offline oracle: one big batched forward over the same rows.
fn offline_logits(model: &ReferenceModel, params: &ParamSet, reqs: &[Request]) -> Vec<f32> {
    let b = reqs.len();
    let f = model.schema.n_cat();
    let nd = model.schema.n_dense;
    let mut cat = Vec::with_capacity(b * f);
    let mut dense = Vec::with_capacity(b * nd);
    for r in reqs {
        cat.extend_from_slice(&r.cat);
        dense.extend_from_slice(&r.dense);
    }
    let batch = Batch::new(
        Tensor::i32(vec![b, f], cat),
        Tensor::f32(vec![b, nd], dense),
        Tensor::f32(vec![b], vec![0.0; b]),
        b,
    );
    model.forward(params, &batch).unwrap()
}

/// Drive `reqs` through a server from `clients` submitter threads in a
/// shuffled arrival order; return scores keyed by request id.
fn serve_scores(
    frozen: &Arc<ServeModel>,
    cfg: ServeConfig,
    reqs: &[Request],
    clients: usize,
    shuffle_seed: u64,
) -> Vec<f32> {
    let clients = clients.max(1);
    let server = Server::start(Arc::clone(frozen), cfg);
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    Rng::new(shuffle_seed).shuffle(&mut order);
    let mut out = vec![f32::NAN; reqs.len()];
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..clients {
            let client = server.client();
            let order = &order;
            handles.push(s.spawn(move || {
                let mut scored = Vec::new();
                let mut i = t;
                while i < order.len() {
                    let req = reqs[order[i]].clone();
                    scored.push(client.score(req).unwrap());
                    i += clients;
                }
                scored
            }));
        }
        for h in handles {
            for sc in h.join().unwrap() {
                out[sc.id as usize] = sc.logit;
            }
        }
    });
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests as usize, reqs.len());
    assert!(stats.batches >= 1);
    assert_eq!(stats.latency.count(), stats.requests);
    out
}

#[test]
fn served_scores_match_offline_forward_all_models_f32() {
    for kind in ModelKind::ALL {
        let model = tiny_model(kind);
        let params = tiny_params(&model, 11);
        let reqs = requests(&model.schema, 160, 21);
        let oracle = offline_logits(&model, &params, &reqs);
        let frozen =
            Arc::new(ServeModel::from_params(model.clone(), params.clone(), false).unwrap());
        for (max_batch, threads, clients) in [(1, 1, 1), (7, 3, 4), (64, 2, 2)] {
            let cfg = ServeConfig {
                max_batch,
                max_delay: Duration::from_micros(300),
                threads,
                max_queue: 0,
            };
            let got = serve_scores(&frozen, cfg, &reqs, clients, 1000 + max_batch as u64);
            for (i, (&g, &o)) in got.iter().zip(&oracle).enumerate() {
                assert!(
                    (g - o).abs() <= 1e-6,
                    "{kind} (batch {max_batch}, {threads} thr): req {i}: {g} vs {o}"
                );
            }
        }
    }
}

#[test]
fn deadline_trigger_flushes_partial_batches() {
    let model = tiny_model(ModelKind::WideDeep);
    let params = tiny_params(&model, 5);
    let frozen = Arc::new(ServeModel::from_params(model, params, false).unwrap());
    // max_batch far larger than the traffic: only the deadline can fire
    let cfg = ServeConfig {
        max_batch: 10_000,
        max_delay: Duration::from_millis(5),
        threads: 2,
        max_queue: 0,
    };
    let server = Server::start(Arc::clone(&frozen), cfg);
    let client = server.client();
    let reqs = requests(frozen.schema(), 3, 9);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = reqs.into_iter().map(|r| client.submit(r).unwrap()).collect();
    for rx in rxs {
        let sc = rx.recv_timeout(Duration::from_secs(5)).expect("deadline must flush");
        assert!(sc.logit.is_finite());
    }
    assert!(t0.elapsed() < Duration::from_secs(5));
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 3);
}

#[test]
fn invalid_request_is_rejected_at_submit() {
    let model = tiny_model(ModelKind::Dcn);
    let params = tiny_params(&model, 2);
    let frozen = Arc::new(ServeModel::from_params(model, params, false).unwrap());
    let server = Server::start(Arc::clone(&frozen), ServeConfig::default());
    let client = server.client();
    let bad = Request { id: 0, cat: vec![0, 0, 0, 0], dense: vec![0.0; 3] };
    // id 0 in column 1 belongs to field 0's range, not field 1's
    assert!(client.submit(bad).is_err());
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 0);
}

/// Admission control: with `max_queue` set, the submit past the bound
/// fails with the typed [`Overloaded`] error (and bumps the
/// `serve.rejected` counter) instead of growing the queue, while the
/// admitted requests still score on shutdown. Deterministic setup: one
/// scoring thread parked on a far-off deadline (huge `max_batch`, long
/// `max_delay`), so the queue provably holds every admitted request
/// when the over-limit submit arrives.
#[test]
fn bounded_queue_sheds_overload_with_typed_error() {
    let model = tiny_model(ModelKind::WideDeep);
    let params = tiny_params(&model, 13);
    let frozen = Arc::new(ServeModel::from_params(model, params, false).unwrap());
    let cfg = ServeConfig {
        max_batch: 10_000,
        max_delay: Duration::from_secs(30),
        threads: 1,
        max_queue: 4,
    };
    let rejected_before = cowclip::obs::counter("serve.rejected").get();
    let server = Server::start(Arc::clone(&frozen), cfg);
    let client = server.client();
    let reqs = requests(frozen.schema(), 5, 23);
    let mut rxs = Vec::new();
    for (i, r) in reqs.into_iter().enumerate() {
        match client.submit(r) {
            Ok(rx) => {
                assert!(i < 4, "request {i} should have been shed");
                rxs.push(rx);
            }
            Err(err) => {
                assert_eq!(i, 4, "request {i} rejected early: {err:#}");
                let over = err
                    .downcast_ref::<Overloaded>()
                    .unwrap_or_else(|| panic!("expected Overloaded, got: {err:#}"));
                assert_eq!(over.depth, 4);
                assert_eq!(over.max_queue, 4);
            }
        }
    }
    // Shutdown flushes the four admitted requests through the scorer.
    let flushed: Vec<_> = std::thread::scope(|s| {
        let h = s.spawn(move || {
            rxs.into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(10)).expect("flush on shutdown"))
                .collect()
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 4, "admitted requests must still score");
        h.join().unwrap()
    });
    assert_eq!(flushed.len(), 4);
    for sc in &flushed {
        assert!(sc.logit.is_finite());
    }
    let rejected_after = cowclip::obs::counter("serve.rejected").get();
    assert!(
        rejected_after >= rejected_before + 1,
        "serve.rejected should count the shed request ({rejected_before} -> {rejected_after})"
    );
}

#[test]
fn quantized_serving_matches_dequantized_oracle_all_models() {
    for kind in ModelKind::ALL {
        let model = tiny_model(kind);
        let params = tiny_params(&model, 31);
        let reqs = requests(&model.schema, 120, 41);
        let frozen =
            Arc::new(ServeModel::from_params(model.clone(), params.clone(), true).unwrap());
        assert!(frozen.is_quantized());
        // the scorer's semantics: forward over the dequantized tables
        let oracle_params = frozen.oracle_params().unwrap();
        let oracle = offline_logits(&model, &oracle_params, &reqs);
        let cfg =
            ServeConfig { max_batch: 9, max_delay: Duration::from_micros(300), threads: 3, max_queue: 0 };
        let got = serve_scores(&frozen, cfg, &reqs, 3, 77);
        for (i, (&g, &o)) in got.iter().zip(&oracle).enumerate() {
            assert!((g - o).abs() <= 1e-6, "{kind}: req {i}: {g} vs {o}");
        }
        // and the dequantized tables sit within the documented bound of
        // the original weights
        let bound = frozen.quant_error_bound().unwrap();
        for (e, (orig, deq)) in params
            .spec
            .iter()
            .zip(params.tensors.iter().zip(&oracle_params.tensors))
        {
            if !matches!(e.group.as_str(), "embed" | "wide") {
                continue;
            }
            for (a, b) in orig.as_f32().unwrap().iter().zip(deq.as_f32().unwrap()) {
                assert!((a - b).abs() <= bound, "{kind} {}: {a} vs {b} (bound {bound})", e.name);
            }
        }
        // table memory actually shrinks (~2x: u16 codes + tiny constants)
        assert!(frozen.table_bytes() < frozen.table_f32_bytes() * 3 / 4);
        assert!(frozen.serving_bytes() < frozen.f32_bytes());
    }
}

/// Quantize → dequantize every table of a *trained* model: per-field
/// round-trip bound holds, and eval AUC moves < 1e-3.
#[test]
fn quant_roundtrip_and_auc_on_trained_model() {
    let schema = tiny_schema();
    let n = 6_000;
    let full = generate(&schema, &SynthConfig { n, seed: 8, ..Default::default() });
    let (train, test) = random_split(&full, 0.8, 3);
    let hypers = HyperSet {
        lr_dense: 1e-2,
        lr_embed: 8e-3,
        l2_embed: 1e-5,
        clip_r: 1.0,
        clip_zeta: 1e-5,
        clip_t: 1.0,
    };
    let engine = Engine::reference(
        ModelKind::DeepFm,
        schema.clone(),
        4,
        vec![16, 16],
        2,
        ClipMode::CowClip,
    );
    let cfg = TrainConfig {
        batch: 256,
        base_batch: 256,
        base_hypers: hypers,
        rule: ScalingRule::NoScale,
        epochs: 3.0,
        workers: 1,
        threads: 1,
        param_shards: 1,
        warmup_steps: 0,
        init_sigma: 0.01,
        seed: 4,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    let report = trainer.train(&train, &test).unwrap();
    assert!(!report.diverged);
    let trained = trainer.params().clone();

    let model = tiny_model(ModelKind::DeepFm);
    let f32_model = ServeModel::from_params(model.clone(), trained.clone(), false).unwrap();
    let q_model = ServeModel::from_params(model.clone(), trained.clone(), true).unwrap();

    // 1. round-trip bound on every vocab table of the trained weights
    let bound = q_model.quant_error_bound().unwrap();
    assert!(bound > 0.0 && bound < 1e-3, "bound {bound} should be tiny for trained tables");
    let deq = q_model.oracle_params().unwrap();
    let mut max_err = 0.0f32;
    for (e, (orig, back)) in
        trained.spec.iter().zip(trained.tensors.iter().zip(&deq.tensors))
    {
        match e.group.as_str() {
            "embed" | "wide" => {
                for (a, b) in orig.as_f32().unwrap().iter().zip(back.as_f32().unwrap()) {
                    max_err = max_err.max((a - b).abs());
                }
            }
            // dense params are not quantized: byte-identical
            _ => assert_eq!(orig, back, "{} must pass through untouched", e.name),
        }
    }
    assert!(max_err <= bound, "round-trip err {max_err} > documented bound {bound}");

    // 2. AUC on the eval split within 1e-3 of the f32 model
    let eval_reqs: Vec<Request> = (0..test.n())
        .map(|i| Request {
            id: i as u64,
            cat: test.cat_row(i).to_vec(),
            dense: test.dense_row(i).to_vec(),
        })
        .collect();
    let f32_logits = f32_model.score_batch(&eval_reqs).unwrap();
    let q_logits = q_model.score_batch(&eval_reqs).unwrap();
    let auc_f32 = auc(&f32_logits, &test.y);
    let auc_q = auc(&q_logits, &test.y);
    assert!(auc_f32 > 0.55, "trained model should beat chance (auc {auc_f32})");
    assert!(
        (auc_f32 - auc_q).abs() < 1e-3,
        "quantization moved AUC too far: {auc_f32} vs {auc_q}"
    );
}

/// The served f32 path and `ServeModel::score_batch` (no queue) agree —
/// the micro-batcher never changes the math, only the batching.
#[test]
fn direct_score_batch_matches_served_path() {
    let model = tiny_model(ModelKind::DcnV2);
    let params = tiny_params(&model, 17);
    let reqs = requests(&model.schema, 64, 5);
    let frozen = Arc::new(ServeModel::from_params(model, params, false).unwrap());
    let direct = frozen.score_batch(&reqs).unwrap();
    let cfg =
        ServeConfig { max_batch: 5, max_delay: Duration::from_micros(200), threads: 2, max_queue: 0 };
    let served = serve_scores(&frozen, cfg, &reqs, 2, 3);
    for (i, (&a, &b)) in direct.iter().zip(&served).enumerate() {
        assert!((a - b).abs() <= 1e-6, "req {i}: {a} vs {b}");
    }
}

/// PR-5 zero-allocation gate for serving: once a scoring thread's
/// scratch arena has warmed on a batch shape, further batches of the
/// same shape must not grow it — the fused gather + inference forward
/// recycles every intermediate (f32 and quantized tables alike).
#[test]
fn steady_state_scoring_performs_no_scratch_allocation() {
    for quant in [false, true] {
        for kind in [ModelKind::DeepFm, ModelKind::DcnV2] {
            let model = tiny_model(kind);
            let params = tiny_params(&model, 23);
            let frozen = ServeModel::from_params(model, params, quant).unwrap();
            let reqs = requests(frozen.schema(), 32, 9);
            let mut scratch = cowclip::reference::Scratch::new();
            let lg = frozen.score_batch_scratch(&reqs, &mut scratch).unwrap();
            let lg0 = lg.clone();
            scratch.recycle(lg);
            let grown = scratch.grow_events();
            assert!(grown > 0, "{kind}/quant={quant}: warmup must populate the arena");
            for _ in 0..4 {
                let lg = frozen.score_batch_scratch(&reqs, &mut scratch).unwrap();
                // bitwise-stable scores double as the stale-data guard
                assert_eq!(lg, lg0, "{kind}/quant={quant}: scores drifted across calls");
                scratch.recycle(lg);
            }
            assert_eq!(
                scratch.grow_events(),
                grown,
                "{kind}/quant={quant}: steady-state scoring allocated scratch buffers"
            );
        }
    }
}
