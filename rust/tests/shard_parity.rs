//! Sharded == serial: the shard-owned `ParamStore` apply stage (row-wise
//! embedding shards, grouped dense tensors, maintained per-field norms,
//! parallel `clip → L2 → Adam`) must reproduce the leader-serial oracle
//! (`ReferenceEngine::apply`, kept byte-for-byte from PR 2) within 1e-6
//! for every clip mode, every model, and any shard count — and different
//! shard counts must agree with each other bitwise (mirrors
//! `parallel_parity.rs` for the thread dimension).

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, TrainReport, Trainer};
use cowclip::data::dataset::Dataset;
use cowclip::data::schema::{criteo_synth, Schema};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::data::Batcher;
use cowclip::model::{init_params, InitConfig, ParamStore};
use cowclip::reference::ModelKind;
use cowclip::runtime::HypersVec;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::{HyperSet, ScalingRule};

const TOL: f32 = 1e-6;

fn close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= TOL, "{what}[{i}]: {x} vs {y}");
    }
}

fn tiny_schema() -> Schema {
    Schema { name: "shard_tiny".into(), n_dense: 2, vocab_sizes: vec![6, 5, 4, 2] }
}

fn tiny_engine(kind: ModelKind, clip: ClipMode) -> Engine {
    Engine::reference(kind, tiny_schema(), 4, vec![8, 8], 2, clip)
}

fn hypers() -> HyperSet {
    HyperSet {
        lr_dense: 1e-2,
        lr_embed: 8e-3,
        l2_embed: 1e-4,
        clip_r: 1.0,
        clip_zeta: 1e-4,
        clip_t: 0.5,
    }
}

/// Acceptance: for all four models, all six clip modes, and 1/2/odd
/// shard counts, a few optimizer steps through the shard-owned store
/// match the leader-serial oracle ≤ 1e-6 per element.
#[test]
fn store_matches_serial_oracle_all_models_modes_shards() {
    let schema = tiny_schema();
    let ds = generate(&schema, &SynthConfig { n: 400, seed: 31, ..Default::default() });
    for kind in ModelKind::ALL {
        for clip in ClipMode::ALL {
            for shards in [1usize, 2, 3] {
                // serial oracle: the pre-refactor apply over plain ParamSets
                let mut oracle = tiny_engine(kind, clip);
                let spec = oracle.spec();
                let init = init_params(&spec, &InitConfig { seed: 5, embed_sigma: 0.02 });
                let mut params_o = init.clone();
                let mut m_o = params_o.zeros_like();
                let mut v_o = params_o.zeros_like();

                // shard-owned store driven through Engine::apply_store
                let store_engine = tiny_engine(kind, clip);
                let store = ParamStore::new(schema.clone(), init, shards).unwrap();

                let mut batcher = Batcher::new(&ds, 32, 7);
                for t in 1..=5usize {
                    let batch = batcher.next_batch();
                    let hv = HypersVec::new(hypers()).at_step(t).with_warmup(0.5);

                    let mut out_o = oracle.grad(&params_o, &batch).unwrap();
                    oracle
                        .apply(&mut params_o, &mut m_o, &mut v_o, &mut out_o.grads, &out_o.counts, &hv)
                        .unwrap();

                    let mut out_s = {
                        let guard = store.read();
                        store_engine.grad(&guard, &batch).unwrap()
                    };
                    store_engine
                        .apply_store(&store, &mut out_s.grads, &out_s.counts, &hv, shards)
                        .unwrap();
                }

                let snap = store.snapshot();
                for (i, (a, b)) in params_o.tensors.iter().zip(&snap.tensors).enumerate() {
                    close(
                        a.as_f32().unwrap(),
                        b.as_f32().unwrap(),
                        &format!("{kind}/{clip}/shards={shards}: param[{i}] ({})", spec[i].name),
                    );
                }
                let (m_s, v_s) = store.moments();
                for (i, (a, b)) in m_o.tensors.iter().zip(&m_s.tensors).enumerate() {
                    close(a.as_f32().unwrap(), b.as_f32().unwrap(),
                        &format!("{kind}/{clip}/shards={shards}: m[{i}]"));
                }
                for (i, (a, b)) in v_o.tensors.iter().zip(&v_s.tensors).enumerate() {
                    close(a.as_f32().unwrap(), b.as_f32().unwrap(),
                        &format!("{kind}/{clip}/shards={shards}: v[{i}]"));
                }
            }
        }
    }
}

fn data() -> (Dataset, Dataset) {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 1_500, seed: 19, ..Default::default() });
    random_split(&ds, 0.9, 0)
}

fn run(
    clip: ClipMode,
    shards: usize,
    train: &Dataset,
    test: &Dataset,
) -> (TrainReport, Vec<Vec<f32>>, Option<Vec<f64>>) {
    let preset = criteo_preset();
    let engine = Engine::reference(ModelKind::DeepFm, criteo_synth(), 8, vec![32, 32], 2, clip);
    let cfg = TrainConfig {
        batch: 128,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 1.0,
        workers: 2,
        threads: 2,
        param_shards: shards,
        warmup_steps: 4,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    let report = trainer.train(train, test).unwrap();
    let params = trainer
        .params()
        .tensors
        .iter()
        .map(|t| t.as_f32().unwrap().to_vec())
        .collect();
    let sqnorms = trainer.store.field_sqnorms();
    (report, params, sqnorms)
}

/// Acceptance: a full threaded training run is invariant to the apply
/// shard count — same loss curve, same final params, same AUC — for the
/// CowClip hot path and the AdaField ablation (the mode the maintained
/// norms serve).
#[test]
fn trainer_run_is_shard_count_invariant() {
    let (train, test) = data();
    for clip in [ClipMode::CowClip, ClipMode::AdaField] {
        let (base_report, base_params, _) = run(clip, 1, &train, &test);
        assert!(!base_report.diverged, "{clip}: serial run diverged");
        for shards in [2usize, 3] {
            let (report, params, _) = run(clip, shards, &train, &test);
            assert!(!report.diverged, "{clip}/shards={shards}: diverged");
            assert_eq!(base_report.steps, report.steps, "{clip}: step count");
            close(
                &base_report.train_loss_curve,
                &report.train_loss_curve,
                &format!("{clip}/shards={shards}: loss curve"),
            );
            for (i, (a, b)) in base_params.iter().zip(&params).enumerate() {
                close(a, b, &format!("{clip}/shards={shards}: param[{i}]"));
            }
            assert!(
                (base_report.final_auc - report.final_auc).abs() <= TOL as f64,
                "{clip}/shards={shards}: AUC {} vs {}",
                base_report.final_auc,
                report.final_auc
            );
        }
    }
}

/// The maintained per-field `Σw²` (what makes sparse AdaField O(touched)
/// instead of O(V·d)) stays in sync with a fresh scan of the weights
/// through a full AdaField training run.
#[test]
fn adafield_maintained_norms_track_weights_through_training() {
    let (train, test) = data();
    let (_, params, sqnorms) = run(ClipMode::AdaField, 3, &train, &test);
    let sqnorms = sqnorms.expect("embed table has maintained norms");
    let schema = criteo_synth();
    let embed = &params[0];
    let d = embed.len() / schema.total_vocab();
    for (fi, (off, vs)) in schema.fields().enumerate() {
        let fresh: f64 = embed[off * d..(off + vs) * d]
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum();
        let diff = (sqnorms[fi] - fresh).abs();
        assert!(
            diff <= 1e-7 * fresh.max(1.0),
            "field {fi}: maintained {} vs fresh {fresh}",
            sqnorms[fi]
        );
    }
}
