//! Trainer-level integration: full runs over the HLO engine, worker
//! sharding equivalence, reference-engine fallback, checkpoints.

use std::path::Path;
use std::sync::Arc;

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, Trainer};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::model::params::ParamSet;
use cowclip::reference::ModelKind;
use cowclip::runtime::Runtime;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;

fn runtime() -> Option<Arc<Runtime>> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::new(&dir).expect("open runtime")))
}

fn config(batch: usize, workers: usize, epochs: f64) -> TrainConfig {
    let preset = criteo_preset();
    TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs,
        workers,
        threads: 1,
        param_shards: 1,
        warmup_steps: 0,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    }
}

#[test]
fn hlo_training_learns_signal() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 12_000, seed: 7, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    let engine = Engine::hlo(rt, ModelKind::DeepFm, "criteo_synth", ClipMode::CowClip).unwrap();
    let mut trainer = Trainer::new(engine, config(512, 1, 2.0)).unwrap();
    let report = trainer.train(&train, &test).unwrap();

    assert!(!report.diverged);
    assert!(report.steps > 20);
    assert!(
        report.final_auc > 0.62,
        "model should beat chance clearly: auc {}",
        report.final_auc
    );
    // training loss should drop from the first few steps to the last few
    let head: f32 = report.train_loss_curve[..5].iter().sum::<f32>() / 5.0;
    let n = report.train_loss_curve.len();
    let tail: f32 = report.train_loss_curve[n - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss should fall: {head} -> {tail}");
}

#[test]
fn worker_count_does_not_change_the_math() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 3000, seed: 8, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);

    let mut finals: Vec<Vec<f32>> = Vec::new();
    for workers in [1usize, 4] {
        let engine =
            Engine::hlo(rt.clone(), ModelKind::WideDeep, "criteo_synth", ClipMode::CowClip)
                .unwrap();
        let mut trainer = Trainer::new(engine, config(512, workers, 1.0)).unwrap();
        let report = trainer.train(&train, &test).unwrap();
        assert!(!report.diverged);
        if workers > 1 {
            assert!(report.reduce_stats.bytes_moved > 0);
            assert_eq!(report.reduce_stats.workers, workers);
        }
        finals.push(trainer.params().tensors[0].as_f32().unwrap().to_vec());
    }
    // data-parallel sharding is numerically equivalent (up to f32 assoc):
    let (a, b) = (&finals[0], &finals[1]);
    let max_diff = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "worker sharding changed results by {max_diff}");
}

#[test]
fn reference_engine_trains_without_artifacts() {
    let schema = cowclip::data::schema::criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 2000, seed: 9, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let engine = Engine::reference(
        ModelKind::DeepFm,
        schema,
        10,
        vec![32, 32],
        3,
        ClipMode::CowClip,
    );
    let mut trainer = Trainer::new(engine, config(64, 1, 1.0)).unwrap();
    let report = trainer.train(&train, &test).unwrap();
    assert!(!report.diverged);
    assert!(report.final_auc.is_finite());
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(rt) = runtime() else { return };
    let engine = Engine::hlo(rt, ModelKind::Dcn, "criteo_synth", ClipMode::CowClip).unwrap();
    let trainer = Trainer::new(engine, config(64, 1, 1.0)).unwrap();
    let dir = std::env::temp_dir().join(format!("cowclip_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("dcn.ckpt");
    trainer.params().save(&path).unwrap();
    let back = ParamSet::load(&path, &trainer.params().spec).unwrap();
    assert_eq!(back.tensors, trainer.params().tensors);
    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpoint resume: a run saved mid-stream and resumed in a fresh
/// trainer must continue exactly like the uninterrupted run — same
/// params, same Adam moments, same step counter — including through the
/// warmup window (the resumed step counter drives the same factors).
#[test]
fn checkpoint_resume_matches_uninterrupted_run() {
    let schema = cowclip::data::schema::criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 2_000, seed: 12, ..Default::default() });
    let engine = || {
        Engine::reference(
            ModelKind::DeepFm,
            cowclip::data::schema::criteo_synth(),
            8,
            vec![32, 32],
            2,
            ClipMode::CowClip,
        )
    };
    let mut cfg = config(128, 1, 1.0);
    cfg.warmup_steps = 6; // steps 5..6 of the resumed run are still warming
    cfg.param_shards = 2;

    // uninterrupted: 8 steps over a fixed batch sequence
    let mut batches = cowclip::data::Batcher::new(&ds, 128, 77);
    let seq: Vec<_> = (0..8).map(|_| batches.next_batch()).collect();
    let mut full = Trainer::new(engine(), cfg.clone()).unwrap();
    for b in &seq {
        full.train_step(b).unwrap();
    }

    // interrupted twin: 4 steps, save, resume in a fresh trainer, finish
    let dir = std::env::temp_dir().join(format!("cowclip_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");
    let mut first = Trainer::new(engine(), cfg.clone()).unwrap();
    for b in &seq[..4] {
        first.train_step(b).unwrap();
    }
    first.save_checkpoint(&path).unwrap();
    let mut resumed = Trainer::new(engine(), cfg).unwrap();
    resumed.resume_from(&path).unwrap();
    assert_eq!(resumed.step(), 4, "resume must restore the step counter");
    for b in &seq[4..] {
        resumed.train_step(b).unwrap();
    }

    assert_eq!(resumed.step(), full.step());
    let (a, b) = (full.params(), resumed.params());
    for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        let (xa, xb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        for (j, (x, y)) in xa.iter().zip(xb).enumerate() {
            assert!((x - y).abs() <= 1e-6, "param[{i}][{j}]: {x} vs {y}");
        }
    }
    drop((a, b));
    let (mf, vf) = full.store.moments();
    let (mr, vr) = resumed.store.moments();
    assert_eq!(mf.tensors, mr.tensors, "m moments must round-trip");
    assert_eq!(vf.tensors, vr.tensors, "v moments must round-trip");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn divergence_is_detected_not_hidden() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 2000, seed: 10, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let engine = Engine::hlo(rt, ModelKind::DeepFm, "criteo_synth", ClipMode::None).unwrap();
    let mut cfg = config(64, 1, 1.0);
    cfg.base_hypers.lr_dense = 1e6; // force a blow-up
    cfg.base_hypers.lr_embed = 1e6;
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    let report = trainer.train(&train, &test).unwrap();
    assert!(report.diverged || report.final_auc.is_nan() || report.final_logloss > 2.0);
}

/// PR-5 acceptance: after a one-step warmup, `train_step`'s compute path
/// performs zero steady-state scratch allocations — every
/// forward/backward intermediate is recycled through the trainer's
/// per-thread arenas. (The escaping gradient payloads are the step's
/// *outputs*, not compute-path intermediates, and are excluded by
/// construction: they never come from the arena.)
#[test]
fn train_step_compute_path_is_allocation_free_at_steady_state() {
    let schema = cowclip::data::schema::criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 2_000, seed: 12, ..Default::default() });
    let (train, _) = random_split(&ds, 0.9, 0);
    let engine = Engine::reference(
        ModelKind::DeepFm,
        schema,
        8,
        vec![32, 32],
        2,
        ClipMode::CowClip,
    );
    let mut trainer = Trainer::new(engine, config(128, 1, 1.0)).unwrap();
    let mut batcher = cowclip::data::Batcher::new(&train, 128, 3);
    // warmup: the first step grows every arena buffer to steady state
    let b = batcher.next_batch();
    trainer.train_step(&b).unwrap();
    let grown = trainer.scratch_grow_events();
    assert!(grown > 0, "warmup must populate the arena");
    let mut losses = Vec::new();
    for _ in 0..5 {
        let b = batcher.next_batch();
        losses.push(trainer.train_step(&b).unwrap().0);
    }
    assert_eq!(
        trainer.scratch_grow_events(),
        grown,
        "steady-state train_step allocated new scratch buffers on the compute path"
    );
    // and the run actually trained (finite, not constant garbage)
    assert!(losses.iter().all(|l| l.is_finite()), "steady-state steps must stay finite");
}
