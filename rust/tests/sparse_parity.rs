//! Dense ↔ sparse parity: the touched-rows gradient path must be
//! elementwise-exact (≤ 1e-6) against the dense reference on every
//! clipping mode, through accumulation and all-reduce, and lazy Adam
//! must match eager Adam wherever their semantics coincide (every row
//! touched every step).

use cowclip::clip::{
    clip_embedding_grads, clip_embedding_grads_sparse, ClipMode, ClipParams,
};
use cowclip::coordinator::allreduce::{tree_allreduce, Contribution};
use cowclip::coordinator::{Engine, GradAccumulator, TrainConfig, Trainer};
use cowclip::data::schema::{criteo_synth, Schema};
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::optim::{Adam, AdamConfig, LazyAdam};
use cowclip::reference::{ModelKind, ReferenceEngine, ReferenceModel};
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::tensor::{GradTensor, SparseRows};
use cowclip::util::Rng;

const TOL: f32 = 1e-6;

fn close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= TOL, "{what}[{i}]: {x} vs {y}");
    }
}

fn test_schema() -> Schema {
    Schema {
        name: "parity".into(),
        n_dense: 2,
        vocab_sizes: vec![40, 25, 10, 3],
    }
}

/// A Criteo-shaped sparse gradient: few touched rows, skewed counts.
fn sparse_grad(schema: &Schema, d: usize, seed: u64) -> (SparseRows, Vec<f32>, Vec<f32>) {
    let v = schema.total_vocab();
    let mut rng = Rng::new(seed);
    let ids: Vec<u32> = (0..v as u32).filter(|_| rng.bernoulli(0.25)).collect();
    let counts: Vec<f32> = ids.iter().map(|_| 1.0 + rng.below(6) as f32).collect();
    let vals: Vec<f32> = (0..ids.len() * d)
        .map(|_| (rng.next_gaussian() * 2.0) as f32)
        .collect();
    let w: Vec<f32> = (0..v * d).map(|_| rng.next_gaussian() as f32 * 0.05).collect();
    (SparseRows::new(v, d, ids, vals), counts, w)
}

/// Acceptance: all six clip modes agree dense vs sparse to 1e-6.
#[test]
fn clip_parity_all_six_modes() {
    let schema = test_schema();
    let d = 8;
    for (mi, mode) in ClipMode::ALL.into_iter().enumerate() {
        let (sg, counts, w) = sparse_grad(&schema, d, 100 + mi as u64);
        let dense = sg.to_dense();
        let mut dense_counts = vec![0.0f32; schema.total_vocab()];
        for (&id, &c) in sg.ids().iter().zip(&counts) {
            dense_counts[id as usize] = c;
        }
        for p in [
            ClipParams::default(),
            ClipParams { r: 0.5, zeta: 1e-4, clip_t: 0.1 },
            ClipParams { r: 2.0, zeta: 0.0, clip_t: 10.0 },
        ] {
            let mut dense_run = dense.clone();
            let mut sparse_run = sg.clone();
            clip_embedding_grads(mode, &mut dense_run, &w, &dense_counts, &schema, d, &p);
            clip_embedding_grads_sparse(mode, &mut sparse_run, &w, &counts, &schema, &p);
            close(&sparse_run.to_dense(), &dense_run, &format!("clip {mode}"));
        }
    }
}

/// Acceptance: lazy Adam == eager Adam (1e-6/element) when every row is
/// touched every step, across many steps and shapes.
#[test]
fn lazy_vs_eager_adam_parity() {
    let cfg = AdamConfig::default();
    let eager = Adam::new(cfg);
    let n_rows = 17;
    let d = 5;
    let mut lazy = LazyAdam::new(cfg, n_rows);
    let mut rng = Rng::new(7);
    let n = n_rows * d;
    let mut we: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
    let mut me = vec![0.0f32; n];
    let mut ve = vec![0.0f32; n];
    let (mut wl, mut ml, mut vl) = (we.clone(), me.clone(), ve.clone());
    let ids: Vec<u32> = (0..n_rows as u32).collect();
    for t in 1..=200u32 {
        let g: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        eager.step(&mut we, &mut me, &mut ve, &g, 2e-3, t as f32);
        lazy.step_rows(&mut wl, &mut ml, &mut vl, &ids, &g, d, 2e-3, t);
    }
    close(&we, &wl, "w");
    close(&me, &ml, "m");
    close(&ve, &vl, "v");
}

/// Lazy Adam's closed-form catch-up reproduces the eager moment
/// trajectory exactly under skipped (zero-grad) steps.
#[test]
fn lazy_adam_moment_catchup_is_exact() {
    let cfg = AdamConfig::default();
    let eager = Adam::new(cfg);
    let mut lazy = LazyAdam::new(cfg, 2);
    let d = 1;
    let (mut we, mut me, mut ve) = (vec![0.2f32, -0.3], vec![0.0f32; 2], vec![0.0f32; 2]);
    let (mut wl, mut ml, mut vl) = (we.clone(), me.clone(), ve.clone());
    // row 0 touched at steps {1, 7}; row 1 at every step
    for t in 1..=7u32 {
        let g0 = if t == 1 || t == 7 { 0.8 } else { 0.0 };
        eager.step(&mut we, &mut me, &mut ve, &[g0, -0.5], 0.01, t as f32);
        if t == 1 || t == 7 {
            lazy.step_rows(&mut wl, &mut ml, &mut vl, &[0, 1], &[g0, -0.5], d, 0.01, t);
        } else {
            lazy.step_rows(&mut wl, &mut ml, &mut vl, &[1], &[-0.5], d, 0.01, t);
        }
    }
    // moments agree on both rows
    close(&me, &ml, "m");
    for (i, (&a, &b)) in ve.iter().zip(&vl).enumerate() {
        assert!((a - b).abs() <= 1e-7, "v[{i}]: {a} vs {b}");
    }
    // the always-touched row's weight agrees exactly too
    assert!((we[1] - wl[1]).abs() <= TOL, "w[1]: {} vs {}", we[1], wl[1]);
}

/// Accumulating k sparse microbatches equals accumulating the same
/// gradients densified, elementwise.
#[test]
fn accumulation_parity_sparse_vs_dense() {
    let schema = test_schema();
    let v = schema.total_vocab();
    let d = 4;
    let k = 8;
    let mut sparse_acc = GradAccumulator::new(v);
    let mut dense_acc = GradAccumulator::new(v);
    for i in 0..k {
        let (sg, counts, _) = sparse_grad(&schema, d, 200 + i);
        let sparse_counts = SparseRows::new(v, 1, sg.ids().to_vec(), counts);
        let out_sparse = cowclip::reference::GradOutput {
            grads: vec![GradTensor::Sparse(sg.clone())],
            counts: sparse_counts.clone(),
            loss: 0.5,
        };
        let out_dense = cowclip::reference::GradOutput {
            grads: vec![GradTensor::Dense(sg.to_tensor())],
            counts: sparse_counts,
            loss: 0.5,
        };
        sparse_acc.add(&out_sparse, 1.0 / k as f64).unwrap();
        dense_acc.add(&out_dense, 1.0 / k as f64).unwrap();
    }
    let (gs, cs, ls) = sparse_acc.finish().unwrap();
    let (gd, cd, ld) = dense_acc.finish().unwrap();
    assert!(matches!(gs[0], GradTensor::Sparse(_)), "sparse path densified");
    close(
        gs[0].to_tensor().as_f32().unwrap(),
        gd[0].to_tensor().as_f32().unwrap(),
        "accumulated grad",
    );
    close(&cs.to_dense(), &cd.to_dense(), "accumulated counts");
    assert!((ls - ld).abs() <= TOL);
}

/// Tree all-reduce over sparse contributions equals the dense reduce,
/// and moves strictly fewer bytes.
#[test]
fn allreduce_parity_and_traffic_saving() {
    let schema = test_schema();
    let v = schema.total_vocab();
    let d = 4;
    let workers = 4;
    let mut sparse_contribs = Vec::new();
    let mut dense_contribs = Vec::new();
    for r in 0..workers {
        let (sg, counts, _) = sparse_grad(&schema, d, 300 + r);
        let sc = SparseRows::new(v, 1, sg.ids().to_vec(), counts);
        sparse_contribs.push(Contribution {
            grads: vec![GradTensor::Sparse(sg.clone())],
            counts: sc.clone(),
            loss_weighted: 0.1 / workers as f32,
            weight: 1.0 / workers as f32,
        });
        dense_contribs.push(Contribution {
            grads: vec![GradTensor::Dense(sg.to_tensor())],
            counts: sc,
            loss_weighted: 0.1 / workers as f32,
            weight: 1.0 / workers as f32,
        });
    }
    let (ts, ss) = tree_allreduce(sparse_contribs).unwrap();
    let (td, sd) = tree_allreduce(dense_contribs).unwrap();
    close(
        ts.grads[0].to_tensor().as_f32().unwrap(),
        td.grads[0].to_tensor().as_f32().unwrap(),
        "reduced grad",
    );
    close(&ts.counts.to_dense(), &td.counts.to_dense(), "reduced counts");
    assert!(
        ss.bytes_moved < sd.bytes_moved,
        "sparse all-reduce should move fewer bytes: {} vs {}",
        ss.bytes_moved,
        sd.bytes_moved
    );
}

/// The reference model's sparse counts match a dense recount of the
/// batch, and the sparse embed gradient's support is exactly the
/// touched-id set.
#[test]
fn reference_grad_sparse_support_is_exact() {
    let schema = test_schema();
    let model = ReferenceModel::new(ModelKind::DeepFm, schema.clone(), 6, vec![16, 16], 2);
    let engine = ReferenceEngine::new(model, ClipMode::CowClip);
    let ds = generate(&schema, &SynthConfig { n: 400, seed: 11, ..Default::default() });
    let mut batcher = cowclip::data::batcher::Batcher::new(&ds, 64, 3);
    let batch = batcher.next_batch();
    let spec = engine.spec();
    let params = cowclip::model::init_params(
        &spec,
        &cowclip::model::InitConfig { seed: 5, embed_sigma: 0.01 },
    );
    let out = engine.grad(&params, &batch).unwrap();

    let mut dense_counts = vec![0.0f32; schema.total_vocab()];
    for &id in batch.x_cat.as_i32().unwrap() {
        dense_counts[id as usize] += 1.0;
    }
    close(&out.counts.to_dense(), &dense_counts, "counts");
    match &out.grads[0] {
        GradTensor::Sparse(s) => {
            let expected: Vec<u32> = dense_counts
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0.0)
                .map(|(i, _)| i as u32)
                .collect();
            assert_eq!(s.ids(), expected.as_slice(), "embed grad support");
        }
        GradTensor::Dense(_) => panic!("reference embed grad should be sparse"),
    }
}

/// End to end: the sparse trainer path learns (loss falls, finite AUC)
/// through Trainer -> workers -> accumulate -> all-reduce -> sparse
/// apply, with multiple workers.
#[test]
fn e2e_sparse_pipeline_trains() {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 3000, seed: 9, ..Default::default() });
    let (train, test) = random_split(&ds, 0.9, 0);
    let preset = criteo_preset();
    let engine = Engine::reference(
        ModelKind::DeepFm,
        schema,
        8,
        vec![32, 32],
        2,
        ClipMode::CowClip,
    );
    let cfg = TrainConfig {
        batch: 128,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 1.0,
        workers: 4,
        threads: 1,
        param_shards: 1,
        warmup_steps: 0,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    let report = trainer.train(&train, &test).unwrap();
    assert!(!report.diverged);
    assert!(report.final_auc.is_finite());
    let head: f32 = report.train_loss_curve[..3].iter().sum::<f32>() / 3.0;
    let n = report.train_loss_curve.len();
    let tail: f32 = report.train_loss_curve[n - 3..].iter().sum::<f32>() / 3.0;
    assert!(tail < head, "loss should fall on the sparse path: {head} -> {tail}");
    assert!(report.reduce_stats.bytes_moved > 0);
}
