//! Distributed == sequential: the multi-process socket path
//! (`coordinator::dist`) must reproduce the in-process seed trainer
//! **bitwise** — same loss curve, same final params, same AUC — for
//! every clip mode and 1/2/4 ranks with compression off (the `Contrib`
//! and `Total` payloads are raw little-endian f32, and the fixed binary
//! reduction tree pairs contributions identically on both paths). With
//! u8 wire quantization + error feedback the run is no longer bitwise,
//! but the final AUC must stay within 1e-3 of the sequential run while
//! the sparse wire sections shrink ≥4×. A hung rank must surface as a
//! deadline error with a clean shutdown, and the `cowclip train
//! --ranks --spawn-workers` CLI path must work end to end as real
//! processes.
//!
//! Workers here run on threads of the test process (the protocol is
//! byte-identical to the multi-process deployment); the last test forks
//! actual `cowclip` processes through the CLI.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use cowclip::clip::ClipMode;
use cowclip::coordinator::{
    coordinate, dist_worker, DistOptions, DistReport, Endpoint, Engine, TrainConfig, TrainReport,
    Trainer,
};
use cowclip::data::dataset::Dataset;
use cowclip::data::schema::criteo_synth;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::model::ParamSet;
use cowclip::reference::ModelKind;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::wire::codec::encode_hello;
use cowclip::wire::{read_frame, write_frame, Compression, FrameKind, Hello};

static SOCK_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Unique per-process socket path (tests in one binary run in parallel).
fn temp_sock(tag: &str) -> PathBuf {
    let k = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("cowclip_dp_{}_{tag}_{k}.sock", std::process::id()))
}

fn engine_for(clip: ClipMode) -> Engine {
    Engine::reference(ModelKind::DeepFm, criteo_synth(), 8, vec![32, 32], 2, clip)
}

fn cfg_for(ranks: usize, batch: usize, epochs: f64) -> TrainConfig {
    let preset = criteo_preset();
    TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs,
        workers: ranks,
        threads: 1,
        param_shards: 1,
        warmup_steps: 4,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    }
}

fn data(n: usize) -> (Dataset, Dataset) {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n, seed: 19, ..Default::default() });
    random_split(&ds, 0.9, 0)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// The in-process seed path: same config, same worker fan-out, no wire.
fn seq_run(
    clip: ClipMode,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> (TrainReport, ParamSet) {
    let mut trainer = Trainer::new(engine_for(clip), cfg.clone()).unwrap();
    let report = trainer.train(train, test).unwrap();
    let params = trainer.store.snapshot();
    (report, params)
}

/// One full socket run: coordinator on this thread, one worker thread
/// per rank, all over a fresh Unix socket.
fn dist_run(
    clip: ClipMode,
    cfg: &TrainConfig,
    compress: Compression,
    train: &Dataset,
    test: &Dataset,
) -> (DistReport, ParamSet) {
    let ranks = cfg.workers;
    let sock = temp_sock("run");
    let opts = DistOptions::new(
        ranks,
        Endpoint::Unix(sock.clone()),
        compress,
        Duration::from_secs(60),
    );
    let out = std::thread::scope(|s| {
        let opts = &opts;
        let handles: Vec<_> = (0..ranks)
            .map(|rank| {
                s.spawn(move || {
                    let engine = engine_for(clip);
                    dist_worker(&engine, cfg, train, rank, opts)
                })
            })
            .collect();
        let engine = engine_for(clip);
        let (report, store) = coordinate(&engine, cfg, train, test, opts).unwrap();
        for (rank, h) in handles.into_iter().enumerate() {
            h.join()
                .unwrap()
                .unwrap_or_else(|e| panic!("rank {rank} failed: {e:#}"));
        }
        (report, store.snapshot())
    });
    let _ = std::fs::remove_file(&sock);
    out
}

/// Acceptance (determinism): with compression off, 1/2/4-rank socket
/// runs are bitwise identical to the sequential seed path for all six
/// clip modes — loss curve, final params, and AUC.
#[test]
fn socket_runs_match_sequential_bitwise_all_modes() {
    let (train, test) = data(1_500);
    for clip in ClipMode::ALL {
        for ranks in [1usize, 2, 4] {
            let cfg = cfg_for(ranks, 128, 1.0);
            let (seq_report, seq_params) = seq_run(clip, &cfg, &train, &test);
            let (dist_report, dist_params) =
                dist_run(clip, &cfg, Compression::None, &train, &test);
            let tag = format!("{clip}/ranks={ranks}");
            assert_eq!(seq_report.steps, dist_report.steps, "{tag}: step count");
            assert_bitwise(
                &seq_report.train_loss_curve,
                &dist_report.train_loss_curve,
                &format!("{tag}: loss curve"),
            );
            for (i, (a, b)) in seq_params.tensors.iter().zip(&dist_params.tensors).enumerate() {
                assert_bitwise(
                    a.as_f32().unwrap(),
                    b.as_f32().unwrap(),
                    &format!("{tag}: param[{i}] ({})", seq_params.spec[i].name),
                );
            }
            assert_eq!(
                seq_report.final_auc.to_bits(),
                dist_report.final_auc.to_bits(),
                "{tag}: AUC {} vs {}",
                seq_report.final_auc,
                dist_report.final_auc
            );
            // Lossless wire: raw and on-wire byte counts coincide.
            assert_eq!(
                dist_report.stats.raw_bytes, dist_report.stats.wire_bytes,
                "{tag}: lossless uplink must cost exactly its raw size"
            );
        }
    }
}

/// Acceptance (compression): u8 quantization with error feedback keeps
/// the final AUC within 1e-3 of the sequential run while the sparse
/// wire sections shrink at least 4x.
#[test]
fn u8_compression_preserves_auc_and_compresses_4x() {
    let (train, test) = data(6_000);
    let cfg = cfg_for(2, 256, 2.0);
    let clip = ClipMode::CowClip;
    let (seq_report, _) = seq_run(clip, &cfg, &train, &test);
    let (dist_report, _) = dist_run(clip, &cfg, Compression::U8, &train, &test);
    assert_eq!(seq_report.steps, dist_report.steps, "step count");
    let delta = (seq_report.final_auc - dist_report.final_auc).abs();
    assert!(
        delta <= 1e-3,
        "u8 wire AUC drifted {delta:.2e} ({} vs {})",
        seq_report.final_auc,
        dist_report.final_auc
    );
    let ratio = dist_report.stats.compression_ratio();
    assert!(ratio >= 4.0, "sparse compression ratio {ratio:.2} < 4.0");
    assert!(
        dist_report.stats.wire_bytes < dist_report.stats.raw_bytes,
        "compressed uplink must beat raw ({} vs {})",
        dist_report.stats.wire_bytes,
        dist_report.stats.raw_bytes
    );
}

/// Acceptance (liveness): a rank that handshakes and then goes silent
/// surfaces as a coordinator error naming the deadline, and the hung
/// peer is told why via an `Error` frame instead of being left hanging.
#[test]
fn hung_rank_surfaces_deadline_error() {
    let (train, test) = data(1_500);
    let cfg = cfg_for(1, 128, 1.0);
    let sock = temp_sock("deadline");
    let mut opts = DistOptions::new(
        1,
        Endpoint::Unix(sock.clone()),
        Compression::None,
        Duration::from_millis(300),
    );
    // Recovery off: the hung rank must surface as a deadline error, not
    // trigger a reconnect window.
    opts.max_restarts = 0;
    let steps_per_epoch = train.n() / cfg.batch;
    let total_steps = ((steps_per_epoch as f64) * cfg.epochs).round() as u64;
    let err = std::thread::scope(|s| {
        let (cfg, opts) = (&cfg, &opts);
        let hung = s.spawn(move || {
            let mut conn = opts.endpoint.connect_retry(Duration::from_secs(10)).unwrap();
            conn.set_io_deadline(Some(Duration::from_secs(10))).unwrap();
            let hello = Hello {
                rank: 0,
                ranks: 1,
                batch: cfg.batch as u64,
                seed: cfg.seed,
                total_steps,
                last_step: 0,
                fingerprint: cfg.fingerprint(),
            };
            write_frame(&mut conn, FrameKind::Hello, &encode_hello(&hello)).unwrap();
            let (kind, _) = read_frame(&mut conn).unwrap();
            assert_eq!(kind, FrameKind::Welcome);
            // Hang: never send a Contrib. The coordinator must give up
            // at its 300 ms deadline and push the Error frame read here.
            let (kind, _) = read_frame(&mut conn).expect("error frame after the deadline");
            assert_eq!(kind, FrameKind::Error);
        });
        let engine = engine_for(ClipMode::CowClip);
        let err = coordinate(&engine, cfg, &train, &test, opts).unwrap_err();
        hung.join().unwrap();
        err
    });
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline"), "error should name the deadline: {msg}");
    let _ = std::fs::remove_file(&sock);
}

/// Acceptance (CLI): `train --ranks 2 --spawn-workers` forks real
/// worker processes, trains over the Unix socket with u8 compression,
/// and reports the result + wire traffic.
#[test]
fn cli_spawn_workers_end_to_end() {
    let sock = temp_sock("cli");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cowclip"))
        .args([
            "train",
            "--model",
            "deepfm",
            "--schema",
            "criteo_synth",
            "--n",
            "2000",
            "--batch",
            "128",
            "--epochs",
            "0.25",
            "--threads",
            "1",
            "--engine",
            "reference",
            "--ranks",
            "2",
            "--spawn-workers",
            "--compress",
            "u8",
            "--deadline-ms",
            "60000",
            "--bind",
        ])
        .arg(format!("unix:{}", sock.display()))
        .output()
        .expect("running the cowclip binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "cli run failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("final test AUC"), "missing result line:\n{stdout}");
    assert!(stdout.contains("uplink:"), "missing wire-traffic line:\n{stdout}");
    let _ = std::fs::remove_file(&sock);
}
