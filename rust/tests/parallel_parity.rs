//! Threaded == sequential: the parallel execution engine (threaded
//! worker fan-out, reduce-as-ready merging, prefetching data pipeline,
//! parallel eval) must reproduce the sequential run exactly — same loss
//! curve, same final parameters, same AUC — because contributions merge
//! in rank order no matter which thread finishes first.
//!
//! Runs on the reference engine for every clip mode; the HLO engine
//! shares the same coordinator path but needs the `pjrt` feature +
//! artifacts (covered by `train_integration.rs` when available).

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, TrainConfig, TrainReport, Trainer};
use cowclip::data::dataset::Dataset;
use cowclip::data::schema::criteo_synth;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::data::{Batcher, Prefetch};
use cowclip::reference::ModelKind;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;

const TOL: f32 = 1e-6;

fn data() -> (Dataset, Dataset) {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n: 2_000, seed: 17, ..Default::default() });
    random_split(&ds, 0.9, 0)
}

fn run(
    clip: ClipMode,
    workers: usize,
    threads: usize,
    train: &Dataset,
    test: &Dataset,
) -> (TrainReport, Vec<Vec<f32>>) {
    let preset = criteo_preset();
    let engine = Engine::reference(
        ModelKind::DeepFm,
        criteo_synth(),
        8,
        vec![32, 32],
        2,
        clip,
    );
    let cfg = TrainConfig {
        batch: 128,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 1.0,
        workers,
        threads,
        param_shards: 1, // the shard dimension is covered by shard_parity.rs
        warmup_steps: 4,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    let report = trainer.train(train, test).unwrap();
    let params = trainer
        .params()
        .tensors
        .iter()
        .map(|t| t.as_f32().unwrap().to_vec())
        .collect();
    (report, params)
}

fn close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= TOL, "{what}[{i}]: {x} vs {y}");
    }
}

/// Acceptance: a 4-worker run on ≥2 threads reproduces the sequential
/// run's loss curve and final params within 1e-6, for every clip mode.
#[test]
fn threaded_run_matches_sequential_all_clip_modes() {
    let (train, test) = data();
    for clip in ClipMode::ALL {
        let (seq, seq_params) = run(clip, 4, 1, &train, &test);
        let (thr, thr_params) = run(clip, 4, 4, &train, &test);
        assert!(!seq.diverged && !thr.diverged, "{clip}: diverged");
        assert_eq!(seq.steps, thr.steps, "{clip}: step count");
        close(
            &seq.train_loss_curve,
            &thr.train_loss_curve,
            &format!("{clip}: loss curve"),
        );
        assert_eq!(seq_params.len(), thr_params.len(), "{clip}: param arity");
        for (i, (a, b)) in seq_params.iter().zip(&thr_params).enumerate() {
            close(a, b, &format!("{clip}: param[{i}]"));
        }
        assert!(
            (seq.final_auc - thr.final_auc).abs() <= TOL as f64,
            "{clip}: AUC {} vs {}",
            seq.final_auc,
            thr.final_auc
        );
        // the reduction does the same number of rank-ordered merges
        assert_eq!(seq.reduce_stats, thr.reduce_stats, "{clip}: reduce stats");
    }
}

/// Thread count is a pure throughput knob: 2 and 3 threads (worker count
/// not divisible by threads) agree with 4.
#[test]
fn odd_thread_counts_agree() {
    let (train, test) = data();
    let (_, p1) = run(ClipMode::CowClip, 4, 1, &train, &test);
    for threads in [2usize, 3] {
        let (_, p) = run(ClipMode::CowClip, 4, threads, &train, &test);
        for (i, (a, b)) in p1.iter().zip(&p).enumerate() {
            close(a, b, &format!("threads={threads}: param[{i}]"));
        }
    }
}

/// Parallel evaluate pushes logits in batch order, so AUC/logloss are
/// identical at any thread count.
#[test]
fn parallel_evaluate_matches_sequential() {
    let (train, test) = data();
    let preset = criteo_preset();
    let engine = Engine::reference(
        ModelKind::WideDeep,
        criteo_synth(),
        8,
        vec![32, 32],
        2,
        ClipMode::CowClip,
    );
    let cfg = TrainConfig {
        batch: 128,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: 1.0,
        workers: 2,
        threads: 1,
        param_shards: 1,
        warmup_steps: 0,
        init_sigma: preset.init_sigma_cowclip,
        seed: 7,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    trainer.train(&train, &test).unwrap();
    // same trained params, eval with 1 vs many threads
    trainer.cfg.threads = 1;
    let (auc_seq, ll_seq) = trainer.evaluate(&test).unwrap();
    trainer.cfg.threads = 4;
    let (auc_par, ll_par) = trainer.evaluate(&test).unwrap();
    assert_eq!(auc_seq, auc_par, "AUC must not depend on eval threads");
    assert_eq!(ll_seq, ll_par, "logloss must not depend on eval threads");
}

/// The prefetcher hands the trainer the exact batch sequence the inline
/// batcher would produce: same epoch coverage, same shuffle order.
#[test]
fn prefetched_batcher_matches_inline_order() {
    let (train, _) = data();
    let steps = 3 * (train.n() / 128);
    let mut inline = Batcher::new(&train, 128, 99);
    let inline_batches: Vec<Vec<i32>> = (0..steps)
        .map(|_| inline.next_batch().x_cat.as_i32().unwrap().to_vec())
        .collect();

    let mut bg = Batcher::new(&train, 128, 99);
    let prefetched: Vec<Vec<i32>> = std::thread::scope(|s| {
        Prefetch::spawn(
            s,
            (0..steps).map(move |_| {
                let b = bg.next_batch();
                let _ = b.touched();
                b
            }),
            2,
        )
        .map(|b| b.x_cat.as_i32().unwrap().to_vec())
        .collect()
    });
    assert_eq!(inline_batches, prefetched);
}
