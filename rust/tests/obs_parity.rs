//! Observability is provably inert: training results are bitwise
//! identical with tracing + metrics on vs off for all six clip modes;
//! span recording allocates nothing after warmup; a 2-rank dist run's
//! trace carries per-rank wire spans whose byte counters reconcile with
//! the wire report; the exported chrome-trace JSON and JSONL snapshots
//! parse with the expected phase names; and the whole subsystem costs
//! at most 3% of step time when enabled.
//!
//! The span/registry state is process-global, so every test that flips
//! tracing or reads counters serializes behind one mutex — the tests in
//! this binary may otherwise run on parallel threads.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use cowclip::clip::ClipMode;
use cowclip::coordinator::{
    coordinate, dist_worker, DistOptions, DistReport, Endpoint, Engine, TrainConfig, TrainReport,
    Trainer,
};
use cowclip::data::dataset::Dataset;
use cowclip::data::schema::criteo_synth;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::model::ParamSet;
use cowclip::obs;
use cowclip::reference::ModelKind;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::util::json::Json;
use cowclip::wire::Compression;

/// Serializes every tracing/registry-sensitive test in this binary.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_guard() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

static FILE_SEQ: AtomicUsize = AtomicUsize::new(0);

fn temp_path(tag: &str, ext: &str) -> PathBuf {
    let k = FILE_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("cowclip_obs_{}_{tag}_{k}.{ext}", std::process::id()))
}

fn engine_for(clip: ClipMode) -> Engine {
    Engine::reference(ModelKind::DeepFm, criteo_synth(), 8, vec![32, 32], 2, clip)
}

fn cfg_for(workers: usize, batch: usize, epochs: f64) -> TrainConfig {
    let preset = criteo_preset();
    TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs,
        workers,
        threads: 1,
        param_shards: 1,
        warmup_steps: 4,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    }
}

fn data(n: usize) -> (Dataset, Dataset) {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n, seed: 19, ..Default::default() });
    random_split(&ds, 0.9, 0)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

fn seq_run(
    clip: ClipMode,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> (TrainReport, ParamSet) {
    let mut trainer = Trainer::new(engine_for(clip), cfg.clone()).unwrap();
    let report = trainer.train(train, test).unwrap();
    let params = trainer.store.snapshot();
    (report, params)
}

/// 2-rank socket run with coordinator + workers on threads of this
/// process (the protocol is byte-identical to the multi-process path).
fn dist_run(
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> (DistReport, ParamSet) {
    let ranks = cfg.workers;
    let sock = temp_path("dist", "sock");
    let opts = DistOptions::new(
        ranks,
        Endpoint::Unix(sock.clone()),
        Compression::None,
        Duration::from_secs(60),
    );
    let out = std::thread::scope(|s| {
        let opts = &opts;
        let handles: Vec<_> = (0..ranks)
            .map(|rank| {
                s.spawn(move || {
                    let engine = engine_for(ClipMode::CowClip);
                    dist_worker(&engine, cfg, train, rank, opts)
                })
            })
            .collect();
        let engine = engine_for(ClipMode::CowClip);
        let (report, store) = coordinate(&engine, cfg, train, test, opts).unwrap();
        for (rank, h) in handles.into_iter().enumerate() {
            h.join().unwrap().unwrap_or_else(|e| panic!("rank {rank} failed: {e:#}"));
        }
        (report, store.snapshot())
    });
    let _ = std::fs::remove_file(&sock);
    out
}

/// Acceptance (inertness): all six clip modes produce bitwise-identical
/// loss curves, params and AUC with tracing + periodic metrics
/// snapshots enabled vs fully disabled.
#[test]
fn all_clip_modes_bitwise_identical_with_obs_on() {
    let _g = obs_guard();
    let (train, test) = data(1_200);
    for clip in ClipMode::ALL {
        let cfg = cfg_for(1, 128, 1.0);
        obs::set_tracing(false);
        let (off_report, off_params) = seq_run(clip, &cfg, &train, &test);

        let jsonl = temp_path("parity", "jsonl");
        obs::reset_spans();
        obs::set_tracing(true);
        let writer = obs::SnapshotWriter::spawn(&jsonl, Duration::from_millis(5)).unwrap();
        let (on_report, on_params) = seq_run(clip, &cfg, &train, &test);
        let lines = writer.finish().unwrap();
        obs::set_tracing(false);
        assert!(lines > 0, "{clip}: snapshot writer produced no lines");
        assert!(
            !obs::collect_spans().is_empty(),
            "{clip}: tracing was on but no spans were recorded"
        );
        let _ = std::fs::remove_file(&jsonl);

        assert_eq!(off_report.steps, on_report.steps, "{clip}: step count");
        assert_bitwise(
            &off_report.train_loss_curve,
            &on_report.train_loss_curve,
            &format!("{clip}: loss curve"),
        );
        for (i, (a, b)) in off_params.tensors.iter().zip(&on_params.tensors).enumerate() {
            assert_bitwise(
                a.as_f32().unwrap(),
                b.as_f32().unwrap(),
                &format!("{clip}: param[{i}] ({})", off_params.spec[i].name),
            );
        }
        assert_eq!(
            off_report.final_auc.to_bits(),
            on_report.final_auc.to_bits(),
            "{clip}: AUC {} vs {}",
            off_report.final_auc,
            on_report.final_auc
        );
    }
}

/// Acceptance (zero growth): after the first span warms a thread's
/// ring, recording tens of thousands more spans and counter updates
/// performs no further ring registration, and re-registering a metric
/// returns the same slot.
#[test]
fn recording_is_allocation_free_after_warmup() {
    let _g = obs_guard();
    obs::reset_spans();
    obs::set_tracing(true);
    {
        let _warm = obs::span(obs::Phase::Forward);
    }
    let grows = obs::thread_ring_grows();
    assert!(grows > 0, "warmup span should have registered this thread's ring");

    let ctr = obs::counter("obs_parity.gate");
    let gauge = obs::gauge("obs_parity.gate_gauge");
    let hist = obs::histogram("obs_parity.gate_hist");
    for i in 0..20_000u64 {
        let _s = obs::span_rank(obs::Phase::Clip, (i % 4) as usize);
        ctr.inc();
        gauge.set(i as f64);
        hist.record((i % 7) as f64);
    }
    assert_eq!(
        obs::thread_ring_grows(),
        grows,
        "steady-state span recording must not grow or re-register the ring"
    );
    // Registration is idempotent: the same name resolves to the same
    // atomic slot, never a new allocation.
    assert!(std::sync::Arc::ptr_eq(&ctr, &obs::counter("obs_parity.gate")));
    obs::set_tracing(false);
}

/// Acceptance (dist attribution): a 2-rank run's trace carries wire-tx
/// and wire-rx spans for both ranks, the per-rank wire-byte counters
/// reconcile exactly with the run's wire report, and the chrome-trace
/// JSON + JSONL snapshots parse with the expected phase names.
#[test]
fn two_rank_dist_trace_and_counters_reconcile() {
    let _g = obs_guard();
    let (train, test) = data(1_200);
    let cfg = cfg_for(2, 128, 1.0);

    obs::reset_spans();
    obs::set_tracing(true);
    let before = obs::snapshot_metrics();
    let jsonl = temp_path("dist", "jsonl");
    let writer = obs::SnapshotWriter::spawn(&jsonl, Duration::from_millis(5)).unwrap();
    let (report, _params) = dist_run(&cfg, &train, &test);
    let lines = writer.finish().unwrap();
    obs::set_tracing(false);
    let after = obs::snapshot_metrics();

    // Per-rank wire spans, both directions, both ranks.
    let spans = obs::collect_spans();
    for rank in 0..2u32 {
        for phase in [obs::Phase::WireTx, obs::Phase::WireRx] {
            assert!(
                spans.iter().any(|s| s.phase == phase && s.rank == rank),
                "missing {} span for rank {rank}",
                phase.name()
            );
        }
    }

    // Per-rank byte counters sum exactly to the wire report: the same
    // expressions feed both, so this is equality, not approximation.
    let delta = |name: &str| after.counter(name).saturating_sub(before.counter(name));
    let rx_sum: u64 = (0..2).map(|r| delta(&format!("dist.rank{r}.rx_bytes"))).sum();
    let tx_sum: u64 = (0..2).map(|r| delta(&format!("dist.rank{r}.tx_bytes"))).sum();
    assert_eq!(rx_sum, report.stats.wire_bytes, "sum of per-rank rx vs uplink wire bytes");
    assert_eq!(tx_sum, report.stats.bcast_bytes, "sum of per-rank tx vs broadcast bytes");

    // Chrome trace export parses and names only known phases.
    let trace = obs::render_json(&obs::chrome_trace_json());
    let v = Json::parse(&trace).unwrap();
    let events = v.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "trace export is empty");
    let known: Vec<&str> = obs::Phase::ALL.iter().map(|p| p.name()).collect();
    for e in events {
        let name = e.get("name").unwrap().as_str().unwrap();
        assert!(known.contains(&name), "unknown phase {name:?} in trace");
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
    }
    assert!(
        events.iter().any(|e| e.get("name").unwrap().as_str().unwrap() == "wire-tx"),
        "trace should contain wire-tx events"
    );

    // JSONL snapshots parse with the metrics schema.
    assert!(lines > 0, "no snapshot lines written");
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let mut parsed = 0;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str().unwrap(), "cowclip-metrics-v1");
        v.get("metrics").unwrap().get("counters").unwrap().as_obj().unwrap();
        parsed += 1;
    }
    assert!(parsed > 0, "no parseable snapshot lines");
    let last = Json::parse(text.lines().rev().find(|l| !l.trim().is_empty()).unwrap()).unwrap();
    let counters = last.get("metrics").unwrap().get("counters").unwrap().as_obj().unwrap();
    assert!(counters.contains_key("dist.steps"), "final snapshot should carry dist.steps");
    let _ = std::fs::remove_file(&jsonl);
}

/// Acceptance (overhead): enabling tracing + metrics costs at most 3%
/// of step wall time. Min-of-N on both sides, with retries, so timer
/// noise on a loaded CI host doesn't flake the gate.
#[test]
fn obs_overhead_within_three_percent() {
    let _g = obs_guard();
    let (train, test) = data(3_000);
    let cfg = cfg_for(1, 256, 1.0);
    let clip = ClipMode::CowClip;

    let min_of = |reps: usize, cfg: &TrainConfig| -> f64 {
        (0..reps)
            .map(|_| {
                let t0 = Instant::now();
                let _ = seq_run(clip, cfg, &train, &test);
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };

    let mut last = (0.0, 0.0);
    for attempt in 0..5 {
        obs::set_tracing(false);
        let off = min_of(3, &cfg);
        obs::reset_spans();
        obs::set_tracing(true);
        let on = min_of(3, &cfg);
        obs::set_tracing(false);
        last = (off, on);
        if on <= off * 1.03 {
            return;
        }
        eprintln!("overhead attempt {attempt}: off {off:.4}s on {on:.4}s — retrying");
    }
    panic!(
        "tracing overhead above 3%: off {:.4}s vs on {:.4}s ({:+.1}%)",
        last.0,
        last.1,
        (last.1 / last.0 - 1.0) * 100.0
    );
}

/// Acceptance (CLI): a traced `cowclip train` writes chrome-trace and
/// JSONL artifacts that `cowclip metrics --validate-*` accepts.
#[test]
fn cli_trace_and_metrics_artifacts_validate() {
    let trace = temp_path("cli", "json");
    let jsonl = temp_path("cli", "jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cowclip"))
        .args([
            "train",
            "--model",
            "deepfm",
            "--schema",
            "criteo_synth",
            "--n",
            "2000",
            "--batch",
            "128",
            "--epochs",
            "0.25",
            "--threads",
            "1",
            "--engine",
            "reference",
            "--metrics-interval",
            "5",
        ])
        .arg("--trace")
        .arg(&trace)
        .arg("--metrics-out")
        .arg(&jsonl)
        .output()
        .expect("running the cowclip binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "traced train failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("final test AUC"), "missing result line:\n{stdout}");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cowclip"))
        .arg("metrics")
        .arg("--validate-trace")
        .arg(&trace)
        .arg("--validate-jsonl")
        .arg(&jsonl)
        .output()
        .expect("running cowclip metrics");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "validation failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("valid chrome trace"), "missing trace verdict:\n{stdout}");
    assert!(stdout.contains("cowclip-metrics-v1"), "missing jsonl verdict:\n{stdout}");
    let _ = std::fs::remove_file(&trace);
    let _ = std::fs::remove_file(&jsonl);
}
