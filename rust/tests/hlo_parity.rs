//! End-to-end parity: AOT HLO programs vs the pure-Rust reference engine.
//!
//! These are the strongest correctness tests in the repo: the same
//! parameters and batches go through (a) the JAX→HLO→PJRT path and
//! (b) the hand-written Rust twin, and gradients / losses / optimizer
//! updates must agree to float32 tolerance.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::Path;
use std::sync::Arc;

use cowclip::clip::ClipMode;
use cowclip::coordinator::{Engine, GradAccumulator};
use cowclip::data::batcher::Batcher;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::model::init::{init_params, InitConfig};
use cowclip::reference::{ModelKind, ReferenceEngine, ReferenceModel};
use cowclip::runtime::{HypersVec, Runtime};
use cowclip::scaling::rules::HyperSet;

fn runtime() -> Option<Arc<Runtime>> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Arc::new(Runtime::new(&dir).expect("open runtime")))
}

fn reference_for(rt: &Runtime, model: ModelKind, schema: &str, clip: ClipMode) -> ReferenceEngine {
    let m = rt.manifest();
    let s = m.schema(schema).unwrap();
    ReferenceEngine::new(
        ReferenceModel::new(
            model,
            s,
            m.model_cfg.embed_dim,
            m.model_cfg.hidden.clone(),
            m.model_cfg.n_cross,
        ),
        clip,
    )
}

fn hypers() -> HyperSet {
    HyperSet {
        lr_dense: 1e-3,
        lr_embed: 1e-3,
        l2_embed: 1e-4,
        clip_r: 1.0,
        clip_zeta: 1e-5,
        clip_t: 0.5,
    }
}

fn rel_close(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    let mut worst_i = 0;
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs() / (atol + rtol * y.abs().max(x.abs()));
        if err > worst {
            worst = err;
            worst_i = i;
        }
    }
    assert!(
        worst <= 1.0,
        "{what}: worst rel err {worst:.2} at {worst_i}: {} vs {}",
        a[worst_i],
        b[worst_i]
    );
}

#[test]
fn manifest_schema_matches_rust_presets() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    for name in ["criteo_synth", "avazu_synth"] {
        let manifest_schema = m.schema(name).unwrap();
        let rust_schema = cowclip::data::schema::by_name(name).unwrap();
        assert_eq!(manifest_schema, rust_schema, "schema drift: {name}");
    }
}

#[test]
fn fwd_parity_all_models_criteo() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 1100, seed: 42, ..Default::default() });
    for kind in [ModelKind::DeepFm, ModelKind::Dcn] {
        let engine = Engine::hlo(rt.clone(), kind, "criteo_synth", ClipMode::CowClip).unwrap();
        let reference = reference_for(&rt, kind, "criteo_synth", ClipMode::CowClip);
        let params = init_params(&engine.spec(), &InitConfig { seed: 5, embed_sigma: 0.01 });

        let mut batcher = Batcher::new(&ds, 1024, 7);
        let batch = batcher.next_batch();
        let hlo_logits = engine.fwd(&params, &batch).unwrap();
        let ref_logits = reference.fwd(&params, &batch).unwrap();
        rel_close(&hlo_logits, &ref_logits, 2e-4, 2e-5, &format!("{kind} fwd"));
    }
}

#[test]
fn grad_parity_deepfm_and_dcnv2() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 600, seed: 43, ..Default::default() });
    for kind in [ModelKind::DeepFm, ModelKind::DcnV2] {
        let engine = Engine::hlo(rt.clone(), kind, "criteo_synth", ClipMode::CowClip).unwrap();
        let reference = reference_for(&rt, kind, "criteo_synth", ClipMode::CowClip);
        let params = init_params(&engine.spec(), &InitConfig { seed: 11, embed_sigma: 0.01 });

        let mut batcher = Batcher::new(&ds, 512, 3);
        let batch = batcher.next_batch();
        let h = engine.grad(&params, &batch).unwrap();
        let r = reference.grad(&params, &batch).unwrap();

        assert!((h.loss - r.loss).abs() < 1e-4, "{kind} loss {} vs {}", h.loss, r.loss);
        rel_close(
            &h.counts.to_dense(),
            &r.counts.to_dense(),
            0.0,
            0.5,
            &format!("{kind} counts"),
        );
        for (i, (hg, rg)) in h.grads.iter().zip(&r.grads).enumerate() {
            rel_close(
                hg.to_tensor().as_f32().unwrap(),
                rg.to_tensor().as_f32().unwrap(),
                5e-3,
                1e-6,
                &format!("{kind} grad[{i}] {}", params.spec[i].name),
            );
        }
    }
}

#[test]
fn apply_parity_cowclip_and_none() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 600, seed: 44, ..Default::default() });
    for clip in [ClipMode::CowClip, ClipMode::None] {
        let mut engine = Engine::hlo(rt.clone(), ModelKind::DeepFm, "criteo_synth", clip).unwrap();
        let mut reference = reference_for(&rt, ModelKind::DeepFm, "criteo_synth", clip);

        let mut params_h = init_params(&engine.spec(), &InitConfig { seed: 21, embed_sigma: 0.01 });
        let mut m_h = params_h.zeros_like();
        let mut v_h = params_h.zeros_like();
        let mut params_r = params_h.clone();
        let mut m_r = m_h.clone();
        let mut v_r = v_h.clone();

        let mut batcher = Batcher::new(&ds, 512, 9);
        let batch = batcher.next_batch();
        let out = engine.grad(&params_h, &batch).unwrap();

        let hv = HypersVec::new(hypers()).at_step(3).with_warmup(0.5);
        let mut grads_h = out.grads.clone();
        engine
            .apply(&mut params_h, &mut m_h, &mut v_h, &mut grads_h, &out.counts, &hv)
            .unwrap();
        let mut grads_r = out.grads.clone();
        let mut h = hypers();
        h.lr_dense *= 0.5; // warmup folded the same way
        reference
            .apply(&mut params_r, &mut m_r, &mut v_r, &mut grads_r, &out.counts, &h, 3.0)
            .unwrap();

        for i in 0..params_h.len() {
            rel_close(
                params_h.tensors[i].as_f32().unwrap(),
                params_r.tensors[i].as_f32().unwrap(),
                5e-4,
                1e-7,
                &format!("{clip} params[{i}]"),
            );
            rel_close(
                m_h.tensors[i].as_f32().unwrap(),
                m_r.tensors[i].as_f32().unwrap(),
                5e-4,
                1e-7,
                &format!("{clip} m[{i}]"),
            );
        }
    }
}

#[test]
fn microbatch_accumulation_matches_big_batch_hlo() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("criteo_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 600, seed: 45, ..Default::default() });
    let engine = Engine::hlo(rt.clone(), ModelKind::WideDeep, "criteo_synth", ClipMode::CowClip).unwrap();
    let params = init_params(&engine.spec(), &InitConfig { seed: 31, embed_sigma: 0.01 });

    let mut batcher = Batcher::new(&ds, 512, 13);
    let big = batcher.next_batch();
    let whole = engine.grad(&params, &big).unwrap();

    let mut acc = GradAccumulator::new(schema.total_vocab());
    for k in 0..8 {
        let micro = cowclip::coordinator::worker::slice_batch(&big, k * 64, (k + 1) * 64).unwrap();
        let out = engine.grad(&params, &micro).unwrap();
        acc.add(&out, 1.0 / 8.0).unwrap();
    }
    let (grads, counts, loss) = acc.finish().unwrap();
    assert!((loss - whole.loss).abs() < 1e-4);
    rel_close(&counts.to_dense(), &whole.counts.to_dense(), 0.0, 0.5, "counts");
    for (i, (a, w)) in grads.iter().zip(&whole.grads).enumerate() {
        rel_close(
            a.to_tensor().as_f32().unwrap(),
            w.to_tensor().as_f32().unwrap(),
            1e-3,
            1e-6,
            &format!("grad[{i}]"),
        );
    }
}

#[test]
fn avazu_no_dense_path_runs() {
    let Some(rt) = runtime() else { return };
    let schema = rt.manifest().schema("avazu_synth").unwrap();
    let ds = generate(&schema, &SynthConfig { n: 300, seed: 46, ..Default::default() });
    let engine = Engine::hlo(rt.clone(), ModelKind::DeepFm, "avazu_synth", ClipMode::CowClip).unwrap();
    let reference = reference_for(&rt, ModelKind::DeepFm, "avazu_synth", ClipMode::CowClip);
    let params = init_params(&engine.spec(), &InitConfig { seed: 41, embed_sigma: 0.01 });
    let mut batcher = Batcher::new(&ds, 64, 1);
    let batch = batcher.next_batch();
    let h = engine.grad(&params, &batch).unwrap();
    let r = reference.grad(&params, &batch).unwrap();
    assert!((h.loss - r.loss).abs() < 1e-4);
    rel_close(
        h.grads[0].to_tensor().as_f32().unwrap(),
        r.grads[0].to_tensor().as_f32().unwrap(),
        5e-3,
        1e-6,
        "avazu embed grad",
    );
}
