//! Fault tolerance == no fault: a distributed run that loses a rank
//! mid-step must recover **bitwise identical** to the uninterrupted
//! sequential baseline (compression off) — same loss curve, same final
//! params, same AUC. Faults are injected deterministically with the
//! `--chaos` schedule machinery (`coordinator::chaos`):
//!
//! * a rank **killed** at a step boundary rejoins (fresh incarnation,
//!   like a supervisor respawn), replays the committed prefix locally
//!   and finishes the run — for all six clip modes, and for early /
//!   final-step kill positions;
//! * a rank that **hangs** past the io deadline is marked lost, then
//!   heals through the worker's in-library reconnect loop;
//! * a **CRC-corrupt** contribution heals in place through the wire
//!   link's Nack/Resend exchange without the rank ever being lost;
//! * a corruption burst past the retry budget fails by name
//!   ("retransmit budget exhausted"), and with recovery disabled
//!   (`max_restarts = 0`) the run aborts cleanly;
//! * the `train --spawn-workers --chaos kill:...` CLI path respawns the
//!   dead child process and reports the recovery in its summary.
//!
//! Workers run on threads of the test process (byte-identical protocol
//! to the multi-process deployment); the last test forks real `cowclip`
//! processes.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use cowclip::clip::ClipMode;
use cowclip::coordinator::{
    coordinate, dist_worker, DistOptions, DistReport, Endpoint, Engine, TrainConfig, TrainReport,
    Trainer,
};
use cowclip::data::dataset::Dataset;
use cowclip::data::schema::criteo_synth;
use cowclip::data::split::random_split;
use cowclip::data::synth::{generate, SynthConfig};
use cowclip::model::ParamSet;
use cowclip::reference::ModelKind;
use cowclip::scaling::presets::criteo_preset;
use cowclip::scaling::rules::ScalingRule;
use cowclip::wire::Compression;

static SOCK_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Unique per-process socket path (tests in one binary run in parallel).
fn temp_sock(tag: &str) -> PathBuf {
    let k = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("cowclip_fp_{}_{tag}_{k}.sock", std::process::id()))
}

fn engine_for(clip: ClipMode) -> Engine {
    Engine::reference(ModelKind::DeepFm, criteo_synth(), 8, vec![32, 32], 2, clip)
}

fn cfg_for(ranks: usize, batch: usize, epochs: f64) -> TrainConfig {
    let preset = criteo_preset();
    TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs,
        workers: ranks,
        threads: 1,
        param_shards: 1,
        warmup_steps: 4,
        init_sigma: preset.init_sigma_cowclip,
        seed: 1234,
        eval_every_epochs: 0,
        verbose: false,
    }
}

fn data(n: usize) -> (Dataset, Dataset) {
    let schema = criteo_synth();
    let ds = generate(&schema, &SynthConfig { n, seed: 19, ..Default::default() });
    random_split(&ds, 0.9, 0)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// The in-process seed path: the fault-free oracle.
fn seq_run(
    clip: ClipMode,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> (TrainReport, ParamSet) {
    let mut trainer = Trainer::new(engine_for(clip), cfg.clone()).unwrap();
    let report = trainer.train(train, test).unwrap();
    let params = trainer.store.snapshot();
    (report, params)
}

/// Assert a recovered distributed run equals the sequential oracle
/// bitwise: step count, loss curve, every parameter tensor, final AUC.
fn assert_run_bitwise(
    tag: &str,
    seq_report: &TrainReport,
    seq_params: &ParamSet,
    dist_report: &DistReport,
    dist_params: &ParamSet,
) {
    assert_eq!(seq_report.steps, dist_report.steps, "{tag}: step count");
    assert_bitwise(
        &seq_report.train_loss_curve,
        &dist_report.train_loss_curve,
        &format!("{tag}: loss curve"),
    );
    for (i, (a, b)) in seq_params.tensors.iter().zip(&dist_params.tensors).enumerate() {
        assert_bitwise(
            a.as_f32().unwrap(),
            b.as_f32().unwrap(),
            &format!("{tag}: param[{i}] ({})", seq_params.spec[i].name),
        );
    }
    assert_eq!(
        seq_report.final_auc.to_bits(),
        dist_report.final_auc.to_bits(),
        "{tag}: AUC {} vs {}",
        seq_report.final_auc,
        dist_report.final_auc
    );
}

/// One socket run with a chaos schedule armed on `faulty_rank`'s worker.
/// The faulty worker's thread plays the part of a process supervisor:
/// when `expect_kill` is set, its first incarnation must die to the
/// injected kill, and the thread "respawns" it by calling `dist_worker`
/// again with the schedule stripped — exactly what the CLI supervisor
/// does with a real child process. Non-kill faults heal inside the one
/// `dist_worker` call (retransmit or reconnect), so no respawn happens.
fn chaos_run(
    clip: ClipMode,
    cfg: &TrainConfig,
    opts: &DistOptions,
    chaos: &str,
    faulty_rank: usize,
    expect_kill: bool,
    train: &Dataset,
    test: &Dataset,
) -> (DistReport, ParamSet) {
    let ranks = cfg.workers;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..ranks)
            .map(|rank| {
                let mut w_opts = opts.clone();
                if rank == faulty_rank {
                    w_opts.chaos = Some(chaos.parse().expect("chaos spec"));
                }
                s.spawn(move || {
                    let engine = engine_for(clip);
                    let first = dist_worker(&engine, cfg, train, rank, &w_opts);
                    if !(rank == faulty_rank && expect_kill) {
                        return first;
                    }
                    let err = first.expect_err("chaos kill must abort the first incarnation");
                    let msg = format!("{err:#}");
                    assert!(msg.contains("chaos: kill"), "expected a chaos kill, got: {msg}");
                    // Respawn: fresh state, no schedule (one-shot fault).
                    let mut clean = w_opts.clone();
                    clean.chaos = None;
                    dist_worker(&engine, cfg, train, rank, &clean)
                })
            })
            .collect();
        let engine = engine_for(clip);
        let (report, store) = coordinate(&engine, cfg, train, test, opts).unwrap();
        for (rank, h) in handles.into_iter().enumerate() {
            h.join()
                .unwrap()
                .unwrap_or_else(|e| panic!("rank {rank} failed: {e:#}"));
        }
        (report, store.snapshot())
    })
}

/// Acceptance (recovery determinism): a 2-rank run whose rank 1 is
/// killed mid-run recovers bitwise identical to the sequential baseline
/// for **all six clip modes** with compression off, and the recovery is
/// visible in the stats (one rank loss, one rejoin, the interrupted
/// step recovered).
#[test]
fn killed_rank_recovers_bitwise_all_modes() {
    let (train, test) = data(1_500);
    for clip in ClipMode::ALL {
        let cfg = cfg_for(2, 128, 1.0);
        let (seq_report, seq_params) = seq_run(clip, &cfg, &train, &test);
        let sock = temp_sock("kill");
        let opts = DistOptions::new(
            2,
            Endpoint::Unix(sock.clone()),
            Compression::None,
            Duration::from_secs(60),
        );
        let (report, params) =
            chaos_run(clip, &cfg, &opts, "kill:rank=1,step=5", 1, true, &train, &test);
        let tag = format!("{clip}/kill@5");
        assert_eq!(report.stats.dead_ranks, 1, "{tag}: rank losses");
        assert_eq!(report.stats.reconnects, 1, "{tag}: rejoins");
        assert!(report.stats.recovered_steps >= 1, "{tag}: recovered steps");
        assert_run_bitwise(&tag, &seq_report, &seq_params, &report, &params);
        let _ = std::fs::remove_file(&sock);
    }
}

/// Acceptance (kill position): recovery is step-position independent —
/// a kill right after warmup and a kill at the *final* step (where the
/// rejoining rank replays the whole committed run locally and only then
/// contributes) both recover bitwise.
#[test]
fn kill_position_early_and_final_step_recover_bitwise() {
    let (train, test) = data(1_500);
    let clip = ClipMode::CowClip;
    let cfg = cfg_for(2, 128, 1.0);
    let total_steps = ((train.n() / cfg.batch) as f64 * cfg.epochs).round() as u64;
    assert!(total_steps >= 4, "need a few steps to place kills");
    let (seq_report, seq_params) = seq_run(clip, &cfg, &train, &test);
    for kill_step in [2, total_steps] {
        let sock = temp_sock("killpos");
        let opts = DistOptions::new(
            2,
            Endpoint::Unix(sock.clone()),
            Compression::None,
            Duration::from_secs(60),
        );
        let spec = format!("kill:rank=1,step={kill_step}");
        let (report, params) = chaos_run(clip, &cfg, &opts, &spec, 1, true, &train, &test);
        let tag = format!("{clip}/kill@{kill_step}");
        assert_eq!(report.stats.dead_ranks, 1, "{tag}: rank losses");
        assert_run_bitwise(&tag, &seq_report, &seq_params, &report, &params);
        let _ = std::fs::remove_file(&sock);
    }
}

/// Acceptance (hang → reconnect): a rank stalled past the io deadline
/// is marked lost; the same worker process notices its dead session,
/// reconnects through the in-library retry loop within the recovery
/// window, and the run still finishes bitwise identical.
#[test]
fn hung_rank_reconnects_and_recovers_bitwise() {
    let (train, test) = data(1_500);
    let clip = ClipMode::CowClip;
    let cfg = cfg_for(2, 128, 1.0);
    let (seq_report, seq_params) = seq_run(clip, &cfg, &train, &test);
    let sock = temp_sock("hang");
    // Deadline 800 ms, stall 1200 ms: the coordinator gives up at
    // ~800 ms and opens a 3x recovery window (2.4 s); the worker wakes
    // at 1.2 s, its own read times out by ~2 s, and it reconnects with
    // >1 s of window to spare.
    let opts = DistOptions::new(
        2,
        Endpoint::Unix(sock.clone()),
        Compression::None,
        Duration::from_millis(800),
    );
    let (report, params) =
        chaos_run(clip, &cfg, &opts, "hang:rank=1,step=3,ms=1200", 1, false, &train, &test);
    let tag = format!("{clip}/hang@3");
    assert_eq!(report.stats.dead_ranks, 1, "{tag}: rank losses");
    assert_eq!(report.stats.reconnects, 1, "{tag}: rejoins");
    assert!(report.stats.recovered_steps >= 1, "{tag}: recovered steps");
    assert_run_bitwise(&tag, &seq_report, &seq_params, &report, &params);
    let _ = std::fs::remove_file(&sock);
}

/// Acceptance (transport healing): one CRC-corrupt contribution heals
/// in place via the Nack/Resend exchange — the retransmit shows up in
/// the stats, no rank is ever lost, and the run is bitwise clean.
#[test]
fn corrupt_contrib_heals_within_budget_bitwise() {
    let (train, test) = data(1_500);
    let clip = ClipMode::CowClip;
    let cfg = cfg_for(2, 128, 1.0);
    let (seq_report, seq_params) = seq_run(clip, &cfg, &train, &test);
    let sock = temp_sock("corrupt");
    let opts = DistOptions::new(
        2,
        Endpoint::Unix(sock.clone()),
        Compression::None,
        Duration::from_secs(60),
    );
    let (report, params) =
        chaos_run(clip, &cfg, &opts, "corrupt:rank=1,step=2", 1, false, &train, &test);
    let tag = format!("{clip}/corrupt@2");
    assert!(report.stats.retransmits >= 1, "{tag}: healed retransmits");
    assert_eq!(report.stats.dead_ranks, 0, "{tag}: corruption must heal without a loss");
    assert_eq!(report.stats.reconnects, 0, "{tag}: no reconnect needed");
    assert_run_bitwise(&tag, &seq_report, &seq_params, &report, &params);
    let _ = std::fs::remove_file(&sock);
}

/// Acceptance (bounded retries): a corruption burst outlasting the
/// retransmit budget fails by name, and with recovery disabled
/// (`max_restarts = 0`) the coordinator aborts instead of waiting for a
/// rejoin — the worker is told why via the error fan-out.
#[test]
fn retransmit_budget_exhaustion_fails_by_name() {
    let (train, test) = data(1_500);
    let clip = ClipMode::CowClip;
    let cfg = cfg_for(1, 128, 1.0);
    let sock = temp_sock("budget");
    let mut opts = DistOptions::new(
        1,
        Endpoint::Unix(sock.clone()),
        Compression::None,
        Duration::from_secs(60),
    );
    opts.retransmit_budget = 2;
    opts.max_restarts = 0;
    let err = std::thread::scope(|s| {
        let (cfg, opts, train) = (&cfg, &opts, &train);
        let worker = s.spawn(move || {
            let mut w_opts = opts.clone();
            // Corrupt every frame flushed at step 2 — including the
            // retransmissions — so the budget cannot win.
            w_opts.chaos = Some("corrupt:rank=0,step=2,times=10".parse().unwrap());
            let engine = engine_for(clip);
            dist_worker(&engine, cfg, train, 0, &w_opts)
        });
        let engine = engine_for(clip);
        let err = coordinate(&engine, cfg, train, &test, opts).unwrap_err();
        assert!(worker.join().unwrap().is_err(), "worker must be told the run died");
        err
    });
    let msg = format!("{err:#}");
    assert!(
        msg.contains("retransmit budget exhausted"),
        "error should name the exhausted budget: {msg}"
    );
    let _ = std::fs::remove_file(&sock);
}

/// Acceptance (CLI): `train --spawn-workers --chaos kill:...` forks
/// real worker processes, the killed child exits nonzero, the
/// supervisor respawns it (chaos stripped), and the run completes with
/// the recovery reported in the summary.
#[test]
fn cli_spawn_workers_respawns_killed_child() {
    let sock = temp_sock("cli");
    let ckpt = std::env::temp_dir()
        .join(format!("cowclip_fp_cli_{}.ckpt", std::process::id()));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cowclip"))
        .args([
            "train",
            "--model",
            "deepfm",
            "--schema",
            "criteo_synth",
            "--n",
            "2000",
            "--batch",
            "128",
            "--epochs",
            "0.5",
            "--threads",
            "1",
            "--engine",
            "reference",
            "--ranks",
            "2",
            "--spawn-workers",
            "--compress",
            "none",
            "--deadline-ms",
            "60000",
            "--chaos",
            "kill:rank=1,step=3",
            "--max-restarts",
            "2",
            "--snapshot-every",
            "2",
            "--save",
        ])
        .arg(&ckpt)
        .arg("--bind")
        .arg(format!("unix:{}", sock.display()))
        .output()
        .expect("running the cowclip binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "cli run failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("final test AUC"), "missing result line:\n{stdout}");
    assert!(stdout.contains("recovery:"), "missing recovery summary:\n{stdout}");
    assert!(ckpt.exists(), "snapshot/checkpoint file missing");
    let _ = std::fs::remove_file(&sock);
    let _ = std::fs::remove_file(&ckpt);
}
