//! SIMD kernel tier parity: every kernel the runtime dispatcher can
//! hand out must agree with the `linalg::naive` scalar oracles —
//! bitwise for the copy-class kernels (`colsum`, `embed_concat_fwd`,
//! `dequant_row`, `relu_mask`), ≤1e-6 relative for the FMA-contracted
//! ones — on odd shapes, remainder lanes and misaligned lengths; and
//! all four model architectures must score/train the same under the
//! scalar and the widest native tier.
//!
//! On a host without AVX2/NEON every `resolve()` call degrades to the
//! scalar vtable, so these tests pass trivially there — the CI matrix
//! also runs the concurrency parity suites under `COWCLIP_KERNEL=scalar`
//! to pin the cross-mode story from the environment side.

use cowclip::data::batcher::Batch;
use cowclip::data::schema::Schema;
use cowclip::model::init::{init_params, InitConfig};
use cowclip::reference::simd::{resolve, scalar, KernelMode};
use cowclip::reference::step::build_spec;
use cowclip::reference::{layers, linalg, ModelKind, ReferenceModel, Scratch};
use cowclip::tensor::Tensor;
use cowclip::util::Rng;

/// Relative gate for kernels whose SIMD form contracts `a*b + c` into
/// one rounding (matmul family, dot, axpy, rowdot).
fn close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = 1e-6f32 * x.abs().max(y.abs()).max(1.0);
        assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
    }
}

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.next_gaussian() as f32).collect()
}

/// Shapes chosen to hit every remainder path of the 4×8 (AVX2) and
/// 4×4 (NEON) tiles: full tiles, column tails, row tails, sub-tile
/// matrices, degenerate dims and the empty batch.
const SHAPES: [(usize, usize, usize); 10] = [
    (0, 4, 8),
    (1, 1, 1),
    (2, 3, 5),
    (4, 8, 8),
    (5, 7, 9),
    (7, 5, 8),
    (3, 17, 33),
    (8, 16, 24),
    (13, 31, 40),
    (6, 64, 65),
];

/// The tiers worth racing on this host: scalar always, plus whatever
/// each explicit mode resolves to (deduplicated by vtable identity so
/// the test body stays meaningful off-x86/off-arm).
fn tiers() -> Vec<&'static cowclip::reference::Kernels> {
    let mut out = vec![scalar()];
    for mode in [KernelMode::Avx2, KernelMode::Neon, KernelMode::Auto] {
        let k = resolve(mode);
        if !out.iter().any(|have| std::ptr::eq(*have, k)) {
            out.push(k);
        }
    }
    out
}

#[test]
fn matmul_family_matches_naive_on_odd_shapes() {
    for k in tiers() {
        for (si, &(b, m, n)) in SHAPES.iter().enumerate() {
            let seed = 100 + si as u64;
            let x = gaussian(b * m, seed);
            let w = gaussian(m * n, seed + 1);
            let g = gaussian(b * n, seed + 2);
            let tag = |op: &str| format!("{}[{op} b={b} m={m} n={n}]", k.name);

            let mut y = vec![f32::NAN; b * n];
            (k.matmul_into)(&x, &w, &mut y, b, m, n);
            close(&y, &linalg::naive::matmul(&x, &w, b, m, n), &tag("matmul"));

            let mut dx = vec![f32::NAN; b * m];
            (k.matmul_nt_into)(&g, &w, &mut dx, b, m, n);
            close(&dx, &linalg::naive::matmul_nt(&g, &w, b, m, n), &tag("matmul_nt"));

            let mut dw = vec![f32::NAN; m * n];
            (k.matmul_tn_into)(&x, &g, &mut dw, b, m, n);
            close(&dw, &linalg::naive::matmul_tn(&x, &g, b, m, n), &tag("matmul_tn"));

            // colsum is in the bitwise class: pure lane adds in the
            // scalar i-ascending order, no FMA anywhere.
            let mut db = vec![f32::NAN; n];
            (k.colsum_into)(&g, &mut db, b, n);
            assert_eq!(db, linalg::naive::colsum(&g, b, n), "{}", tag("colsum"));

            let c = gaussian(b * n, seed + 3);
            let mut rd = vec![f32::NAN; b];
            (k.rowdot_into)(&g, &c, &mut rd, b, n);
            close(&rd, &linalg::naive::rowdot(&g, &c, b, n), &tag("rowdot"));
        }
    }
}

#[test]
fn dot_axpy_match_sequential_oracle_on_misaligned_lengths() {
    // every lane-remainder case of the 8-wide and 4-wide kernels
    for k in tiers() {
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100] {
            let a = gaussian(len, 7 + len as u64);
            let b = gaussian(len, 9 + len as u64);
            let seq: f32 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
            close(&[(k.dot)(&a, &b)], &[seq], &format!("{}[dot len={len}]", k.name));

            let mut y = gaussian(len, 11 + len as u64);
            let want: Vec<f32> = y.iter().zip(&a).map(|(&yv, &xv)| yv + 0.37 * xv).collect();
            (k.axpy)(&mut y, &a, 0.37);
            close(&y, &want, &format!("{}[axpy len={len}]", k.name));
        }
    }
}

#[test]
fn copy_class_kernels_are_bitwise_in_every_tier() {
    for k in tiers() {
        // dequant_row: explicit mul-then-add, including the remainder
        // lanes and the full u16 range
        for len in [0usize, 1, 3, 7, 8, 9, 16, 17, 33] {
            let mut rng = Rng::new(40 + len as u64);
            let codes: Vec<u16> = (0..len).map(|_| rng.below(65536) as u16).collect();
            let (min, step) = (-0.73f32, 1.9e-4f32);
            let mut out = vec![f32::NAN; len];
            (k.dequant_row)(&codes, min, step, &mut out);
            let want: Vec<f32> = codes.iter().map(|&c| min + c as f32 * step).collect();
            assert_eq!(out, want, "{}[dequant len={len}]", k.name);
        }

        // relu_mask: ordered compare — negatives and -0.0 zero the
        // gradient, positives and NaN pre-activations keep it
        let pre = [1.0f32, -1.0, 0.0, -0.0, f32::NAN, 0.5, -3.0, 2.0, 1e-9, -1e-9, 7.0];
        for len in [0usize, 1, 3, 8, 11] {
            let mut dy: Vec<f32> = (0..len).map(|i| i as f32 + 1.0).collect();
            let mut want = dy.clone();
            for (gv, &p) in want.iter_mut().zip(&pre[..len]) {
                if p <= 0.0 {
                    *gv = 0.0;
                }
            }
            (k.relu_mask)(&mut dy, &pre[..len]);
            assert_eq!(dy, want, "{}[relu_mask len={len}]", k.name);
        }

        // embed_concat_fwd: pure gather+copy — compare against the
        // scalar fused pass on a rows-with-tails layout
        let (b, f, d, nd) = (5usize, 3usize, 6usize, 2usize);
        let vocab = 11usize;
        let table = gaussian(vocab * d, 77);
        let dense = gaussian(b * nd, 78);
        let mut rng = Rng::new(79);
        let ids: Vec<i32> = (0..b * f).map(|_| rng.below(vocab as u64) as i32).collect();
        let d0 = f * d + nd;
        let mut got = vec![f32::NAN; b * d0];
        let mut want = vec![f32::NAN; b * d0];
        (k.embed_concat_fwd)(&table, &ids, &dense, b, f, d, nd, &mut got);
        layers::embed_concat_fwd(&table, &ids, &dense, b, f, d, nd, &mut want);
        assert_eq!(got, want, "{}[embed_concat_fwd]", k.name);
    }
}

#[test]
fn within_mode_repeat_is_bitwise() {
    // the determinism tier-1 claim: a fixed vtable replays the identical
    // instruction stream, so repeated calls cannot differ in one bit
    for k in tiers() {
        let (b, m, n) = (9usize, 33usize, 17usize);
        let x = gaussian(b * m, 5);
        let w = gaussian(m * n, 6);
        let mut y0 = vec![0.0f32; b * n];
        let mut y1 = vec![f32::NAN; b * n];
        (k.matmul_into)(&x, &w, &mut y0, b, m, n);
        (k.matmul_into)(&x, &w, &mut y1, b, m, n);
        assert_eq!(y0, y1, "{}: repeated matmul drifted", k.name);
        assert_eq!((k.dot)(&x, &x).to_bits(), (k.dot)(&x, &x).to_bits(), "{}: dot", k.name);
    }
}

#[test]
fn unsupported_modes_fall_back_to_scalar_not_ub() {
    // requesting the other architecture's tier must degrade cleanly
    #[cfg(not(target_arch = "x86_64"))]
    assert!(std::ptr::eq(resolve(KernelMode::Avx2), scalar()));
    #[cfg(not(target_arch = "aarch64"))]
    assert!(std::ptr::eq(resolve(KernelMode::Neon), scalar()));
    assert!(std::ptr::eq(resolve(KernelMode::Scalar), scalar()));
    // and resolution is a pure function of (mode, host)
    assert!(std::ptr::eq(resolve(KernelMode::Auto), resolve(KernelMode::Auto)));
}

fn tiny_schema() -> Schema {
    Schema { name: "kernel_parity".into(), n_dense: 3, vocab_sizes: vec![7, 5, 3] }
}

fn tiny_batch(schema: &Schema, b: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let offs = schema.offsets();
    let mut x_cat = Vec::new();
    for _ in 0..b {
        for (f, &vs) in schema.vocab_sizes.iter().enumerate() {
            x_cat.push((offs[f] + rng.below(vs as u64) as usize) as i32);
        }
    }
    let x_dense: Vec<f32> = (0..b * schema.n_dense).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<f32> = (0..b).map(|_| rng.bernoulli(0.4) as u8 as f32).collect();
    Batch::new(
        Tensor::i32(vec![b, schema.n_cat()], x_cat),
        Tensor::f32(vec![b, schema.n_dense], x_dense),
        Tensor::f32(vec![b], y),
        b,
    )
}

/// All four architectures, forward + backward + infer, scalar tier vs
/// the widest tier the host runs — the end-to-end cross-mode gate.
#[test]
fn all_models_agree_across_kernel_tiers() {
    let auto = resolve(KernelMode::Auto);
    for kind in ModelKind::ALL {
        let schema = tiny_schema();
        let scalar_model = ReferenceModel::new(kind, schema.clone(), 4, vec![8, 8], 2)
            .with_kernels(scalar());
        let simd_model = ReferenceModel::new(kind, schema.clone(), 4, vec![8, 8], 2)
            .with_kernels(auto);
        let spec = build_spec(kind, &schema, 4, &[8, 8], 2);
        let params = init_params(&spec, &InitConfig { seed: 21, embed_sigma: 0.05 });
        // batch of 13: row tails in the 4-row matmul tiles every layer
        let batch = tiny_batch(&schema, 13, 22);

        let want = scalar_model.forward(&params, &batch).unwrap();
        let got = simd_model.forward(&params, &batch).unwrap();
        close(&got, &want, &format!("{kind}: forward ({})", auto.name));

        let (loss_s, grads_s, counts_s) = scalar_model.grad(&params, &batch).unwrap();
        let (loss_v, grads_v, counts_v) = simd_model.grad(&params, &batch).unwrap();
        close(&[loss_v], &[loss_s], &format!("{kind}: loss"));
        assert_eq!(counts_v, counts_s, "{kind}: touched-row counts");
        assert_eq!(grads_v.len(), grads_s.len());
        for (gi, (gv, gs)) in grads_v.iter().zip(&grads_s).enumerate() {
            close(
                gv.to_tensor().as_f32().unwrap(),
                gs.to_tensor().as_f32().unwrap(),
                &format!("{kind}: grad[{gi}]"),
            );
        }

        // infer path: same x0 (embed_concat is bitwise in every tier),
        // cross-mode logits within the FMA gate
        let b = batch.batch_size();
        let f = schema.n_cat();
        let (d, nd, d0) = (4usize, schema.n_dense, scalar_model.d0());
        let ids = batch.x_cat.as_i32().unwrap();
        let dense = batch.x_dense.as_f32().unwrap();
        let mut table: Option<&[f32]> = None;
        let mut wide: Option<&[f32]> = None;
        let mut dense_params: Vec<Tensor> = Vec::new();
        for (e, t) in spec.iter().zip(&params.tensors) {
            match e.group.as_str() {
                "embed" => table = Some(t.as_f32().unwrap()),
                "wide" => wide = Some(t.as_f32().unwrap()),
                _ => dense_params.push(t.clone()),
            }
        }
        let mut x0 = vec![0.0f32; b * d0];
        layers::embed_concat_fwd(table.unwrap(), ids, dense, b, f, d, nd, &mut x0);
        let wide_sums: Option<Vec<f32>> = wide.map(|wt| {
            (0..b)
                .map(|i| ids[i * f..(i + 1) * f].iter().map(|&id| wt[id as usize]).sum())
                .collect()
        });
        let mut scratch = Scratch::new();
        let want = scalar_model
            .infer_x0(&dense_params, &x0, wide_sums.as_deref(), b, &mut scratch)
            .unwrap();
        let got = simd_model
            .infer_x0(&dense_params, &x0, wide_sums.as_deref(), b, &mut scratch)
            .unwrap();
        close(&got, &want, &format!("{kind}: infer_x0"));
    }
}
