//! 16-bit affine quantization of vocab-shaped tables for serving.
//!
//! The embedding table dominates a CTR model's memory (V·d f32 scalars;
//! the dense MLP/cross weights are tiny next to it), and a serving
//! replica never updates it — so a frozen model can store each scalar as
//! a `u16` code plus **per-field** affine constants and halve resident
//! table memory. Per-field (not per-table) constants matter because
//! CowClip-trained fields have wildly different weight scales (the
//! per-field norms are the whole point of the clipping algorithm);
//! sharing one scale across the table would crush the small fields'
//! resolution.
//!
//! # Scheme and error bound
//!
//! For field `f` with weight range `[min_f, max_f]` over all of its rows:
//!
//! ```text
//! step_f  = (max_f − min_f) / 65535
//! code(x) = round((x − min_f) / step_f)          (clamped to [0, 65535])
//! deq(c)  = min_f + c · step_f                   (f32 arithmetic)
//! ```
//!
//! The rounding error is at most `step_f / 2`; evaluating `deq` in f32
//! adds at most a few ulps of `max(|min_f|, |max_f|, range_f)`. The
//! **documented per-field bound** returned by
//! [`QuantizedTable::error_bound`] is
//!
//! ```text
//! |x − deq(code(x))| ≤ step_f / 2 + 2⁻²⁰ · (range_f + absmax_f)
//! ```
//!
//! and the round-trip test in `rust/tests/serve_parity.rs` asserts it
//! for every table of a trained model. A degenerate field (all weights
//! equal, `step_f = 0`) round-trips exactly.

use anyhow::{ensure, Result};

/// Slop factor covering f32 evaluation of `min + code·step` (a few ulps
/// of the field's magnitude — see the module docs for the full bound).
const F32_EVAL_SLOP: f32 = 1.0 / (1u32 << 20) as f32;

/// A `[rows, d]` table stored as `u16` codes + per-field affine
/// constants. Rows are grouped into fields by `(global_offset, vocab)`
/// spans exactly like the training-side `Schema::fields` iterator, so a
/// gather always knows its field index statically (column `j` of a CTR
/// request is field `j`) and pays no lookup.
#[derive(Clone, Debug)]
pub struct QuantizedTable {
    rows: usize,
    d: usize,
    codes: Vec<u16>,
    /// `(global_offset, vocab)` per field — the quantization groups.
    fields: Vec<(usize, usize)>,
    field_min: Vec<f32>,
    field_step: Vec<f32>,
}

impl QuantizedTable {
    /// Quantize a packed `[rows, d]` f32 table. `fields` must be the
    /// schema's `(offset, vocab)` spans, contiguous and covering `rows`.
    pub fn quantize(w: &[f32], d: usize, fields: &[(usize, usize)]) -> Result<QuantizedTable> {
        ensure!(d >= 1, "table width must be >= 1");
        ensure!(!fields.is_empty(), "need at least one field");
        let rows = w.len() / d;
        ensure!(rows * d == w.len(), "table length {} not a multiple of d={d}", w.len());
        let mut expect = 0usize;
        for &(off, vs) in fields {
            ensure!(off == expect, "fields must be contiguous (offset {off} vs {expect})");
            expect = off + vs;
        }
        ensure!(expect == rows, "fields cover {expect} rows, table has {rows}");

        let mut codes = vec![0u16; w.len()];
        let mut field_min = Vec::with_capacity(fields.len());
        let mut field_step = Vec::with_capacity(fields.len());
        for &(off, vs) in fields {
            let span = &w[off * d..(off + vs) * d];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in span {
                ensure!(x.is_finite(), "non-finite weight in quantized table");
                lo = lo.min(x);
                hi = hi.max(x);
            }
            if span.is_empty() {
                lo = 0.0;
                hi = 0.0;
            }
            let step = ((hi as f64 - lo as f64) / 65535.0) as f32;
            for (c, &x) in codes[off * d..(off + vs) * d].iter_mut().zip(span) {
                *c = if step == 0.0 {
                    0
                } else {
                    let q = ((x as f64 - lo as f64) / step as f64).round();
                    q.clamp(0.0, 65535.0) as u16
                };
            }
            field_min.push(lo);
            field_step.push(step);
        }
        Ok(QuantizedTable { rows, d, codes, fields: fields.to_vec(), field_min, field_step })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Dequantize row `id` (a global id belonging to field `field`)
    /// into `out` (length `d`).
    #[inline]
    pub fn row_into(&self, id: usize, field: usize, out: &mut [f32]) {
        let min = self.field_min[field];
        let step = self.field_step[field];
        let src = &self.codes[id * self.d..(id + 1) * self.d];
        for (o, &c) in out.iter_mut().zip(src) {
            *o = min + c as f32 * step;
        }
    }

    /// Raw `u16` codes of row `id` — the input to the SIMD
    /// `dequant_row` kernel (see `reference::simd`).
    #[inline]
    pub fn row_codes(&self, id: usize) -> &[u16] {
        &self.codes[id * self.d..(id + 1) * self.d]
    }

    /// The `(min, step)` affine constants of `field`.
    #[inline]
    pub fn affine(&self, field: usize) -> (f32, f32) {
        (self.field_min[field], self.field_step[field])
    }

    /// Dequantize the single scalar of a `d == 1` row (the wide table).
    #[inline]
    pub fn value(&self, id: usize, field: usize) -> f32 {
        debug_assert_eq!(self.d, 1);
        self.field_min[field] + self.codes[id] as f32 * self.field_step[field]
    }

    /// Dequantize the whole table back to packed f32 — the offline
    /// oracle the serving parity test scores against.
    pub fn dequantize_all(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.d];
        for (fi, &(off, vs)) in self.fields.iter().enumerate() {
            let min = self.field_min[fi];
            let step = self.field_step[fi];
            for (o, &c) in out[off * self.d..(off + vs) * self.d]
                .iter_mut()
                .zip(&self.codes[off * self.d..(off + vs) * self.d])
            {
                *o = min + c as f32 * step;
            }
        }
        out
    }

    /// The documented per-field round-trip bound (module docs):
    /// `step/2 + 2⁻²⁰·(range + absmax)`.
    pub fn error_bound(&self, field: usize) -> f32 {
        let min = self.field_min[field];
        let step = self.field_step[field];
        let range = step * 65535.0;
        let absmax = min.abs().max((min + range).abs());
        step * 0.5 + F32_EVAL_SLOP * (range + absmax)
    }

    /// Largest per-field bound — the table-level guarantee.
    pub fn max_error_bound(&self) -> f32 {
        (0..self.fields.len()).map(|f| self.error_bound(f)).fold(0.0, f32::max)
    }

    /// Resident bytes of the quantized representation.
    pub fn bytes(&self) -> usize {
        self.codes.len() * 2 + self.fields.len() * (2 * 4 + 2 * 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn fields() -> Vec<(usize, usize)> {
        vec![(0, 6), (6, 3), (9, 1)]
    }

    #[test]
    fn roundtrip_within_documented_bound() {
        let d = 4;
        let mut rng = Rng::new(3);
        // fields with very different scales, like CowClip-trained tables
        let mut w = vec![0.0f32; 10 * d];
        for (fi, (off, vs)) in fields().into_iter().enumerate() {
            let scale = [1.0f32, 1e-3, 10.0][fi];
            for x in &mut w[off * d..(off + vs) * d] {
                *x = rng.next_gaussian() as f32 * scale;
            }
        }
        let q = QuantizedTable::quantize(&w, d, &fields()).unwrap();
        let back = q.dequantize_all();
        for (fi, (off, vs)) in fields().into_iter().enumerate() {
            let bound = q.error_bound(fi);
            for i in off * d..(off + vs) * d {
                let err = (w[i] - back[i]).abs();
                assert!(err <= bound, "field {fi} idx {i}: err {err} > bound {bound}");
            }
        }
        // per-field constants keep the small field's resolution fine:
        // the 1e-3-scale field's bound is far below the 1.0-scale one
        assert!(q.error_bound(1) < q.error_bound(0) / 10.0);
    }

    #[test]
    fn row_gather_matches_dequantize_all() {
        let d = 3;
        let w: Vec<f32> = (0..30).map(|i| (i as f32) * 0.01 - 0.15).collect();
        let q = QuantizedTable::quantize(&w, d, &fields()).unwrap();
        let all = q.dequantize_all();
        let mut row = vec![0.0f32; d];
        for (fi, (off, vs)) in fields().into_iter().enumerate() {
            for id in off..off + vs {
                q.row_into(id, fi, &mut row);
                assert_eq!(&row[..], &all[id * d..(id + 1) * d]);
            }
        }
    }

    #[test]
    fn wide_scalar_gather() {
        let w: Vec<f32> = (0..10).map(|i| (i as f32) * 0.5).collect();
        let q = QuantizedTable::quantize(&w, 1, &fields()).unwrap();
        let all = q.dequantize_all();
        for (fi, (off, vs)) in fields().into_iter().enumerate() {
            for id in off..off + vs {
                assert_eq!(q.value(id, fi), all[id]);
            }
        }
    }

    #[test]
    fn constant_field_roundtrips_exactly() {
        let w = vec![0.125f32; 10];
        let q = QuantizedTable::quantize(&w, 1, &fields()).unwrap();
        assert_eq!(q.dequantize_all(), w);
        assert_eq!(q.error_bound(0), 0.0 + F32_EVAL_SLOP * 0.125);
    }

    #[test]
    fn memory_is_roughly_halved() {
        let d = 8;
        let big_fields = vec![(0usize, 600usize), (600, 300), (900, 100)];
        let w = vec![0.5f32; 1000 * d];
        let q = QuantizedTable::quantize(&w, d, &big_fields).unwrap();
        // u16 codes + per-field constants: just over half the f32 bytes
        assert!(q.bytes() < w.len() * 4 * 6 / 10, "{} vs {}", q.bytes(), w.len() * 4);
        assert!(q.bytes() >= w.len() * 2);
    }

    #[test]
    fn bad_field_layout_rejected() {
        let w = vec![0.0f32; 10];
        assert!(QuantizedTable::quantize(&w, 1, &[(0, 4), (5, 5)]).is_err()); // gap
        assert!(QuantizedTable::quantize(&w, 1, &[(0, 4)]).is_err()); // short
        assert!(QuantizedTable::quantize(&w, 3, &fields()).is_err()); // len % d
    }
}
