//! Online inference: checkpoint-frozen scoring behind a micro-batching
//! request queue, with optionally quantized embedding tables.
//!
//! Training fast only matters if the freshly trained model reaches
//! traffic — this module is everything downstream of
//! `Trainer::evaluate`: the production-shaped serving tier that closes
//! the train → serve loop of the CowClip reproduction.
//!
//! # Request lifecycle
//!
//! **enqueue → coalesce → score → respond.** A [`Client`] validates each
//! single-impression [`Request`] and pushes it onto the shared queue; a
//! scoring thread drains a micro-batch when the queue reaches
//! [`ServeConfig::max_batch`] *or* the oldest request has waited
//! [`ServeConfig::max_delay`] (so a lone request is never stranded);
//! [`ServeConfig::max_queue`] caps admission, shedding overload with
//! the typed [`Overloaded`] error at submit time;
//! the batch runs one inference-only forward through the immutable
//! `Arc<`[`ServeModel`]`>`; each request's logit and calibrated
//! probability return over its reply channel. Per-request latency lands
//! in a [`crate::metrics::LatencyHistogram`] (p50/p90/p99 + mean) and
//! [`ServeStats`] reports QPS and batch-coalescing stats at shutdown.
//! See [`queue`] for the batching-policy details.
//!
//! # Freshness story
//!
//! The trainer's checkpoint *is* the deployment artifact:
//!
//! ```text
//! cowclip train --save model.ckpt        # CCKS: params + moments + step
//! cowclip inspect model.ckpt             # sanity-check before rollout
//! cowclip serve --ckpt model.ckpt ...    # frozen scoring replica
//! ```
//!
//! [`ServeModel::load`] accepts the full `CCKS` training checkpoint
//! (optimizer state is ignored — serving needs only weights) or a bare
//! `CCKP` params file, so every checkpoint a run ever saved can be
//! served, and a retrain → re-serve cycle is two commands.
//!
//! # Quantization
//!
//! With `--quant` the embedding and wide tables store u16 codes plus
//! per-field affine constants ([`QuantizedTable`]), roughly halving
//! serving memory. Scoring dequantizes rows during the gather (each
//! request column's field is known statically, so no lookups); the
//! served scores equal the reference forward over the dequantized
//! tables exactly, and each dequantized weight sits within the
//! documented per-field bound of the trained one — see [`quant`] for
//! the formula and `rust/tests/serve_parity.rs` for the gate.
//!
//! # Quickstart (library)
//!
//! ```no_run
//! use std::sync::Arc;
//! use cowclip::data::schema::criteo_synth;
//! use cowclip::reference::{ModelKind, ReferenceModel};
//! use cowclip::serve::{Request, ServeConfig, ServeModel, Server};
//!
//! # fn main() -> anyhow::Result<()> {
//! let model = ReferenceModel::new(
//!     ModelKind::DeepFm, criteo_synth(), 10, vec![128, 128, 128], 3);
//! let frozen = Arc::new(ServeModel::load(
//!     std::path::Path::new("model.ckpt"), model, /*quant=*/ true)?);
//! let server = Server::start(frozen, ServeConfig::default());
//! let client = server.client();
//! let scored = client.score(Request {
//!     id: 0,
//!     cat: vec![0; 26],          // global ids, one per field
//!     dense: vec![0.0; 13],
//! })?;
//! println!("p(click) = {:.4}", scored.prob);
//! let stats = server.shutdown()?;
//! println!("{} requests at {:.0} QPS", stats.requests, stats.qps());
//! # Ok(())
//! # }
//! ```

pub mod model;
pub mod quant;
pub mod queue;
pub mod request;

pub use model::ServeModel;
pub use quant::QuantizedTable;
pub use queue::{score_all, Client, Overloaded, ServeConfig, ServeStats, Server};
pub use request::{read_requests_tsv, Request, Scored};
