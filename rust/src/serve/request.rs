//! Scoring requests and responses, plus the TSV request reader.
//!
//! A request is one impression: global categorical ids per
//! `data::schema` (column `j` is field `j`, id already offset into the
//! concatenated vocabulary) plus the dense features. Responses carry
//! the logit and the calibrated click probability.

use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::data::schema::Schema;

/// One scoring request (a single impression).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: u64,
    /// `[n_cat]` global categorical ids (column `j` belongs to field `j`).
    pub cat: Vec<i32>,
    /// `[n_dense]` dense features.
    pub dense: Vec<f32>,
}

impl Request {
    /// Check arity and per-field id ranges against a schema.
    pub fn validate(&self, schema: &Schema) -> Result<()> {
        ensure!(
            self.cat.len() == schema.n_cat(),
            "request {}: {} categorical ids, schema wants {}",
            self.id,
            self.cat.len(),
            schema.n_cat()
        );
        ensure!(
            self.dense.len() == schema.n_dense,
            "request {}: {} dense features, schema wants {}",
            self.id,
            self.dense.len(),
            schema.n_dense
        );
        for ((off, vs), &id) in schema.fields().zip(&self.cat) {
            let (lo, hi) = (off as i64, (off + vs) as i64);
            ensure!(
                (id as i64) >= lo && (id as i64) < hi,
                "request {}: id {id} outside field range [{lo}, {hi})",
                self.id
            );
        }
        Ok(())
    }
}

/// One scored response.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    /// The request's correlation id.
    pub id: u64,
    /// Raw model output.
    pub logit: f32,
    /// `sigmoid(logit)` — the predicted click probability.
    pub prob: f32,
}

/// Read requests from a TSV file: one request per line, `n_cat` global
/// ids followed by `n_dense` floats, separated by tabs or spaces. Blank
/// lines and `#` comments are skipped; every row is validated against
/// the schema. Request ids are assigned in file order.
pub fn read_requests_tsv(path: &Path, schema: &Schema) -> Result<Vec<Request>> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let want = schema.n_cat() + schema.n_dense;
    let mut out = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line
            .with_context(|| format!("{}:{}: read error", path.display(), lineno + 1))?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = trimmed.split(['\t', ' ']).filter(|t| !t.is_empty()).collect();
        if toks.len() != want {
            bail!(
                "{}:{}: {} columns, expected {} ({} cat ids + {} dense)",
                path.display(),
                lineno + 1,
                toks.len(),
                want,
                schema.n_cat(),
                schema.n_dense
            );
        }
        let (cat_toks, dense_toks) = toks.split_at(schema.n_cat());
        let cat: Vec<i32> = cat_toks
            .iter()
            .enumerate()
            .map(|(col, t)| {
                t.parse().with_context(|| {
                    format!("{}:{}: column {}: bad id {t:?}", path.display(), lineno + 1, col + 1)
                })
            })
            .collect::<Result<_>>()?;
        let dense: Vec<f32> = dense_toks
            .iter()
            .enumerate()
            .map(|(col, t)| {
                t.parse().with_context(|| {
                    format!(
                        "{}:{}: column {}: bad dense value {t:?}",
                        path.display(),
                        lineno + 1,
                        schema.n_cat() + col + 1
                    )
                })
            })
            .collect::<Result<_>>()?;
        let req = Request { id: out.len() as u64, cat, dense };
        req.validate(schema)
            .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
        out.push(req);
    }
    ensure!(!out.is_empty(), "{}: no requests found", path.display());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> Schema {
        Schema { name: "req".into(), n_dense: 2, vocab_sizes: vec![4, 3] }
    }

    #[test]
    fn validate_checks_ranges_and_arity() {
        let s = schema();
        let ok = Request { id: 0, cat: vec![3, 6], dense: vec![0.5, -1.0] };
        ok.validate(&s).unwrap();
        let bad_field = Request { id: 1, cat: vec![4, 6], dense: vec![0.0, 0.0] };
        assert!(bad_field.validate(&s).is_err(), "id 4 belongs to field 1");
        let bad_arity = Request { id: 2, cat: vec![0], dense: vec![0.0, 0.0] };
        assert!(bad_arity.validate(&s).is_err());
        let bad_dense = Request { id: 3, cat: vec![0, 4], dense: vec![0.0] };
        assert!(bad_dense.validate(&s).is_err());
    }

    #[test]
    fn tsv_roundtrip_and_errors() {
        let s = schema();
        let dir = std::env::temp_dir().join(format!("serve_tsv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.tsv");
        std::fs::write(&good, "# a comment\n0\t4\t0.5\t-1.0\n\n3 6 1.0 2.0\n").unwrap();
        let reqs = read_requests_tsv(&good, &s).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].cat, vec![0, 4]);
        assert_eq!(reqs[1].id, 1);
        assert_eq!(reqs[1].dense, vec![1.0, 2.0]);

        let bad = dir.join("bad.tsv");
        std::fs::write(&bad, "0\t99\t0.0\t0.0\n").unwrap();
        assert!(read_requests_tsv(&bad, &s).is_err(), "out-of-range id must fail");
        let short = dir.join("short.tsv");
        std::fs::write(&short, "0\t4\t0.5\n").unwrap();
        assert!(read_requests_tsv(&short, &s).is_err(), "missing column must fail");
        std::fs::remove_dir_all(&dir).ok();
    }
}
