//! The frozen, immutable scoring model served to request traffic.
//!
//! A [`ServeModel`] is built once — from a training checkpoint (`CCKS`
//! or bare `CCKP`) or an in-memory `ParamSet` — and never mutated, so it
//! is shared across scoring threads as a plain `Arc` with no locks on
//! the hot path. The vocab-shaped tables (embedding + wide) optionally
//! quantize to u16 codes with per-field affine constants
//! ([`QuantizedTable`]), cutting serving memory roughly in half; the
//! dense MLP/cross parameters stay f32 (they are negligible next to the
//! tables and feed matmuls directly).
//!
//! Scoring is a **single fused pass** per request: each categorical
//! field's embedding row gathers (dequantizing on the fly in quantized
//! mode — the gather knows each column's field statically, so the
//! affine constants need no lookup) *directly into the model's `x0`
//! input layout*, the wide-table sum accumulates in the same sweep, and
//! the dense features copy into the row tail — then the reference
//! model's inference-only forward ([`ReferenceModel::infer_x0`]) runs
//! over it, mirroring the training forward op for op on the same
//! vectorized kernels. In f32 mode served logits are therefore
//! bit-identical to `ReferenceModel::forward`; in quantized mode they
//! are exactly the forward over the dequantized tables, whose weights
//! sit within the documented per-field bound of the trained ones
//! (`rust/tests/serve_parity.rs` pins both). All scoring intermediates
//! (the `x0` batch, wide sums, layer activations, logits) live in the
//! calling thread's [`Scratch`] arena — the queue's scoring threads
//! each own one for the lifetime of the server, so steady-state scoring
//! performs zero heap allocation.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::quant::QuantizedTable;
use super::request::Request;
use crate::data::schema::Schema;
use crate::model::manifest::ParamEntry;
use crate::model::params::ParamSet;
use crate::model::store::ParamStore;
use crate::reference::{Kernels, ReferenceModel, Scratch};
use crate::tensor::Tensor;

/// Frozen storage of one vocab-shaped table.
enum TableStore {
    F32(Vec<f32>),
    Quant(QuantizedTable),
}

impl TableStore {
    /// Gather one row into `out`; quantized tables dequantize through
    /// the serving model's SIMD vtable (`k.dequant_row` — the fused
    /// gather–dequantize pass, bitwise equal to the scalar
    /// `min + code as f32 * step` in every tier).
    fn row_into(&self, k: &Kernels, id: usize, field: usize, d: usize, out: &mut [f32]) {
        match self {
            TableStore::F32(w) => out.copy_from_slice(&w[id * d..(id + 1) * d]),
            TableStore::Quant(q) => {
                let (min, step) = q.affine(field);
                (k.dequant_row)(q.row_codes(id), min, step, out);
            }
        }
    }

    fn value(&self, id: usize, field: usize) -> f32 {
        match self {
            TableStore::F32(w) => w[id],
            TableStore::Quant(q) => q.value(id, field),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            TableStore::F32(w) => w.len() * 4,
            TableStore::Quant(q) => q.bytes(),
        }
    }

    fn f32_bytes(&self) -> usize {
        match self {
            TableStore::F32(w) => w.len() * 4,
            TableStore::Quant(q) => q.rows() * q.d() * 4,
        }
    }

    fn to_f32(&self) -> Vec<f32> {
        match self {
            TableStore::F32(w) => w.clone(),
            TableStore::Quant(q) => q.dequantize_all(),
        }
    }
}

/// The frozen model (see module docs). Immutable after construction;
/// share it across scoring threads as `Arc<ServeModel>`.
pub struct ServeModel {
    model: ReferenceModel,
    spec: Vec<ParamEntry>,
    /// `(offset, vocab)` per categorical field, collected once.
    fields: Vec<(usize, usize)>,
    /// The `embed`-group table (always present).
    embed: TableStore,
    /// The `wide`-group table (DeepFM / W&D only).
    wide: Option<TableStore>,
    /// Non-vocab parameters in spec order (wide_bias, MLP, cross, head).
    dense: Vec<Tensor>,
    quantized: bool,
}

impl ServeModel {
    /// Freeze an in-memory parameter set for serving. `params` must
    /// match the model's spec (it is consumed — serving owns a private
    /// copy that trainers can't touch).
    pub fn from_params(model: ReferenceModel, params: ParamSet, quant: bool) -> Result<ServeModel> {
        let spec = params.spec.clone();
        let expected = crate::reference::step::build_spec(
            model.kind,
            &model.schema,
            model.embed_dim,
            &model.hidden,
            model.n_cross,
        );
        ensure!(
            spec == expected,
            "parameter spec does not match the {} architecture",
            model.kind
        );
        let fields: Vec<(usize, usize)> = model.schema.fields().collect();
        let mut embed = None;
        let mut wide = None;
        let mut dense = Vec::new();
        for (e, t) in spec.iter().zip(params.tensors.into_iter()) {
            match e.group.as_str() {
                "embed" => {
                    ensure!(embed.is_none(), "multiple embed tables in spec");
                    embed = Some(freeze_table(t, e, &fields, quant)?);
                }
                "wide" => {
                    ensure!(wide.is_none(), "multiple wide tables in spec");
                    wide = Some(freeze_table(t, e, &fields, quant)?);
                }
                _ => dense.push(t),
            }
        }
        let embed = embed.context("spec has no embed table")?;
        ensure!(
            wide.is_some() == model.uses_wide(),
            "wide table presence does not match the {} architecture",
            model.kind
        );
        Ok(ServeModel { model, spec, fields, embed, wide, dense, quantized: quant })
    }

    /// Load a frozen model from a training checkpoint — either the full
    /// `CCKS` state (moments are ignored; serving only needs weights) or
    /// a bare PR-1 `CCKP` params file. This is the freshness hand-off:
    /// `train --save ckpt` → `serve --ckpt ckpt`.
    pub fn load(path: &Path, model: ReferenceModel, quant: bool) -> Result<ServeModel> {
        let spec = crate::reference::step::build_spec(
            model.kind,
            &model.schema,
            model.embed_dim,
            &model.hidden,
            model.n_cross,
        );
        let params = ParamStore::load_params(path, &spec)
            .with_context(|| format!("loading serving weights from {}", path.display()))?;
        ServeModel::from_params(model, params, quant)
    }

    pub fn schema(&self) -> &Schema {
        &self.model.schema
    }

    pub fn reference(&self) -> &ReferenceModel {
        &self.model
    }

    pub fn is_quantized(&self) -> bool {
        self.quantized
    }

    /// Validate and score a micro-batch; returns one logit per request,
    /// in request order. Convenience form with a throwaway scratch
    /// arena — the queue's scoring threads use
    /// [`ServeModel::score_batch_scratch`] with a persistent one.
    pub fn score_batch(&self, reqs: &[Request]) -> Result<Vec<f32>> {
        for r in reqs {
            r.validate(&self.model.schema)?;
        }
        let mut scratch = Scratch::new();
        self.score_batch_validated(reqs, &mut scratch)
    }

    /// Validate and score on a caller-owned scratch arena. The returned
    /// logits buffer was taken from `scratch`; recycle it there once the
    /// scores have been copied out.
    pub fn score_batch_scratch(
        &self,
        reqs: &[Request],
        scratch: &mut Scratch,
    ) -> Result<Vec<f32>> {
        for r in reqs {
            r.validate(&self.model.schema)?;
        }
        self.score_batch_validated(reqs, scratch)
    }

    /// Scoring without re-validation — the micro-batching queue's path:
    /// `Client::submit` already validated every request at enqueue, so
    /// the scoring thread must not pay the O(batch · n_cat) range
    /// checks a second time.
    ///
    /// One fused pass per request builds the model input: embedding rows
    /// gather (+dequantize) straight into `x0`'s embed block, the wide
    /// sum accumulates in the same field sweep, and the dense features
    /// land in the row tail — no separate embeds / x_dense staging
    /// buffers.
    pub(crate) fn score_batch_validated(
        &self,
        reqs: &[Request],
        scratch: &mut Scratch,
    ) -> Result<Vec<f32>> {
        let b = reqs.len();
        if b == 0 {
            return Ok(Vec::new()); // lint:allow(hotpath-alloc): empty Vec never allocates (empty batch)
        }
        let f = self.model.schema.n_cat();
        let d = self.model.embed_dim;
        let nd = self.model.schema.n_dense;
        let d0 = self.model.d0();
        debug_assert!(reqs.iter().all(|r| r.validate(&self.model.schema).is_ok()));

        let kernels = self.model.kernels();
        let mut x0 = scratch.take(b * d0);
        let mut wide_sums = self.wide.as_ref().map(|_| scratch.take(b));
        for (i, r) in reqs.iter().enumerate() {
            let row = &mut x0[i * d0..(i + 1) * d0];
            let mut s = 0.0f32;
            for (j, &id) in r.cat.iter().enumerate() {
                self.embed.row_into(kernels, id as usize, j, d, &mut row[j * d..(j + 1) * d]);
                if let Some(wide) = self.wide.as_ref() {
                    s += wide.value(id as usize, j);
                }
            }
            if let Some(sums) = wide_sums.as_mut() {
                sums[i] = s;
            }
            if nd > 0 {
                row[f * d..].copy_from_slice(&r.dense);
            }
        }
        let logits = self.model.infer_x0(&self.dense, &x0, wide_sums.as_deref(), b, scratch)?;
        scratch.recycle(x0);
        if let Some(sums) = wide_sums {
            scratch.recycle(sums);
        }
        Ok(logits)
    }

    /// Rebuild a full `ParamSet` with the tables as the scorer actually
    /// sees them (dequantized in quantized mode) — the offline oracle the
    /// parity suite runs `ReferenceModel::forward` against.
    pub fn oracle_params(&self) -> Result<ParamSet> {
        let mut tensors = Vec::with_capacity(self.spec.len());
        let mut dense_it = self.dense.iter();
        for e in &self.spec {
            let t = match e.group.as_str() {
                "embed" => Tensor::f32(e.shape.clone(), self.embed.to_f32()),
                "wide" => Tensor::f32(
                    e.shape.clone(),
                    self.wide.as_ref().context("spec has a wide table but model does not")?.to_f32(),
                ),
                _ => dense_it.next().context("dense param underflow")?.clone(),
            };
            tensors.push(t);
        }
        ParamSet::new(self.spec.clone(), tensors)
    }

    /// Resident bytes of the vocab tables as served (the quantization
    /// target; the dense MLP/cross params are reported separately).
    pub fn table_bytes(&self) -> usize {
        self.embed.bytes() + self.wide.as_ref().map_or(0, |w| w.bytes())
    }

    /// Bytes the same tables occupy un-quantized (f32).
    pub fn table_f32_bytes(&self) -> usize {
        self.embed.f32_bytes() + self.wide.as_ref().map_or(0, |w| w.f32_bytes())
    }

    /// Resident bytes of the frozen parameters as served.
    pub fn serving_bytes(&self) -> usize {
        self.table_bytes() + self.dense.iter().map(|t| t.len() * 4).sum::<usize>()
    }

    /// Bytes the same parameters occupy un-quantized (f32).
    pub fn f32_bytes(&self) -> usize {
        self.table_f32_bytes() + self.dense.iter().map(|t| t.len() * 4).sum::<usize>()
    }

    /// Largest per-field dequantization error bound across the quantized
    /// tables (`None` in f32 mode). See `serve::quant` for the formula.
    pub fn quant_error_bound(&self) -> Option<f32> {
        if !self.quantized {
            return None;
        }
        let mut bound = 0.0f32;
        for t in [Some(&self.embed), self.wide.as_ref()].into_iter().flatten() {
            if let TableStore::Quant(q) = t {
                bound = bound.max(q.max_error_bound());
            }
        }
        Some(bound)
    }
}

fn freeze_table(
    t: Tensor,
    e: &ParamEntry,
    fields: &[(usize, usize)],
    quant: bool,
) -> Result<TableStore> {
    let d = e.shape.get(1).copied().unwrap_or(1);
    let data = match t {
        Tensor::F32 { data, .. } => data,
        Tensor::I32 { .. } => bail!("non-f32 vocab table {}", e.name),
    };
    Ok(if quant {
        TableStore::Quant(QuantizedTable::quantize(&data, d, fields)?)
    } else {
        TableStore::F32(data)
    })
}
