//! The micro-batching request queue and scoring-thread pool.
//!
//! # Request lifecycle
//!
//! ```text
//! enqueue ── Client::submit validates the request and pushes it (with
//! │          its arrival time and a reply channel) onto the shared
//! │          queue, waking the scoring pool.
//! coalesce ─ a scoring thread drains a micro-batch when EITHER trigger
//! │          fires: the queue holds `max_batch` requests (throughput
//! │          trigger), or the oldest queued request has waited
//! │          `max_delay` (latency-deadline trigger — a lone request is
//! │          never stranded behind an unfilled batch).
//! score ──── the thread runs one batched forward through the frozen
//! │          `Arc<ServeModel>` (no locks held while scoring; other
//! │          threads keep draining the queue concurrently).
//! respond ── each request's logit/probability goes back over its reply
//!            channel; per-request latency (enqueue → scored) lands in
//!            the shared histogram.
//! ```
//!
//! The queue itself is a `Mutex<VecDeque>` + `Condvar` with
//! short-critical-section discipline: the lock covers only push/drain
//! bookkeeping, never scoring, so contention stays negligible next to a
//! forward pass. Batching policy is two-trigger (size OR deadline),
//! which is the standard production trade: `max_batch` bounds the work
//! per forward, `max_delay` bounds the queueing latency any request can
//! pay waiting for co-riders. A third knob, `max_queue`, bounds
//! *admission*: past that depth `submit` fails fast with the typed
//! [`Overloaded`] error (counted on `serve.rejected`) so overload sheds
//! at the door instead of stretching every queued request's latency.
//!
//! Shutdown flushes: remaining requests are drained and scored without
//! waiting for deadlines, then the workers exit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::model::ServeModel;
use super::request::{Request, Scored};
use crate::metrics::{sigmoid, LatencyHistogram};

/// Micro-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Drain a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or as soon as the oldest queued request has waited this long.
    pub max_delay: Duration,
    /// Scoring threads (each drains and scores whole micro-batches).
    pub threads: usize,
    /// Admission bound on queued-but-unscored requests (`0` =
    /// unbounded). At the bound, [`Client::submit`] fails fast with the
    /// typed [`Overloaded`] error instead of letting queueing latency
    /// grow without limit.
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            threads: 2,
            max_queue: 0,
        }
    }
}

/// Typed admission-control failure: the queue already holds `max_queue`
/// pending requests. Callers shed or retry; the request was never
/// enqueued. Counted on `serve.rejected`.
#[derive(Clone, Copy, Debug)]
pub struct Overloaded {
    /// Queue depth observed at rejection time.
    pub depth: usize,
    /// The configured bound.
    pub max_queue: usize,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "serve: queue overloaded ({} pending >= --max-queue {})",
            self.depth, self.max_queue
        )
    }
}

impl std::error::Error for Overloaded {}

struct PendingReq {
    req: Request,
    enqueued: Instant,
    reply: mpsc::Sender<Scored>,
}

struct QueueState {
    deque: VecDeque<PendingReq>,
    shutdown: bool,
}

/// Serving counters, folded under one lock off the scoring path.
#[derive(Default)]
struct Counters {
    requests: u64,
    batches: u64,
    latency: LatencyHistogram,
}

struct Shared {
    model: Arc<ServeModel>,
    cfg: ServeConfig,
    q: Mutex<QueueState>,
    cv: Condvar,
    counters: Mutex<Counters>,
    /// First scoring error, if any (requests in that batch get dropped
    /// replies; `shutdown` surfaces the message).
    error: Mutex<Option<String>>,
    started: Instant,
    next_id: AtomicU64,
    /// Registry handles, registered once at [`Server::start`]; the
    /// scoring threads update them with relaxed atomic ops only.
    m_requests: Arc<crate::obs::Counter>,
    m_batches: Arc<crate::obs::Counter>,
    m_rejected: Arc<crate::obs::Counter>,
    m_latency: Arc<crate::obs::AtomicHistogram>,
}

/// A running micro-batching scorer: owns the scoring threads; hand out
/// [`Client`]s to submit traffic, then [`Server::shutdown`] to flush,
/// join and collect the serving stats.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
}

/// Aggregate serving statistics, collected at shutdown.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests scored.
    pub requests: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Per-request enqueue→scored latency (milliseconds).
    pub latency: LatencyHistogram,
    /// Server lifetime (start → shutdown).
    pub wall: Duration,
}

impl ServeStats {
    /// Mean requests per micro-batch (the coalescing win).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Scored requests per second over the server lifetime.
    pub fn qps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }
}

impl Server {
    /// Spawn the scoring pool over a frozen model.
    pub fn start(model: Arc<ServeModel>, cfg: ServeConfig) -> Server {
        let threads = cfg.threads.max(1);
        let shared = Arc::new(Shared {
            model,
            cfg,
            q: Mutex::new(QueueState { deque: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            counters: Mutex::new(Counters::default()),
            error: Mutex::new(None),
            started: Instant::now(),
            next_id: AtomicU64::new(0),
            m_requests: crate::obs::counter("serve.requests"),
            m_batches: crate::obs::counter("serve.batches"),
            m_rejected: crate::obs::counter("serve.rejected"),
            m_latency: crate::obs::histogram("serve.latency_ms"),
        });
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Server { shared, workers }
    }

    pub fn client(&self) -> Client {
        Client { shared: Arc::clone(&self.shared) }
    }

    /// Flush the queue, stop the scoring threads and return the stats.
    pub fn shutdown(mut self) -> Result<ServeStats> {
        {
            let mut st = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("scoring thread panicked"))?;
        }
        if let Some(e) = self.shared.error.lock().unwrap_or_else(PoisonError::into_inner).take() {
            bail!("serving error: {e}");
        }
        let c = self.shared.counters.lock().unwrap_or_else(PoisonError::into_inner);
        Ok(ServeStats {
            requests: c.requests,
            batches: c.batches,
            latency: c.latency.clone(),
            wall: self.shared.started.elapsed(),
        })
    }
}

impl Client {
    /// Fresh correlation id (callers that don't track their own).
    pub fn next_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Validate and enqueue one request; the returned channel yields the
    /// score once its micro-batch runs. Submitting never blocks on
    /// scoring (open-loop friendly).
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Scored>> {
        req.validate(self.shared.model.schema())?;
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            if st.shutdown {
                bail!("server is shutting down");
            }
            let cap = self.shared.cfg.max_queue;
            if cap > 0 && st.deque.len() >= cap {
                let depth = st.deque.len();
                drop(st);
                self.shared.m_rejected.inc();
                return Err(anyhow::Error::new(Overloaded { depth, max_queue: cap }));
            }
            st.deque.push_back(PendingReq { req, enqueued: Instant::now(), reply: tx });
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Submit and wait for the score (closed-loop callers and tests).
    pub fn score(&self, req: Request) -> Result<Scored> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| {
            let msg = self
                .shared
                .error
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .clone()
                .unwrap_or_else(|| "scoring dropped the request".into());
            anyhow::anyhow!("serving error: {msg}")
        })
    }
}

/// One scoring thread: coalesce → score → respond until shutdown. Each
/// thread owns a persistent scratch arena: the gather/forward
/// intermediates and the logits buffer are recycled every batch, so
/// steady-state scoring performs no heap allocation on the compute path.
fn worker_loop(shared: &Shared) {
    let max_batch = shared.cfg.max_batch.max(1);
    let mut scratch = crate::reference::Scratch::new();
    loop {
        // --- coalesce: wait for a full batch or the oldest deadline ---
        let batch: Vec<PendingReq> = {
            let mut st = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if st.deque.is_empty() {
                    if st.shutdown {
                        return;
                    }
                    st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                    continue;
                }
                if st.deque.len() >= max_batch || st.shutdown {
                    break; // size trigger (or flush-on-shutdown)
                }
                let deadline = match st.deque.front() {
                    Some(p) => p.enqueued + shared.cfg.max_delay,
                    None => continue,
                };
                let now = Instant::now();
                if now >= deadline {
                    break; // latency-deadline trigger
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                st = guard;
            }
            let take = st.deque.len().min(max_batch);
            st.deque.drain(..take).collect()
        };
        // more work may remain for an idle sibling
        shared.cv.notify_one();

        // --- score (no locks held) ---
        let mut reqs = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for p in batch {
            meta.push((p.enqueued, p.reply));
            reqs.push(p.req);
        }
        // requests were validated at submit; don't re-check per batch
        let scored = {
            let _score = crate::obs::span(crate::obs::Phase::ServeScore);
            shared.model.score_batch_validated(&reqs, &mut scratch)
        };
        match scored {
            Ok(logits) => {
                let scored_at = Instant::now();
                shared.m_batches.inc();
                shared.m_requests.add(reqs.len() as u64);
                {
                    let mut c = shared.counters.lock().unwrap_or_else(PoisonError::into_inner);
                    c.batches += 1;
                    c.requests += reqs.len() as u64;
                    for (enq, _) in &meta {
                        let ms = scored_at.duration_since(*enq).as_secs_f64() * 1e3;
                        c.latency.record(ms);
                        shared.m_latency.record(ms);
                    }
                }
                // --- respond ---
                for ((_, reply), (req, &logit)) in meta.iter().zip(reqs.iter().zip(&logits)) {
                    // a gone receiver just means the caller stopped waiting
                    let _ = reply.send(Scored { id: req.id, logit, prob: sigmoid(logit) });
                }
                // scores are copied into the replies; the buffer goes
                // back to the arena
                scratch.recycle(logits);
            }
            Err(e) => {
                let mut slot = shared.error.lock().unwrap_or_else(PoisonError::into_inner);
                if slot.is_none() {
                    *slot = Some(e.to_string());
                }
                // replies drop here; blocked callers see RecvError
            }
        }
    }
}

/// Convenience for load drivers: submit a whole request list open-loop
/// (everything enqueued before anything is awaited), then wait for all
/// responses. Returns the scores in submission order.
pub fn score_all(client: &Client, reqs: Vec<Request>) -> Result<Vec<Scored>> {
    let rxs: Vec<mpsc::Receiver<Scored>> =
        reqs.into_iter().map(|r| client.submit(r)).collect::<Result<_>>()?;
    rxs.into_iter()
        .map(|rx| rx.recv().context("scoring dropped a request (see server error)"))
        .collect()
}
