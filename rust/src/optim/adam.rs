//! Adam with bias correction; constants identical to the L2 JAX program.

/// Adam hyperparameters (fixed across the paper's experiments).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Stateless Adam step operating on caller-owned moment buffers, so the
/// same code serves every parameter tensor.
#[derive(Clone, Copy, Debug, Default)]
pub struct Adam {
    pub cfg: AdamConfig,
}

impl Adam {
    pub fn new(cfg: AdamConfig) -> Adam {
        Adam { cfg }
    }

    /// In-place update of `w`, `m`, `v` with gradient `g` at 1-based step
    /// `t` and learning rate `lr`.
    pub fn step(&self, w: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, t: f32) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), m.len());
        debug_assert_eq!(w.len(), v.len());
        let AdamConfig { beta1, beta2, eps } = self.cfg;
        let bc1 = 1.0 - beta1.powf(t);
        let bc2 = 1.0 - beta2.powf(t);
        for i in 0..w.len() {
            m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
            v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            w[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_magnitude_is_lr() {
        let adam = Adam::default();
        let mut w = vec![0.0f32; 3];
        let mut m = vec![0.0; 3];
        let mut v = vec![0.0; 3];
        adam.step(&mut w, &mut m, &mut v, &[1.0, -5.0, 0.25], 0.01, 1.0);
        for (i, sign) in [(0usize, -1.0f32), (1, 1.0), (2, -1.0)] {
            assert!((w[i].abs() - 0.01).abs() < 1e-4, "w[{i}]={}", w[i]);
            assert_eq!(w[i].signum(), sign);
        }
    }

    #[test]
    fn zero_grad_is_noop() {
        let adam = Adam::default();
        let mut w = vec![1.5f32, -2.0];
        let mut m = vec![0.0; 2];
        let mut v = vec![0.0; 2];
        adam.step(&mut w, &mut m, &mut v, &[0.0, 0.0], 0.1, 1.0);
        assert_eq!(w, vec![1.5, -2.0]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (w - 3)^2
        let adam = Adam::default();
        let mut w = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        for t in 1..=2000 {
            let g = vec![2.0 * (w[0] - 3.0)];
            adam.step(&mut w, &mut m, &mut v, &g, 0.05, t as f32);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "w={}", w[0]);
    }

    #[test]
    fn moments_follow_recurrence() {
        let adam = Adam::default();
        let mut w = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        adam.step(&mut w, &mut m, &mut v, &[2.0], 0.01, 1.0);
        assert!((m[0] - 0.2).abs() < 1e-6);
        assert!((v[0] - 0.004).abs() < 1e-7);
    }
}
