//! Adam with bias correction; constants identical to the L2 JAX program.
//!
//! Two variants live here:
//!
//! * [`Adam`] — the eager, dense update over a whole buffer (the L2
//!   twin). Bias corrections are computed in f64: `beta2^t` in f32
//!   drifts visibly past ~1e4 steps (an epoch at small batch), which is
//!   exactly the long-horizon regime the paper trains in.
//! * [`LazyAdam`] — the sparse row-wise update for embedding tables. It
//!   touches only the rows present in the batch; per-row last-update
//!   steps let it apply the closed-form moment decay `m *= beta1^k`,
//!   `v *= beta2^k` for the `k` missed (zero-gradient) steps on first
//!   touch, so moments match the eager trajectory exactly. (The eager
//!   update would also drift `w` slightly on zero-grad steps once
//!   moments are nonzero; lazy Adam skips that drift — the standard
//!   sparse-CTR semantics, cf. "On the Factory Floor", Anil et al.)

/// Adam hyperparameters (fixed across the paper's experiments).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Stateless Adam step operating on caller-owned moment buffers, so the
/// same code serves every parameter tensor.
#[derive(Clone, Copy, Debug, Default)]
pub struct Adam {
    pub cfg: AdamConfig,
}

impl Adam {
    pub fn new(cfg: AdamConfig) -> Adam {
        Adam { cfg }
    }

    /// In-place update of `w`, `m`, `v` with gradient `g` at 1-based step
    /// `t` and learning rate `lr`.
    pub fn step(&self, w: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, t: f32) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), m.len());
        debug_assert_eq!(w.len(), v.len());
        let AdamConfig { beta1, beta2, eps } = self.cfg;
        let bc1 = 1.0 - (beta1 as f64).powf(t as f64);
        let bc2 = 1.0 - (beta2 as f64).powf(t as f64);
        for i in 0..w.len() {
            m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
            v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
            let mhat = m[i] as f64 / bc1;
            let vhat = v[i] as f64 / bc2;
            w[i] -= (lr as f64 * mhat / (vhat.sqrt() + eps as f64)) as f32;
        }
    }
}

/// Sparse Adam over the rows of an `[n_rows, d]` table: only the rows in
/// `ids` pay any work. Per-row `last_step` bookkeeping applies the
/// closed-form bias-corrected moment decay for skipped steps on first
/// touch, so per-step cost is O(touched · d) regardless of `n_rows`.
#[derive(Clone, Debug)]
pub struct LazyAdam {
    pub cfg: AdamConfig,
    /// 1-based step of the last update per row; 0 = never touched.
    last_step: Vec<u32>,
}

impl LazyAdam {
    pub fn new(cfg: AdamConfig, n_rows: usize) -> LazyAdam {
        LazyAdam { cfg, last_step: vec![0; n_rows] }
    }

    pub fn n_rows(&self) -> usize {
        self.last_step.len()
    }

    /// Update rows `ids` of the dense `w`/`m`/`v` tables with the packed
    /// sparse gradient `g` (`ids.len() * d` values) at 1-based global
    /// step `t` — identical per-element math to [`Adam::step`] on the
    /// touched rows, after catching moments up on the missed steps.
    pub fn step_rows(
        &mut self,
        w: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        ids: &[u32],
        g: &[f32],
        d: usize,
        lr: f32,
        t: u32,
    ) {
        lazy_step_rows(&self.cfg, w, m, v, &mut self.last_step, ids, g, d, lr, t, 0);
    }
}

/// Shard-local lazy-Adam scatter update over a *slice* of a table.
///
/// `w`/`m`/`v` hold rows `[base, base + last.len())` of the full table
/// (`last.len() * d` values each); `ids` are **global** row ids inside
/// that range, and `last` is the matching slice of the per-row 1-based
/// last-update steps (0 = never touched). The per-element math is
/// exactly [`LazyAdam::step_rows`] — which delegates here with
/// `base = 0` — so a table split across shard owners bitwise-matches the
/// unsharded update.
#[allow(clippy::too_many_arguments)]
pub fn lazy_step_rows(
    cfg: &AdamConfig,
    w: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    last: &mut [u32],
    ids: &[u32],
    g: &[f32],
    d: usize,
    lr: f32,
    t: u32,
    base: usize,
) {
    debug_assert_eq!(g.len(), ids.len() * d);
    debug_assert_eq!(w.len(), last.len() * d);
    debug_assert_eq!(w.len(), m.len());
    debug_assert_eq!(w.len(), v.len());
    let AdamConfig { beta1, beta2, eps } = *cfg;
    let bc1 = 1.0 - (beta1 as f64).powf(t as f64);
    let bc2 = 1.0 - (beta2 as f64).powf(t as f64);
    for (k, &id) in ids.iter().enumerate() {
        let row = id as usize - base;
        let lo = row * d;
        let prev = last[row];
        if prev > 0 {
            // closed-form decay for the zero-grad steps since `prev`
            let missed = t.saturating_sub(1).saturating_sub(prev);
            if missed > 0 {
                let dm = (beta1 as f64).powi(missed as i32) as f32;
                let dv = (beta2 as f64).powi(missed as i32) as f32;
                for x in &mut m[lo..lo + d] {
                    *x *= dm;
                }
                for x in &mut v[lo..lo + d] {
                    *x *= dv;
                }
            }
        }
        for j in 0..d {
            let gi = g[k * d + j];
            m[lo + j] = beta1 * m[lo + j] + (1.0 - beta1) * gi;
            v[lo + j] = beta2 * v[lo + j] + (1.0 - beta2) * gi * gi;
            let mhat = m[lo + j] as f64 / bc1;
            let vhat = v[lo + j] as f64 / bc2;
            w[lo + j] -= (lr as f64 * mhat / (vhat.sqrt() + eps as f64)) as f32;
        }
        last[row] = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_magnitude_is_lr() {
        let adam = Adam::default();
        let mut w = vec![0.0f32; 3];
        let mut m = vec![0.0; 3];
        let mut v = vec![0.0; 3];
        adam.step(&mut w, &mut m, &mut v, &[1.0, -5.0, 0.25], 0.01, 1.0);
        for (i, sign) in [(0usize, -1.0f32), (1, 1.0), (2, -1.0)] {
            assert!((w[i].abs() - 0.01).abs() < 1e-4, "w[{i}]={}", w[i]);
            assert_eq!(w[i].signum(), sign);
        }
    }

    #[test]
    fn zero_grad_is_noop() {
        let adam = Adam::default();
        let mut w = vec![1.5f32, -2.0];
        let mut m = vec![0.0; 2];
        let mut v = vec![0.0; 2];
        adam.step(&mut w, &mut m, &mut v, &[0.0, 0.0], 0.1, 1.0);
        assert_eq!(w, vec![1.5, -2.0]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (w - 3)^2
        let adam = Adam::default();
        let mut w = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        for t in 1..=2000 {
            let g = vec![2.0 * (w[0] - 3.0)];
            adam.step(&mut w, &mut m, &mut v, &g, 0.05, t as f32);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "w={}", w[0]);
    }

    #[test]
    fn moments_follow_recurrence() {
        let adam = Adam::default();
        let mut w = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        adam.step(&mut w, &mut m, &mut v, &[2.0], 0.01, 1.0);
        assert!((m[0] - 0.2).abs() < 1e-6);
        assert!((v[0] - 0.004).abs() < 1e-7);
    }

    #[test]
    fn bias_correction_stays_precise_at_large_t() {
        // f32 powf used to lose the bias correction entirely out here;
        // the f64 path must stay finite and sane.
        let adam = Adam::default();
        let mut w = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        adam.step(&mut w, &mut m, &mut v, &[1.0], 0.01, 2.0e5);
        // bc1 ≈ bc2 ≈ 1 at this horizon: update ≈ lr * 0.1 / sqrt(0.001)
        let want = -0.01 * 0.1 / 0.001f64.sqrt();
        assert!(w[0].is_finite());
        assert!((w[0] as f64 - want).abs() < 1e-4, "w={} want {want}", w[0]);
    }

    #[test]
    fn lazy_matches_eager_when_all_rows_touched() {
        let cfg = AdamConfig::default();
        let eager = Adam::new(cfg);
        let mut lazy = LazyAdam::new(cfg, 3);
        let d = 2;
        let (mut we, mut me, mut ve) = (vec![0.1f32; 6], vec![0.0f32; 6], vec![0.0f32; 6]);
        let (mut wl, mut ml, mut vl) = (we.clone(), me.clone(), ve.clone());
        let ids = [0u32, 1, 2];
        for t in 1..=50u32 {
            let g: Vec<f32> = (0..6).map(|i| ((i + t as usize) % 5) as f32 - 2.0).collect();
            eager.step(&mut we, &mut me, &mut ve, &g, 0.01, t as f32);
            lazy.step_rows(&mut wl, &mut ml, &mut vl, &ids, &g, d, 0.01, t);
        }
        for i in 0..6 {
            assert!((we[i] - wl[i]).abs() <= 1e-6, "w[{i}]: {} vs {}", we[i], wl[i]);
            assert!((me[i] - ml[i]).abs() <= 1e-6, "m[{i}]");
            assert!((ve[i] - vl[i]).abs() <= 1e-6, "v[{i}]");
        }
    }

    #[test]
    fn lazy_catchup_decays_moments_like_eager() {
        // Row 0 is touched at steps 1 and 5; eager sees zero grads at
        // 2..4. Moments must agree exactly; w differs only by the tiny
        // zero-grad drift the lazy semantics skip.
        let cfg = AdamConfig::default();
        let eager = Adam::new(cfg);
        let mut lazy = LazyAdam::new(cfg, 1);
        let (mut we, mut me, mut ve) = (vec![0.5f32], vec![0.0f32], vec![0.0f32]);
        let (mut wl, mut ml, mut vl) = (we.clone(), me.clone(), ve.clone());

        eager.step(&mut we, &mut me, &mut ve, &[1.0], 0.01, 1.0);
        lazy.step_rows(&mut wl, &mut ml, &mut vl, &[0], &[1.0], 1, 0.01, 1);
        for t in 2..=4 {
            eager.step(&mut we, &mut me, &mut ve, &[0.0], 0.01, t as f32);
            // lazy: row untouched, nothing happens
        }
        eager.step(&mut we, &mut me, &mut ve, &[-1.0], 0.01, 5.0);
        lazy.step_rows(&mut wl, &mut ml, &mut vl, &[0], &[-1.0], 1, 0.01, 5);

        assert!((me[0] - ml[0]).abs() <= 1e-6, "m: {} vs {}", me[0], ml[0]);
        assert!((ve[0] - vl[0]).abs() <= 1e-7, "v: {} vs {}", ve[0], vl[0]);
        // the w gap is exactly the skipped zero-grad drift: small
        assert!((we[0] - wl[0]).abs() < 0.05, "w: {} vs {}", we[0], wl[0]);
    }

    #[test]
    fn offset_shard_update_matches_whole_table() {
        // one table updated whole vs split at row 2 into two shard
        // slices with rebased state: bitwise identical trajectories
        let cfg = AdamConfig::default();
        let d = 3;
        let rows = 5;
        let mut whole = LazyAdam::new(cfg, rows);
        let mut w = vec![0.1f32; rows * d];
        let mut m = vec![0.0f32; rows * d];
        let mut v = vec![0.0f32; rows * d];
        let (mut ws, mut ms, mut vs) = (w.clone(), m.clone(), v.clone());
        let mut last_s = vec![0u32; rows];
        for t in 1..=8u32 {
            let ids: Vec<u32> = if t % 2 == 0 { vec![0, 3] } else { vec![1, 3, 4] };
            let g: Vec<f32> = (0..ids.len() * d).map(|i| (i as f32 + t as f32) * 0.1).collect();
            whole.step_rows(&mut w, &mut m, &mut v, &ids, &g, d, 0.01, t);

            let split_k = ids.partition_point(|&id| (id as usize) < 2);
            let (lo_ids, hi_ids) = ids.split_at(split_k);
            let (lo_g, hi_g) = g.split_at(split_k * d);
            let (w0, w1) = ws.split_at_mut(2 * d);
            let (m0, m1) = ms.split_at_mut(2 * d);
            let (v0, v1) = vs.split_at_mut(2 * d);
            let (l0, l1) = last_s.split_at_mut(2);
            lazy_step_rows(&cfg, w0, m0, v0, l0, lo_ids, lo_g, d, 0.01, t, 0);
            lazy_step_rows(&cfg, w1, m1, v1, l1, hi_ids, hi_g, d, 0.01, t, 2);
        }
        assert_eq!(w, ws);
        assert_eq!(m, ms);
        assert_eq!(v, vs);
    }

    #[test]
    fn lazy_untouched_rows_are_free_and_frozen() {
        let mut lazy = LazyAdam::new(AdamConfig::default(), 4);
        let d = 2;
        let mut w: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut m = vec![0.0f32; 8];
        let mut v = vec![0.0f32; 8];
        let w0 = w.clone();
        lazy.step_rows(&mut w, &mut m, &mut v, &[1], &[1.0, -1.0], d, 0.1, 1);
        // row 1 moved, everything else untouched
        assert_ne!(&w[2..4], &w0[2..4]);
        assert_eq!(&w[0..2], &w0[0..2]);
        assert_eq!(&w[4..8], &w0[4..8]);
        assert_eq!(lazy.n_rows(), 4);
    }
}
