//! Host-side reference optimizer (Adam + L2), mirroring
//! `python/compile/optim.py` exactly. Used by the pure-Rust reference
//! trainer and by the HLO↔Rust parity tests; the production training
//! path runs the AOT `apply` program instead.

pub mod adam;

pub use adam::{lazy_step_rows, Adam, AdamConfig, LazyAdam};
