//! Learning-rate warmup (paper: one epoch of linear warmup on the dense
//! weights only; embedding LR is *not* warmed up — the paper found it
//! doesn't help there).

/// Linear warmup over `steps` steps, factor in (0, 1].
#[derive(Clone, Copy, Debug)]
pub struct Warmup {
    pub steps: usize,
}

impl Warmup {
    pub fn new(steps: usize) -> Warmup {
        Warmup { steps }
    }

    /// One epoch's worth of steps.
    pub fn one_epoch(steps_per_epoch: usize) -> Warmup {
        Warmup { steps: steps_per_epoch }
    }

    pub fn none() -> Warmup {
        Warmup { steps: 0 }
    }

    /// Multiplier for 1-based step `t`.
    pub fn factor(&self, t: usize) -> f32 {
        if self.steps == 0 || t >= self.steps {
            1.0
        } else {
            (t as f32 + 1.0) / self.steps as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramps_linearly_then_flat() {
        let w = Warmup::new(10);
        assert!(w.factor(0) > 0.0);
        assert!(w.factor(4) < w.factor(8));
        assert_eq!(w.factor(10), 1.0);
        assert_eq!(w.factor(1000), 1.0);
    }

    #[test]
    fn none_is_identity() {
        let w = Warmup::none();
        assert_eq!(w.factor(0), 1.0);
        assert_eq!(w.factor(5), 1.0);
    }

    #[test]
    fn epoch_constructor() {
        assert_eq!(Warmup::one_epoch(37).steps, 37);
    }
}
