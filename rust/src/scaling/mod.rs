//! Hyperparameter scaling engine: the paper's Scaling Rules 1-4 plus the
//! baseline variants, the dataset presets of Tables 8/9, and learning-rate
//! warmup. This is where "scale the batch 128x" turns into concrete
//! hypers-vector values fed to the AOT `apply` program each step.

pub mod presets;
pub mod rules;
pub mod warmup;

pub use presets::{avazu_preset, criteo_preset, DatasetPreset};
pub use rules::{HyperSet, ScalingRule};
pub use warmup::Warmup;
