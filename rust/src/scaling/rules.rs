//! Scaling rules (paper §3): how (learning rate, L2 weight) move when the
//! batch grows from `b` to `s·b`.
//!
//! | rule      | eta_embed | eta_dense | lambda  | paper ref          |
//! |-----------|-----------|-----------|---------|--------------------|
//! | NoScale   | 1         | 1         | 1       | baseline           |
//! | Sqrt      | sqrt(s)   | sqrt(s)   | sqrt(s) | Rule 1 (Krizhevsky)|
//! | SqrtStar  | sqrt(s)   | sqrt(s)   | 1       | Guo et al. variant |
//! | Linear    | s         | s         | 1       | Rule 2 (Goyal)     |
//! | N2Lambda  | 1         | sqrt(s)   | s^2     | Rule 4 (ours)      |
//! | CowClip   | 1         | sqrt(s)   | s       | Rule 3 (ours)      |
//!
//! Fixed clip thresholds scale by sqrt(s) (paper appendix: the sparse-id
//! regime accumulates gradients like independent draws).

use std::fmt;
use std::str::FromStr;

use anyhow::bail;

/// Fully resolved hyperparameters for one training configuration —
/// exactly the runtime `hypers` vector minus the step counter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HyperSet {
    pub lr_dense: f32,
    pub lr_embed: f32,
    pub l2_embed: f32,
    pub clip_r: f32,
    pub clip_zeta: f32,
    pub clip_t: f32,
}

impl HyperSet {
    /// Pack into the 8-slot hypers vector (slot 6 = step, slot 7 spare).
    pub fn to_vec(&self, step: f32) -> [f32; 8] {
        [
            self.lr_dense,
            self.lr_embed,
            self.l2_embed,
            self.clip_r,
            self.clip_zeta,
            self.clip_t,
            step,
            0.0,
        ]
    }
}

/// The scaling strategy under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScalingRule {
    NoScale,
    Sqrt,
    /// Sqrt on LR, lambda left alone (the DeepFM paper's variant).
    SqrtStar,
    Linear,
    /// Rule 4: embedding LR fixed, lambda scaled s^2.
    N2Lambda,
    /// Rule 3 (used with the CowClip algorithm): embedding LR fixed,
    /// lambda scaled s.
    CowClip,
}

impl ScalingRule {
    pub const ALL: [ScalingRule; 6] = [
        ScalingRule::NoScale,
        ScalingRule::Sqrt,
        ScalingRule::SqrtStar,
        ScalingRule::Linear,
        ScalingRule::N2Lambda,
        ScalingRule::CowClip,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ScalingRule::NoScale => "none",
            ScalingRule::Sqrt => "sqrt",
            ScalingRule::SqrtStar => "sqrt_star",
            ScalingRule::Linear => "linear",
            ScalingRule::N2Lambda => "n2_lambda",
            ScalingRule::CowClip => "cowclip",
        }
    }

    /// Paper row label.
    pub fn label(&self) -> &'static str {
        match self {
            ScalingRule::NoScale => "No Scaling",
            ScalingRule::Sqrt => "Sqrt Scaling",
            ScalingRule::SqrtStar => "Sqrt Scaling*",
            ScalingRule::Linear => "LR (Linear) Scaling",
            ScalingRule::N2Lambda => "n^2-lambda Scaling (Ours)",
            ScalingRule::CowClip => "CowClip (Ours)",
        }
    }

    /// Apply the rule: scale base hypers for a batch `s` times the base.
    pub fn apply(&self, base: &HyperSet, s: f64) -> HyperSet {
        let sf = s as f32;
        let sqrt_s = (s.sqrt()) as f32;
        let mut h = *base;
        match self {
            ScalingRule::NoScale => {}
            ScalingRule::Sqrt => {
                h.lr_embed *= sqrt_s;
                h.lr_dense *= sqrt_s;
                h.l2_embed *= sqrt_s;
            }
            ScalingRule::SqrtStar => {
                h.lr_embed *= sqrt_s;
                h.lr_dense *= sqrt_s;
            }
            ScalingRule::Linear => {
                h.lr_embed *= sf;
                h.lr_dense *= sf;
            }
            ScalingRule::N2Lambda => {
                h.lr_dense *= sqrt_s;
                h.l2_embed *= sf * sf;
            }
            ScalingRule::CowClip => {
                h.lr_dense *= sqrt_s;
                h.l2_embed *= sf;
            }
        }
        // fixed clip thresholds follow sqrt scaling (appendix analysis)
        h.clip_t *= sqrt_s;
        h
    }
}

impl fmt::Display for ScalingRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ScalingRule {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "none" => ScalingRule::NoScale,
            "sqrt" => ScalingRule::Sqrt,
            "sqrt_star" => ScalingRule::SqrtStar,
            "linear" => ScalingRule::Linear,
            "n2_lambda" => ScalingRule::N2Lambda,
            "cowclip" => ScalingRule::CowClip,
            other => bail!("unknown scaling rule {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> HyperSet {
        HyperSet {
            lr_dense: 1e-4,
            lr_embed: 1e-4,
            l2_embed: 1e-4,
            clip_r: 1.0,
            clip_zeta: 1e-5,
            clip_t: 1.0,
        }
    }

    #[test]
    fn identity_at_scale_one() {
        for rule in ScalingRule::ALL {
            assert_eq!(rule.apply(&base(), 1.0), base(), "{rule}");
        }
    }

    #[test]
    fn linear_rule_matches_table8() {
        // Table 8, batch 8K = 8x base: LR 8e-4, L2 unchanged.
        let h = ScalingRule::Linear.apply(&base(), 8.0);
        assert!((h.lr_embed - 8e-4).abs() < 1e-9);
        assert!((h.l2_embed - 1e-4).abs() < 1e-9);
    }

    #[test]
    fn sqrt_rule_matches_table8() {
        // Table 8, batch 2K: LR and L2 = sqrt(2)e-4
        let h = ScalingRule::Sqrt.apply(&base(), 2.0);
        let want = (2.0f32).sqrt() * 1e-4;
        assert!((h.lr_embed - want).abs() < 1e-9);
        assert!((h.l2_embed - want).abs() < 1e-9);
    }

    #[test]
    fn n2_lambda_matches_table8_empirical_column() {
        // Table 8 "Empirical Scaling": 8K -> L2 = 64e-4 ... wait, s^2 = 64
        // L2 = 64 * 1e-4 = 6.4e-3; the paper's table shows 1.28e-2 at 8K
        // because it tuned 2x (underlined). We implement the rule itself.
        let h = ScalingRule::N2Lambda.apply(&base(), 4.0);
        assert!((h.l2_embed - 16.0e-4).abs() < 1e-8);
        assert!((h.lr_embed - 1e-4).abs() < 1e-9, "embed LR must not scale");
        assert!((h.lr_dense - 2e-4).abs() < 1e-9);
    }

    #[test]
    fn cowclip_rule_matches_table9() {
        // Table 9 Criteo rows: L2 = s * 1e-4; embed LR pinned at 1e-4.
        for (s, want_l2) in [(2.0, 2e-4), (8.0, 8e-4), (16.0, 1.6e-3), (64.0, 6.4e-3)] {
            let h = ScalingRule::CowClip.apply(&base(), s);
            assert!((h.l2_embed - want_l2).abs() < 1e-8, "s={s}");
            assert!((h.lr_embed - 1e-4).abs() < 1e-9);
        }
    }

    #[test]
    fn clip_threshold_sqrt_scales() {
        let h = ScalingRule::NoScale.apply(&base(), 16.0);
        assert!((h.clip_t - 4.0).abs() < 1e-6);
    }

    #[test]
    fn hypers_vector_layout() {
        let v = base().to_vec(42.0);
        assert_eq!(v[0], 1e-4);
        assert_eq!(v[2], 1e-4);
        assert_eq!(v[6], 42.0);
        assert_eq!(v[7], 0.0);
    }

    #[test]
    fn parse_roundtrip() {
        for r in ScalingRule::ALL {
            assert_eq!(r.as_str().parse::<ScalingRule>().unwrap(), r);
        }
    }
}
