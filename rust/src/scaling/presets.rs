//! Dataset presets from the paper's Tables 8 and 9 (hyperparameters at
//! the base batch size), rescaled to this testbed's base batch.
//!
//! Paper base batch is 1K (1024) on 45M/32M rows; ours is 64 on ~2e5 rows
//! (DESIGN.md §4 maps the 1K→128K span onto 64→8K). The *relative*
//! schedule — what multiplies what when the batch scales — is the object
//! under study and carries over unchanged.

use super::rules::HyperSet;

/// Everything the harness needs to train on one dataset.
#[derive(Clone, Debug)]
pub struct DatasetPreset {
    pub name: &'static str,
    /// Base batch size that `HyperSet` is calibrated for.
    pub base_batch: usize,
    /// Base hypers for baseline (non-CowClip) runs.
    pub baseline: HyperSet,
    /// Base hypers for CowClip runs (dense LR boosted per Table 9).
    pub cowclip: HyperSet,
    /// Embedding init sigma for baseline runs.
    pub init_sigma_baseline: f32,
    /// Embedding init sigma for CowClip runs (paper uses 1e-2).
    pub init_sigma_cowclip: f32,
    /// Warmup epochs on the dense LR for CowClip runs.
    pub warmup_epochs: f64,
}

/// Criteo preset (paper Table 9 left: r=1, zeta=1e-5, dense LR 8x base).
pub fn criteo_preset() -> DatasetPreset {
    let baseline = HyperSet {
        lr_dense: 1e-3,
        lr_embed: 1e-3,
        l2_embed: 1e-5,
        clip_r: 1.0,
        clip_zeta: 1e-5,
        clip_t: 1.0,
    };
    DatasetPreset {
        name: "criteo_synth",
        base_batch: 64,
        baseline,
        cowclip: HyperSet {
            // paper: dense LR starts 8x the embedding LR under CowClip
            lr_dense: 8e-3,
            lr_embed: 1e-3,
            l2_embed: 1e-5,
            clip_r: 1.0,
            clip_zeta: 1e-5,
            clip_t: 1.0,
        },
        init_sigma_baseline: 1e-4,
        init_sigma_cowclip: 1e-2,
        warmup_epochs: 1.0,
    }
}

/// Avazu preset (paper Table 9 right: dense LR = embed LR at base,
/// zeta one decade larger than Criteo).
pub fn avazu_preset() -> DatasetPreset {
    let baseline = HyperSet {
        lr_dense: 1e-3,
        lr_embed: 1e-3,
        l2_embed: 1e-5,
        clip_r: 1.0,
        clip_zeta: 1e-4,
        clip_t: 1.0,
    };
    DatasetPreset {
        name: "avazu_synth",
        base_batch: 64,
        baseline,
        cowclip: baseline,
        init_sigma_baseline: 1e-4,
        init_sigma_cowclip: 1e-2,
        warmup_epochs: 1.0,
    }
}

/// Preset lookup by schema name.
pub fn by_schema(name: &str) -> Option<DatasetPreset> {
    match name {
        "criteo_synth" => Some(criteo_preset()),
        "avazu_synth" => Some(avazu_preset()),
        _ => None,
    }
}

/// The paper's batch-size ladder mapped onto this testbed:
/// (paper label, our batch size). Paper 1K..128K -> ours 64..8192.
pub const BATCH_LADDER: [(&str, usize); 8] = [
    ("1K", 64),
    ("2K", 128),
    ("4K", 256),
    ("8K", 512),
    ("16K", 1024),
    ("32K", 2048),
    ("64K", 4096),
    ("128K", 8192),
];

/// Paper label for one of our batch sizes (exact ladder match only).
pub fn paper_label(batch: usize) -> Option<&'static str> {
    BATCH_LADDER.iter().find(|&&(_, b)| b == batch).map(|&(l, _)| l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scaling::rules::ScalingRule;

    #[test]
    fn ladder_spans_128x() {
        assert_eq!(BATCH_LADDER[0].1 * 128, BATCH_LADDER[7].1);
        assert!(BATCH_LADDER.windows(2).all(|w| w[1].1 == w[0].1 * 2));
        assert_eq!(paper_label(512), Some("8K"));
        assert_eq!(paper_label(999), None);
    }

    #[test]
    fn criteo_dense_lr_boost_matches_paper_ratio() {
        let p = criteo_preset();
        assert!((p.cowclip.lr_dense / p.cowclip.lr_embed - 8.0).abs() < 1e-6);
    }

    #[test]
    fn table9_schedule_shape() {
        // CowClip rule over the preset reproduces Table 9's pattern:
        // embed LR constant, lambda linear in s, dense LR sqrt-scaled.
        let p = criteo_preset();
        let at_8k = ScalingRule::CowClip.apply(&p.cowclip, 8.0);
        assert_eq!(at_8k.lr_embed, p.cowclip.lr_embed);
        assert!((at_8k.l2_embed / p.cowclip.l2_embed - 8.0).abs() < 1e-4);
        assert!(
            (at_8k.lr_dense / p.cowclip.lr_dense - 8f32.sqrt()).abs() < 1e-4
        );
    }

    #[test]
    fn presets_resolve_by_schema() {
        assert!(by_schema("criteo_synth").is_some());
        assert!(by_schema("avazu_synth").is_some());
        assert!(by_schema("mnist").is_none());
    }
}
