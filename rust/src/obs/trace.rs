//! chrome://tracing export of the recorded span rings.
//!
//! The emitted file is the Chrome Trace Event JSON array format
//! (`{"traceEvents": [...]}`): load it in `chrome://tracing` or Perfetto
//! to see the step-phase timeline per thread and per distributed rank.
//! Complete events (`"ph": "X"`) carry microsecond start/duration;
//! `pid` groups spans by rank (`rank + 1`; unattributed spans land in
//! pid 0) and `tid` is the recording thread, so a 2-rank run renders as
//! two process lanes of `wire-tx`/`wire-rx`/`reduce`/... strips.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::snapshot::render_json;
use super::span::{collect_spans, NO_RANK};

/// The recorded spans as a chrome-trace JSON tree.
pub fn chrome_trace_json() -> Json {
    let spans = collect_spans();
    let mut events: Vec<Json> = Vec::with_capacity(spans.len());
    for s in &spans {
        let mut ev = BTreeMap::new();
        ev.insert("name".to_string(), Json::Str(s.phase.name().to_string()));
        ev.insert("cat".to_string(), Json::Str("phase".to_string()));
        ev.insert("ph".to_string(), Json::Str("X".to_string()));
        ev.insert("ts".to_string(), Json::Num(s.start_ns as f64 / 1e3));
        ev.insert("dur".to_string(), Json::Num(s.dur_ns as f64 / 1e3));
        let pid = if s.rank == NO_RANK { 0 } else { s.rank as u64 + 1 };
        ev.insert("pid".to_string(), Json::Num(pid as f64));
        ev.insert("tid".to_string(), Json::Num(s.tid as f64));
        let mut args = BTreeMap::new();
        let rank = if s.rank == NO_RANK { -1.0 } else { s.rank as f64 };
        args.insert("rank".to_string(), Json::Num(rank));
        ev.insert("args".to_string(), Json::Obj(args));
        events.push(Json::Obj(ev));
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(events));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Export the recorded spans to `path` (`--trace <path>`).
pub fn export_chrome(path: &Path) -> Result<()> {
    let body = render_json(&chrome_trace_json()) + "\n";
    std::fs::write(path, body).with_context(|| format!("trace: write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_trace_is_valid_json() {
        // No spans recorded by this test: the tree must still parse and
        // carry the traceEvents array.
        let v = chrome_trace_json();
        let back = Json::parse(&render_json(&v)).unwrap();
        assert!(back.get("traceEvents").unwrap().as_arr().is_ok());
    }
}
