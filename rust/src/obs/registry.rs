//! The lock-free metrics registry: fixed-slot atomic counters, gauges
//! and histograms, registered once by name and updated on the hot path
//! with plain relaxed atomic operations.
//!
//! # Design
//!
//! Registration ([`counter`] / [`gauge`] / [`histogram`]) takes a
//! `Mutex` and may allocate — it happens once, at startup or per-run
//! setup, and returns an `Arc` handle. Every subsequent update through
//! the handle is lock-free and allocation-free: a counter bump is a
//! single `fetch_add(Relaxed)`, a gauge set a single `store(Relaxed)`,
//! a histogram record a fixed handful of relaxed atomic ops. The
//! cowclip-lint `obs-inert` rule family statically enforces that hot
//! paths only reach the recording API, never registration.
//!
//! Names are dotted lowercase (`train.steps`, `dist.rank0.tx_bytes`);
//! [`snapshot_metrics`] returns every metric sorted by name, so all
//! exposition formats (JSONL, Prometheus text, the `Metrics` wire
//! frame) render deterministically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use super::hist::{bucket_of, Histogram, LAT_BUCKETS};

/// Monotone event counter. Bumps are single relaxed atomic adds.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in one atomic word).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free variant of [`Histogram`]: same bounds and bucket function
/// (shared via `obs::hist`), atomically updatable from any thread.
/// Percentile math runs on a [`Histogram`] snapshot so it exists once.
pub struct AtomicHistogram {
    buckets: [AtomicU64; LAT_BUCKETS],
    n: AtomicU64,
    /// Sum in integer nanosecond-of-a-millisecond units (`ms * 1e6`):
    /// `fetch_add` needs an integer, and 1 ns resolution loses nothing
    /// the bucket math could keep.
    sum_ns: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            n: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }
}

impl AtomicHistogram {
    /// Record one sample in milliseconds (negatives clamp to 0). All
    /// relaxed atomics, no locks, no allocation. `fetch_min`/`fetch_max`
    /// on the raw bits are order-correct because the clamped sample is
    /// non-negative (IEEE-754 bit patterns of non-negative floats sort
    /// like their values).
    pub fn record(&self, ms: f64) {
        let ms = if ms.is_finite() { ms.max(0.0) } else { 0.0 };
        self.buckets[bucket_of(ms)].fetch_add(1, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((ms * 1e6) as u64, Ordering::Relaxed);
        self.min_bits.fetch_min(ms.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(ms.to_bits(), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Snapshot into the plain histogram type (percentiles, summary).
    pub fn snapshot(&self) -> Histogram {
        let mut counts = [0u64; LAT_BUCKETS];
        for (dst, src) in counts.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        Histogram::from_parts(
            counts,
            self.n.load(Ordering::Relaxed),
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e6,
            f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        )
    }
}

/// Name-sorted registry slots (linear structures, not hash maps: the
/// registry is small, ordered iteration is the common read, and the
/// snapshot order must be deterministic).
#[derive(Default)]
struct Inner {
    counters: Vec<(String, Arc<Counter>)>,
    gauges: Vec<(String, Arc<Gauge>)>,
    hists: Vec<(String, Arc<AtomicHistogram>)>,
}

fn registry() -> &'static Mutex<Inner> {
    static REGISTRY: OnceLock<Mutex<Inner>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Inner::default()))
}

fn lookup<T: Default>(slots: &mut Vec<(String, Arc<T>)>, name: &str) -> Arc<T> {
    match slots.binary_search_by(|(n, _)| n.as_str().cmp(name)) {
        Ok(i) => Arc::clone(&slots[i].1),
        Err(i) => {
            let handle: Arc<T> = Arc::new(T::default());
            slots.insert(i, (name.to_string(), Arc::clone(&handle)));
            handle
        }
    }
}

/// Register (or fetch) the counter named `name`. Registration-time
/// only: never call from a hot path — hold the handle instead.
pub fn counter(name: &str) -> Arc<Counter> {
    lookup(&mut registry().lock().unwrap_or_else(PoisonError::into_inner).counters, name)
}

/// Register (or fetch) the gauge named `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    lookup(&mut registry().lock().unwrap_or_else(PoisonError::into_inner).gauges, name)
}

/// Register (or fetch) the atomic histogram named `name`.
pub fn histogram(name: &str) -> Arc<AtomicHistogram> {
    lookup(&mut registry().lock().unwrap_or_else(PoisonError::into_inner).hists, name)
}

/// A point-in-time copy of every registered metric, sorted by name.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, Histogram)>,
}

impl MetricsSnapshot {
    /// Value of a counter in this snapshot (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Value of a gauge in this snapshot (0.0 when absent).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }
}

/// Snapshot every registered metric.
pub fn snapshot_metrics() -> MetricsSnapshot {
    let g = registry().lock().unwrap_or_else(PoisonError::into_inner);
    MetricsSnapshot {
        counters: g.counters.iter().map(|(n, c)| (n.clone(), c.get())).collect(),
        gauges: g.gauges.iter().map(|(n, c)| (n.clone(), c.get())).collect(),
        hists: g.hists.iter().map(|(n, h)| (n.clone(), h.snapshot())).collect(),
    }
}

/// Unregister everything (test isolation). Live handles keep working
/// but stop appearing in snapshots.
pub fn reset_metrics() {
    let mut g = registry().lock().unwrap_or_else(PoisonError::into_inner);
    g.counters.clear();
    g.gauges.clear();
    g.hists.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_ops() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        g.set(-1.0);
        assert_eq!(g.get(), -1.0);
    }

    #[test]
    fn atomic_histogram_matches_plain() {
        let a = AtomicHistogram::default();
        let mut h = Histogram::new();
        for i in 1..=100 {
            let ms = i as f64 * 0.37;
            a.record(ms);
            h.record(ms);
        }
        let s = a.snapshot();
        assert_eq!(s.count(), h.count());
        assert_eq!(s.percentile(50.0), h.percentile(50.0));
        assert_eq!(s.percentile(99.0), h.percentile(99.0));
        assert_eq!(s.max_ms(), h.max_ms());
        assert!((s.mean_ms() - h.mean_ms()).abs() < 1e-4);
    }

    #[test]
    fn atomic_histogram_empty_and_junk_samples() {
        let a = AtomicHistogram::default();
        let s = a.snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.percentile(50.0), 0.0);
        a.record(f64::NAN);
        a.record(-3.0);
        assert_eq!(a.count(), 2);
        assert_eq!(a.snapshot().percentile(100.0), 0.0);
    }

    #[test]
    fn registration_is_idempotent_and_sorted() {
        // exercise private `lookup` directly so this test cannot race
        // other tests through the global registry
        let mut slots: Vec<(String, Arc<Counter>)> = Vec::new();
        let b = lookup(&mut slots, "b.metric");
        let a = lookup(&mut slots, "a.metric");
        let b2 = lookup(&mut slots, "b.metric");
        b.add(3);
        b2.add(4);
        a.inc();
        assert_eq!(slots.len(), 2);
        assert_eq!(slots[0].0, "a.metric");
        assert_eq!(slots[1].0, "b.metric");
        assert_eq!(slots[1].1.get(), 7, "both handles hit one slot");
    }
}
