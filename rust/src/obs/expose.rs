//! Metrics exposition: Prometheus-style text dumps and the live
//! `MetricsReq`/`Metrics` frame exchange behind `cowclip metrics`.
//!
//! Three read paths, one source of truth (the registry snapshot):
//!
//! * [`prometheus_text`] — the text format `cowclip serve` prints at
//!   shutdown (and anything else that wants a scrapeable dump).
//! * [`serve_metrics`] — a detached responder thread bound to an
//!   [`Endpoint`]; each accepted connection may send one `MetricsReq`
//!   frame and gets back one `Metrics` frame whose payload is the
//!   `cowclip-metrics-v1` JSON tree. Live dist/serve runs opt in with
//!   `--metrics-bind`.
//! * [`fetch_metrics`] — the client side (`cowclip metrics --connect`).

use std::fmt::Write as _;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::transport::Endpoint;
use crate::wire::frame::{read_frame, write_frame, FrameKind};

use super::registry::snapshot_metrics;
use super::snapshot::{metrics_json, render_json};

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; dotted registry names
/// map through `cowclip_` + dots-to-underscores.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 8);
    out.push_str("cowclip_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn prom_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render every registered metric as Prometheus exposition text.
/// Counters and gauges map directly; histograms expose count, mean and
/// the p50/p90/p99 quantile gauges (in milliseconds).
pub fn prometheus_text() -> String {
    let snap = snapshot_metrics();
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = prom_name(name);
        let _ = writeln!(out, "# TYPE {n} counter");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, v) in &snap.gauges {
        let n = prom_name(name);
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {}", prom_num(*v));
    }
    for (name, h) in &snap.hists {
        let n = prom_name(name);
        let (p50, p90, p99, mean) = h.summary();
        let _ = writeln!(out, "# TYPE {n}_count counter");
        let _ = writeln!(out, "{n}_count {}", h.count());
        let _ = writeln!(out, "# TYPE {n}_mean gauge");
        let _ = writeln!(out, "{n}_mean {}", prom_num(mean));
        for (q, v) in [("0.5", p50), ("0.9", p90), ("0.99", p99)] {
            let _ = writeln!(out, "{n}{{quantile=\"{q}\"}} {}", prom_num(v));
        }
    }
    out
}

/// Answer `MetricsReq` frames on `endpoint` from a detached thread for
/// the lifetime of the process. Each accepted connection gets exactly
/// one snapshot reply; accept timeouts just re-poll so the thread dies
/// with the process instead of pinning shutdown.
pub fn serve_metrics(endpoint: &Endpoint) -> Result<()> {
    let listener = endpoint.bind().context("metrics: bind exposition endpoint")?;
    std::thread::spawn(move || loop {
        let Ok(mut conn) = listener.accept_deadline(Duration::from_millis(200)) else {
            continue;
        };
        let _ = conn.set_io_deadline(Some(Duration::from_secs(5)));
        let ok = matches!(read_frame(&mut conn), Ok((FrameKind::MetricsReq, _)));
        if ok {
            let body = render_json(&metrics_json());
            let _ = write_frame(&mut conn, FrameKind::Metrics, body.as_bytes());
        }
        conn.shutdown();
    });
    Ok(())
}

/// One-shot client pull: connect to `endpoint`, send `MetricsReq`, and
/// return the `Metrics` payload (a `cowclip-metrics-v1` JSON document).
pub fn fetch_metrics(endpoint: &Endpoint, timeout: Duration) -> Result<String> {
    let mut conn = endpoint
        .connect_retry(timeout)
        .context("metrics: connect to exposition endpoint")?;
    conn.set_io_deadline(Some(timeout))?;
    write_frame(&mut conn, FrameKind::MetricsReq, &[])?;
    let (kind, payload) = read_frame(&mut conn)?;
    conn.shutdown();
    if kind != FrameKind::Metrics {
        bail!("metrics: expected a Metrics frame, got {kind:?}");
    }
    String::from_utf8(payload).context("metrics: reply is not UTF-8")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("train.steps"), "cowclip_train_steps");
        assert_eq!(prom_name("dist.rank0.tx_bytes"), "cowclip_dist_rank0_tx_bytes");
    }

    #[test]
    fn prom_numbers_render_clean() {
        assert_eq!(prom_num(12.0), "12");
        assert_eq!(prom_num(0.125), "0.125");
    }
}
