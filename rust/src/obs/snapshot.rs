//! Deterministic JSON rendering + the shared snapshot/bench schema.
//!
//! The repo carries no serializer dependency; this module renders the
//! existing [`Json`] tree (previously parse-only) so every emitter —
//! metrics JSONL snapshots, chrome-trace export, the `Metrics` wire
//! frame, and the `BENCH_*.json` reports — shares one schema and one
//! formatter instead of three divergent hand-formatted writers.
//! Objects render in `BTreeMap` key order and metric names are sorted
//! at snapshot time, so output is byte-deterministic for a given state.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::registry;

/// Render a JSON value compactly (single line — JSONL-safe).
pub fn render_json(v: &Json) -> String {
    let mut out = String::new();
    write_value(&mut out, v, None, 0);
    out
}

/// Render a JSON value with 2-space indentation (human-facing files).
pub fn render_json_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(&mut out, v, Some(2), 0);
    out
}

fn write_value(out: &mut String, v: &Json, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => out.push_str(&fmt_num(*n)),
        Json::Str(s) => write_string(out, s),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(out, item, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, item, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// JSON has no NaN/Inf; non-finite numbers render as 0 (documented
/// lossy guard — metric values are finite in practice). Integral values
/// render without a fractional part.
fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        return "0".to_string();
    }
    if n == n.trunc() && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The current metrics registry as a JSON tree:
/// `{"counters": {..}, "gauges": {..}, "histograms": {name: summary}}`.
pub fn metrics_json() -> Json {
    let snap = registry::snapshot_metrics();
    let mut counters = std::collections::BTreeMap::new();
    for (name, v) in snap.counters {
        counters.insert(name, Json::Num(v as f64));
    }
    let mut gauges = std::collections::BTreeMap::new();
    for (name, v) in snap.gauges {
        gauges.insert(name, Json::Num(v));
    }
    let mut hists = std::collections::BTreeMap::new();
    for (name, h) in snap.hists {
        let (p50, p90, p99, mean) = h.summary();
        let mut o = std::collections::BTreeMap::new();
        o.insert("count".to_string(), Json::Num(h.count() as f64));
        o.insert("mean_ms".to_string(), Json::Num(mean));
        o.insert("p50_ms".to_string(), Json::Num(p50));
        o.insert("p90_ms".to_string(), Json::Num(p90));
        o.insert("p99_ms".to_string(), Json::Num(p99));
        o.insert("max_ms".to_string(), Json::Num(h.max_ms()));
        hists.insert(name, Json::Obj(o));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("counters".to_string(), Json::Obj(counters));
    root.insert("gauges".to_string(), Json::Obj(gauges));
    root.insert("histograms".to_string(), Json::Obj(hists));
    Json::Obj(root)
}

/// Build one JSONL snapshot line: sequence number, elapsed wall time,
/// and the full metrics tree.
fn snapshot_line(seq: u64, started: Instant) -> String {
    let mut root = std::collections::BTreeMap::new();
    root.insert("schema".to_string(), Json::Str("cowclip-metrics-v1".to_string()));
    root.insert("seq".to_string(), Json::Num(seq as f64));
    root.insert(
        "elapsed_ms".to_string(),
        Json::Num(started.elapsed().as_secs_f64() * 1e3),
    );
    root.insert("metrics".to_string(), metrics_json());
    render_json(&Json::Obj(root))
}

/// Periodic JSONL metrics writer (`--metrics-interval`): appends one
/// snapshot line every `interval` to `path`, plus a final line at
/// [`SnapshotWriter::finish`]. The writer thread snapshots off the hot
/// path; recording threads never block on it.
pub struct SnapshotWriter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<u64>>,
    path: PathBuf,
    started: Instant,
}

impl SnapshotWriter {
    /// Start the writer; truncates `path`.
    pub fn spawn(path: &Path, interval: Duration) -> Result<SnapshotWriter> {
        std::fs::write(path, "")
            .with_context(|| format!("metrics: create {}", path.display()))?;
        let stop = Arc::new(AtomicBool::new(false));
        let started = Instant::now();
        let handle = {
            let stop = Arc::clone(&stop);
            let path = path.to_path_buf();
            std::thread::spawn(move || {
                let mut seq = 0u64;
                let tick = Duration::from_millis(interval.as_millis().clamp(1, 50) as u64);
                let mut next = Instant::now() + interval;
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    if Instant::now() >= next {
                        append_line(&path, &snapshot_line(seq, started));
                        seq += 1;
                        next += interval;
                    }
                }
                seq
            })
        };
        Ok(SnapshotWriter { stop, handle: Some(handle), path: path.to_path_buf(), started })
    }

    /// Stop the writer thread and append one final snapshot. Returns
    /// the number of lines written (periodic + final).
    pub fn finish(mut self) -> Result<u64> {
        self.stop.store(true, Ordering::Relaxed);
        let seq = match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("metrics writer panicked"))?,
            None => 0,
        };
        append_line(&self.path, &snapshot_line(seq, self.started));
        Ok(seq + 1)
    }
}

fn append_line(path: &Path, line: &str) {
    let opened = std::fs::OpenOptions::new().append(true).create(true).open(path);
    if let Ok(mut f) = opened {
        let _ = writeln!(f, "{line}");
    }
}

/// The shared `BENCH_*.json` report shape: schema tag, bench name,
/// smoke flag, host arch, caller tags, and a `results` row array. One
/// emitter for `BENCH_kernels.json` / `BENCH_e2e.json` /
/// `BENCH_dist.json` (and the future sweep harness) replaces the three
/// divergent hand-formatted writers the benches used to carry.
pub fn bench_report(bench: &str, smoke: bool, tags: &[(&str, Json)], results: Vec<Json>) -> Json {
    let mut root = std::collections::BTreeMap::new();
    root.insert("schema".to_string(), Json::Str("cowclip-bench-v1".to_string()));
    root.insert("bench".to_string(), Json::Str(bench.to_string()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert(
        "arch".to_string(),
        Json::Str(std::env::consts::ARCH.to_string()),
    );
    for (k, v) in tags {
        root.insert((*k).to_string(), v.clone());
    }
    root.insert("results".to_string(), Json::Arr(results));
    Json::Obj(root)
}

/// Write a JSON tree to `path` (pretty, trailing newline) and report
/// like the benches always have.
pub fn write_json_report(path: &str, v: &Json) {
    let body = render_json_pretty(v) + "\n";
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("{path} not written: {e}"),
    }
}

/// Convenience: an object row from `(key, value)` pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = std::collections::BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_reparses() {
        let v = obj(vec![
            ("name", Json::Str("a \"quoted\"\nline".to_string())),
            ("n", Json::Num(3.0)),
            ("frac", Json::Num(0.25)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        for rendered in [render_json(&v), render_json_pretty(&v)] {
            let back = Json::parse(&rendered).expect("round-trip parse");
            assert_eq!(back.get("name").unwrap().as_str().unwrap(), "a \"quoted\"\nline");
            assert_eq!(back.get("n").unwrap().as_f64().unwrap(), 3.0);
            assert_eq!(back.get("frac").unwrap().as_f64().unwrap(), 0.25);
            assert!(back.get("ok").unwrap().as_bool().unwrap());
            assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 2);
        }
        assert!(!render_json(&v).contains('\n'), "compact form must be JSONL-safe");
    }

    #[test]
    fn numbers_render_clean() {
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(-2.0), "-2");
        assert_eq!(fmt_num(0.5), "0.5");
        assert_eq!(fmt_num(f64::NAN), "0");
        assert_eq!(fmt_num(f64::INFINITY), "0");
    }

    #[test]
    fn bench_report_schema_shape() {
        let rep = bench_report(
            "kernels",
            true,
            &[("kernel", Json::Str("scalar".to_string()))],
            vec![obj(vec![("name", Json::Str("matmul".to_string()))])],
        );
        let back = Json::parse(&render_json_pretty(&rep)).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str().unwrap(), "cowclip-bench-v1");
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "kernels");
        assert!(back.get("smoke").unwrap().as_bool().unwrap());
        assert_eq!(back.get("kernel").unwrap().as_str().unwrap(), "scalar");
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 1);
    }
}
