//! Span-based step-phase tracing over preallocated per-thread rings.
//!
//! # Design
//!
//! Each recording thread owns one fixed-capacity ring of atomic words
//! (allocated once, at that thread's first span after tracing is
//! enabled); recording a span writes three relaxed `AtomicU64` stores
//! plus one `Release` head bump — no locks, no allocation, no
//! contention with other writers. A global registry of `Arc<Ring>`s
//! (locked only at thread registration and at export time) lets the
//! trace exporter walk every thread's spans after the run.
//!
//! When tracing is **off** (the default), [`span`] returns an inert
//! guard without even reading the clock, so instrumentation left in the
//! hot path costs one relaxed atomic load per call site.
//!
//! # Determinism / inertness contract
//!
//! Recording reads the clock and writes to obs-private atomics; it
//! never reads or writes model state, gradients, RNG state or iteration
//! order. Training and serving results are therefore bitwise identical
//! with tracing on or off (`rust/tests/obs_parity.rs` pins this for all
//! six clip modes).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

/// Step-phase taxonomy. One span = one timed occurrence of a phase on
/// one thread (optionally attributed to a distributed rank).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// Batch materialization + touched-id sort in the prefetch thread.
    Prefetch = 0,
    /// Embedding gather fused into the x0 concat.
    Gather = 1,
    /// Dense forward (MLP / FM / cross streams).
    Forward = 2,
    /// Backward pass (dense + sparse embedding grads).
    Backward = 3,
    /// Gradient clipping (any of the six modes).
    Clip = 4,
    /// Tree all-reduce pairwise merge.
    Reduce = 5,
    /// A frame written to a socket (dist uplink / broadcast).
    WireTx = 6,
    /// A frame read from a socket (dist uplink / broadcast).
    WireRx = 7,
    /// Optimizer apply (L2 + Adam / lazy rows).
    Apply = 8,
    /// An evaluation pass over the test split.
    Eval = 9,
    /// One micro-batch scored by the serving queue.
    ServeScore = 10,
}

impl Phase {
    pub const ALL: [Phase; 11] = [
        Phase::Prefetch,
        Phase::Gather,
        Phase::Forward,
        Phase::Backward,
        Phase::Clip,
        Phase::Reduce,
        Phase::WireTx,
        Phase::WireRx,
        Phase::Apply,
        Phase::Eval,
        Phase::ServeScore,
    ];

    /// Stable lowercase name used in trace JSON and tests.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefetch => "prefetch",
            Phase::Gather => "gather",
            Phase::Forward => "forward",
            Phase::Backward => "backward",
            Phase::Clip => "clip",
            Phase::Reduce => "reduce",
            Phase::WireTx => "wire-tx",
            Phase::WireRx => "wire-rx",
            Phase::Apply => "apply",
            Phase::Eval => "eval",
            Phase::ServeScore => "serve-score",
        }
    }

    fn from_code(code: u8) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| *p as u8 == code)
    }
}

/// Rank value meaning "not attributed to a distributed rank".
pub const NO_RANK: u32 = u32::MAX;

/// Spans per thread ring; older spans are overwritten once full (the
/// exporter reports the freshest `RING_SPANS` per thread).
pub const RING_SPANS: usize = 8192;
const WORDS: usize = 3; // meta, start_ns, dur_ns

/// One thread's preallocated span ring (single writer, many readers).
struct Ring {
    tid: u64,
    /// Monotone span count; slot `i % RING_SPANS` holds span `i`.
    head: AtomicU64,
    slots: Box<[AtomicU64]>,
}

impl Ring {
    fn new(tid: u64) -> Ring {
        let slots: Vec<AtomicU64> = (0..RING_SPANS * WORDS).map(|_| AtomicU64::new(0)).collect();
        Ring { tid, head: AtomicU64::new(0), slots: slots.into_boxed_slice() }
    }

    /// Single-writer push: relaxed payload stores, `Release` head bump
    /// so a reader that `Acquire`-loads the head sees complete slots.
    fn push(&self, meta: u64, start_ns: u64, dur_ns: u64) {
        let i = (self.head.load(Ordering::Relaxed) as usize % RING_SPANS) * WORDS;
        self.slots[i].store(meta, Ordering::Relaxed);
        self.slots[i + 1].store(start_ns, Ordering::Relaxed);
        self.slots[i + 2].store(dur_ns, Ordering::Relaxed);
        self.head.fetch_add(1, Ordering::Release);
    }
}

struct SpanState {
    rings: Mutex<Vec<Arc<Ring>>>,
    /// Bumped by [`reset_spans`]; threads re-register lazily when their
    /// cached ring's generation goes stale.
    generation: AtomicU64,
    next_tid: AtomicU64,
}

static TRACING: AtomicBool = AtomicBool::new(false);

fn state() -> &'static SpanState {
    static STATE: OnceLock<SpanState> = OnceLock::new();
    STATE.get_or_init(|| SpanState {
        rings: Mutex::new(Vec::new()),
        generation: AtomicU64::new(0),
        next_tid: AtomicU64::new(0),
    })
}

/// The process-wide time origin for span start stamps.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static RING: RefCell<Option<(u64, Arc<Ring>)>> = const { RefCell::new(None) };
    static RING_GROWS: Cell<u64> = const { Cell::new(0) };
}

/// Enable or disable span recording process-wide.
pub fn set_tracing(on: bool) {
    if on {
        // Pin the time origin before the first span so start stamps
        // are non-negative offsets.
        let _ = epoch();
    }
    TRACING.store(on, Ordering::Relaxed);
}

/// Is span recording currently enabled?
pub fn tracing_on() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// How many times *this thread* allocated/registered a span ring. Flat
/// after the first span per generation — the zero-growth gate in
/// `rust/tests/obs_parity.rs` asserts on it, mirroring the
/// `Scratch::grow_events` pattern.
pub fn thread_ring_grows() -> u64 {
    RING_GROWS.with(Cell::get)
}

/// Drop all recorded spans and detach every thread's ring (test
/// isolation; threads re-register on their next span).
pub fn reset_spans() {
    let st = state();
    st.generation.fetch_add(1, Ordering::Release);
    st.rings.lock().unwrap_or_else(PoisonError::into_inner).clear();
}

/// RAII span: created by [`span`]/[`span_rank`], records on drop. Inert
/// (and clock-free) when tracing is disabled.
pub struct SpanGuard {
    live: Option<(Phase, u32, Instant)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((phase, rank, t0)) = self.live.take() {
            record(phase, rank, t0);
        }
    }
}

/// Open a span for `phase` on this thread (no rank attribution).
pub fn span(phase: Phase) -> SpanGuard {
    if !tracing_on() {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((phase, NO_RANK, Instant::now())) }
}

/// Open a span for `phase` attributed to distributed rank `rank`.
pub fn span_rank(phase: Phase, rank: usize) -> SpanGuard {
    if !tracing_on() {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((phase, rank as u32, Instant::now())) }
}

fn record(phase: Phase, rank: u32, t0: Instant) {
    let dur_ns = t0.elapsed().as_nanos() as u64;
    // saturates to 0 if t0 somehow predates the pinned epoch
    let start_ns = t0.duration_since(epoch()).as_nanos() as u64;
    let meta = ((rank as u64) << 8) | phase as u64;
    RING.with(|cell| {
        let st = state();
        let generation = st.generation.load(Ordering::Acquire);
        let mut slot = cell.borrow_mut();
        let stale = match &*slot {
            Some((g, _)) => *g != generation,
            None => true,
        };
        if stale {
            // Registration: the only allocating path, once per thread
            // per generation (counted by `thread_ring_grows`).
            let ring = Arc::new(Ring::new(st.next_tid.fetch_add(1, Ordering::Relaxed)));
            st.rings
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(Arc::clone(&ring));
            RING_GROWS.with(|g| g.set(g.get() + 1));
            *slot = Some((generation, ring));
        }
        if let Some((_, ring)) = &*slot {
            ring.push(meta, start_ns, dur_ns);
        }
    });
}

/// One exported span.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRecord {
    pub phase: Phase,
    /// `NO_RANK` when the span has no distributed-rank attribution.
    pub rank: u32,
    /// Per-ring thread id (registration order, process-unique).
    pub tid: u64,
    /// Nanoseconds since the tracing epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Snapshot every thread's ring (freshest `RING_SPANS` spans per
/// thread), sorted by start time for a stable export order.
pub fn collect_spans() -> Vec<SpanRecord> {
    let rings: Vec<Arc<Ring>> = state()
        .rings
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .iter()
        .map(Arc::clone)
        .collect();
    let mut out: Vec<SpanRecord> = Vec::new();
    for ring in &rings {
        let head = ring.head.load(Ordering::Acquire);
        let n = (head as usize).min(RING_SPANS);
        let first = head as usize - n;
        for k in first..head as usize {
            let i = (k % RING_SPANS) * WORDS;
            let meta = ring.slots[i].load(Ordering::Relaxed);
            let start_ns = ring.slots[i + 1].load(Ordering::Relaxed);
            let dur_ns = ring.slots[i + 2].load(Ordering::Relaxed);
            let Some(phase) = Phase::from_code((meta & 0xFF) as u8) else {
                continue;
            };
            out.push(SpanRecord {
                phase,
                rank: (meta >> 8) as u32,
                tid: ring.tid,
                start_ns,
                dur_ns,
            });
        }
    }
    out.sort_by_key(|s| (s.start_ns, s.tid, s.dur_ns));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_wraps() {
        let ring = Ring::new(7);
        for k in 0..(RING_SPANS as u64 + 10) {
            ring.push(k, k * 2, k * 3);
        }
        let head = ring.head.load(Ordering::Acquire);
        assert_eq!(head, RING_SPANS as u64 + 10);
        // the freshest span sits at (head-1) % RING_SPANS
        let i = ((head - 1) as usize % RING_SPANS) * WORDS;
        assert_eq!(ring.slots[i].load(Ordering::Relaxed), head - 1);
    }

    #[test]
    fn phase_codes_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_code(p as u8), Some(p));
            assert!(!p.name().is_empty());
        }
        assert_eq!(Phase::from_code(200), None);
    }

    #[test]
    fn disabled_span_is_inert() {
        // Tracing defaults to off in the lib test binary; an inert
        // guard must not register a ring for this thread.
        let before = thread_ring_grows();
        {
            let _g = span(Phase::Forward);
        }
        assert_eq!(thread_ring_grows(), before);
    }
}
