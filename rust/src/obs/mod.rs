//! Unified telemetry: step-phase tracing, the lock-free metrics
//! registry, and exposition (JSONL snapshots, chrome-trace export,
//! Prometheus text, the `Metrics` wire frame).
//!
//! # Layout
//!
//! * [`span`](mod@span) — preallocated per-thread span rings recording the
//!   step-phase taxonomy (`prefetch`, `gather`, `forward`, `backward`,
//!   `clip`, `reduce`, `wire-tx`, `wire-rx`, `apply`, `eval`,
//!   `serve-score`) with thread + rank attribution.
//! * [`registry`] — fixed-slot atomic counters / gauges / histograms:
//!   register once at startup, update on the hot path with single
//!   relaxed atomic operations.
//! * [`hist`] — the shared fixed-bucket histogram + QPS meter
//!   (generalized out of `metrics/meters.rs`; `metrics::LatencyHistogram`
//!   re-exports it).
//! * [`snapshot`] — deterministic JSON rendering, the periodic JSONL
//!   [`SnapshotWriter`], and the shared `cowclip-bench-v1` report shape.
//! * [`trace`] — chrome://tracing export of the span rings (`--trace`).
//! * [`expose`] — Prometheus text + the live `MetricsReq`/`Metrics`
//!   frame exchange (`cowclip metrics --connect`).
//!
//! # Inertness contract
//!
//! Observability never touches numerics: spans and metrics read the
//! clock and write to obs-private atomics only, so every parity suite
//! passes bitwise-unchanged with tracing and metrics enabled
//! (`rust/tests/obs_parity.rs`). Steady-state recording is
//! allocation-free and lock-free; the only allocating paths are
//! registration (per metric, per thread-ring) and export, which run off
//! the hot path. The cowclip-lint `obs-inert` rule family statically
//! checks that hot-path code reaches only the alloc-free recording API
//! ([`span`](fn@span) / [`span_rank`] / [`tracing_on`]).

pub mod expose;
pub mod hist;
pub mod registry;
pub mod snapshot;
pub mod span;
pub mod trace;

pub use expose::{fetch_metrics, prometheus_text, serve_metrics};
pub use hist::{Histogram, QpsMeter};
pub use registry::{
    counter, gauge, histogram, reset_metrics, snapshot_metrics, AtomicHistogram, Counter, Gauge,
    MetricsSnapshot,
};
pub use snapshot::{
    bench_report, metrics_json, obj, render_json, render_json_pretty, write_json_report,
    SnapshotWriter,
};
pub use span::{
    collect_spans, reset_spans, set_tracing, span, span_rank, thread_ring_grows, tracing_on, Phase,
    SpanGuard, SpanRecord, NO_RANK,
};
pub use trace::{chrome_trace_json, export_chrome};
