//! The shared fixed-bucket latency histogram and throughput meter.
//!
//! This is the bucket math that used to live in `metrics/meters.rs`
//! (serve-only), generalized so every tier — serving, the lock-free
//! metrics registry ([`crate::obs::registry::AtomicHistogram`]) and the
//! exposition formats — shares **one** implementation of the bounds,
//! the bucket index function and the percentile interpolation.
//! `metrics::LatencyHistogram` is now a re-export of [`Histogram`], so
//! the public p50/p90/p99 API (and its edge-case behavior: empty → 0.0,
//! single-sample and all-equal exact via the `[min, max]` clamp) is
//! unchanged.

use std::time::Instant;

/// Number of latency buckets (fixed so histograms merge trivially).
pub const LAT_BUCKETS: usize = 64;
/// First bucket upper bound in milliseconds (1 µs).
pub const LAT_BASE_MS: f64 = 1e-3;
/// Geometric bucket growth; 64 buckets cover ~1 µs to ~15 s.
pub const LAT_RATIO: f64 = 1.3;

/// Upper bound of bucket `i` in milliseconds.
pub fn bucket_bound(i: usize) -> f64 {
    LAT_BASE_MS * LAT_RATIO.powi(i as i32)
}

/// Bucket index for a sample of `ms` milliseconds.
pub fn bucket_of(ms: f64) -> usize {
    if ms <= LAT_BASE_MS {
        return 0;
    }
    let i = ((ms / LAT_BASE_MS).ln() / LAT_RATIO.ln()).ceil() as usize;
    i.min(LAT_BUCKETS - 1)
}

/// Fixed-bucket latency histogram with log-spaced bounds.
///
/// Bucket `i` covers `(base·r^(i-1), base·r^i]` milliseconds, with the
/// last bucket absorbing everything larger, so recording is O(1), the
/// memory footprint is constant, and two histograms (e.g. per scoring
/// thread) merge by adding counts. Percentiles interpolate linearly
/// inside the winning bucket and are clamped to the observed
/// `[min, max]`, which makes the empty (0.0), single-sample and
/// all-equal cases exact.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; LAT_BUCKETS],
    n: u64,
    sum_ms: f64,
    min_ms: f64,
    max_ms: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; LAT_BUCKETS],
            n: 0,
            sum_ms: 0.0,
            min_ms: f64::INFINITY,
            max_ms: 0.0,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a histogram from raw parts (the atomic registry variant
    /// snapshots into this type so the percentile math lives once).
    pub fn from_parts(
        counts: [u64; LAT_BUCKETS],
        n: u64,
        sum_ms: f64,
        min_ms: f64,
        max_ms: f64,
    ) -> Self {
        Histogram { counts, n, sum_ms, min_ms, max_ms }
    }

    /// Record one latency sample in milliseconds (negatives clamp to 0).
    pub fn record(&mut self, ms: f64) {
        let ms = ms.max(0.0);
        self.counts[bucket_of(ms)] += 1;
        self.n += 1;
        self.sum_ms += ms;
        self.min_ms = self.min_ms.min(ms);
        self.max_ms = self.max_ms.max(ms);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum_ms += other.sum_ms;
        self.min_ms = self.min_ms.min(other.min_ms);
        self.max_ms = self.max_ms.max(other.max_ms);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_ms / self.n as f64
        }
    }

    pub fn max_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max_ms
        }
    }

    /// Percentile `p` in `[0, 100]` in milliseconds (0.0 when empty).
    /// Resolution is one bucket (~±15%); exact for single-sample and
    /// all-equal inputs thanks to the `[min, max]` clamp.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0) * self.n as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen + c;
            if (next as f64) >= target {
                let lo = if i == 0 { 0.0 } else { bucket_bound(i - 1) };
                // the last bucket is unbounded above: close it with the
                // observed max so p100 reports the true extreme
                let hi = if i == LAT_BUCKETS - 1 { self.max_ms } else { bucket_bound(i) };
                let frac = ((target - seen as f64) / c as f64).clamp(0.0, 1.0);
                return (lo + frac * (hi - lo)).clamp(self.min_ms, self.max_ms);
            }
            seen = next;
        }
        self.max_ms
    }

    /// `(p50, p90, p99, mean)` in milliseconds — the serving report row.
    pub fn summary(&self) -> (f64, f64, f64, f64) {
        (self.percentile(50.0), self.percentile(90.0), self.percentile(99.0), self.mean_ms())
    }
}

/// Wall-clock throughput meter: count events, read events/second.
#[derive(Clone, Debug)]
pub struct QpsMeter {
    started: Instant,
    n: u64,
}

impl Default for QpsMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl QpsMeter {
    pub fn new() -> Self {
        QpsMeter { started: Instant::now(), n: 0 }
    }

    /// Count `k` completed events.
    pub fn hit(&mut self, k: u64) {
        self.n += k;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Events per second since construction.
    pub fn qps(&self) -> f64 {
        let secs = self.elapsed_secs();
        if secs <= 0.0 {
            0.0
        } else {
            self.n as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_monotone() {
        for i in 1..LAT_BUCKETS {
            assert!(bucket_bound(i) > bucket_bound(i - 1));
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(f64::MAX), LAT_BUCKETS - 1);
        // every bound lands in its own bucket
        for i in 0..LAT_BUCKETS {
            assert!(bucket_of(bucket_bound(i)) <= i.max(1));
        }
    }

    #[test]
    fn from_parts_round_trips_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=50 {
            h.record(i as f64 * 0.1);
        }
        let clone = Histogram::from_parts(h.counts, h.n, h.sum_ms, h.min_ms, h.max_ms);
        assert_eq!(clone.count(), h.count());
        assert_eq!(clone.percentile(50.0), h.percentile(50.0));
        assert_eq!(clone.summary(), h.summary());
    }
}
