//! Cost-model simulation of the baseline systems in Table 6/13.
//!
//! XDL, FAE, DLRM and Hotline are closed/unavailable systems the paper
//! quotes published numbers for; per the substitution rule (DESIGN.md §4)
//! we reproduce the *comparison* with a calibrated analytic cost model
//! rather than pretending to rerun them. Rows produced from this module
//! are always labelled `(sim)` in experiment output.

mod baselines;

pub use baselines::{BaselineSystem, SimCostModel};
