//! Analytic per-step cost models for the compared training systems.
//!
//! Model: `t_step = t_dense(b) + t_embed(b) + t_comm(b, gpus)` with
//! constants fitted to the paper's published minutes (Tables 6 and 13).
//! Each baseline differs in how embedding traffic and communication scale:
//!
//! * **XDL** — parameter-server style; embedding exchange dominates, poor
//!   scaling with batch, multi-GPU adds near-linear comm cost.
//! * **FAE** — hot-embedding-aware layout: ~40% of XDL's embedding
//!   traffic.
//! * **DLRM** — model-parallel embedding tables; better batch scaling but
//!   heavy all-to-all when scaling GPUs.
//! * **Hotline** — pipelined dispatch of hot/cold ids; lowest constant.
//!
//! The paper's key point survives any reasonable constant choice: these
//! systems buy speed with more GPUs while capping at 4K batch, whereas
//! CowClip scales the batch on one device.

/// Which published system to model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineSystem {
    Xdl,
    Fae,
    Dlrm,
    Hotline,
}

impl BaselineSystem {
    pub const ALL: [BaselineSystem; 4] = [
        BaselineSystem::Xdl,
        BaselineSystem::Fae,
        BaselineSystem::Dlrm,
        BaselineSystem::Hotline,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            BaselineSystem::Xdl => "XDL",
            BaselineSystem::Fae => "FAE",
            BaselineSystem::Dlrm => "DLRM",
            BaselineSystem::Hotline => "Hotline",
        }
    }

    /// (AUC %, logloss) the paper reports for the system on Criteo —
    /// quoted, not computed; the systems cap at small batch sizes with
    /// visibly worse accuracy than CowClip.
    pub fn criteo_quality(&self) -> (f64, f64) {
        match self {
            BaselineSystem::Xdl => (80.2, 0.452),
            BaselineSystem::Fae => (80.2, 0.452),
            BaselineSystem::Dlrm => (79.8, 0.456),
            BaselineSystem::Hotline => (79.8, 0.456),
        }
    }

    /// Largest batch the system scales to in the paper (beyond which it
    /// loses accuracy), and the GPUs used per batch size {1K:1, 2K:2, 4K:4}.
    pub fn max_batch_paper(&self) -> usize {
        4096 // 4K for all four baselines, per Table 6 footnotes
    }
}

/// Fitted cost model producing per-epoch minutes on the paper's testbed.
#[derive(Clone, Copy, Debug)]
pub struct SimCostModel {
    /// Dense compute minutes per epoch at batch 1K on 1 GPU.
    pub dense_min: f64,
    /// Embedding/dispatch minutes per epoch at batch 1K on 1 GPU.
    pub embed_min: f64,
    /// Communication minutes per epoch per extra GPU.
    pub comm_min_per_gpu: f64,
    /// How embedding cost shrinks as batch doubles (0.5 = halves,
    /// 1.0 = flat). Captures dispatch-bound vs compute-bound behaviour.
    pub embed_batch_exponent: f64,
}

impl SimCostModel {
    /// Constants fitted to Table 6 (Criteo, total training minutes for
    /// 10 epochs; we model the total directly).
    pub fn for_system(sys: BaselineSystem) -> SimCostModel {
        match sys {
            // totals at (1K,1gpu)=196, (2K,2)=179, (4K,4)=160
            BaselineSystem::Xdl => SimCostModel {
                dense_min: 49.0,
                embed_min: 147.0,
                comm_min_per_gpu: 22.0,
                embed_batch_exponent: 0.28,
            },
            // (1K)=122, (2K,2)=116, (4K,4)=104
            BaselineSystem::Fae => SimCostModel {
                dense_min: 49.0,
                embed_min: 73.0,
                comm_min_per_gpu: 12.0,
                embed_batch_exponent: 0.2,
            },
            // (1K)=196, (2K,2)=133, (4K,4)=76
            BaselineSystem::Dlrm => SimCostModel {
                dense_min: 49.0,
                embed_min: 147.0,
                comm_min_per_gpu: 4.0,
                embed_batch_exponent: 0.95,
            },
            // (1K)=53, (2K,2)=45, (4K,4)=39
            BaselineSystem::Hotline => SimCostModel {
                dense_min: 20.0,
                embed_min: 33.0,
                comm_min_per_gpu: 5.0,
                embed_batch_exponent: 0.45,
            },
        }
    }

    /// Predicted total training minutes at `batch` (paper-scale labels,
    /// e.g. 1024 for "1K") on `gpus` devices.
    pub fn minutes(&self, batch: usize, gpus: usize) -> f64 {
        let s = batch as f64 / 1024.0;
        // dense compute amortizes near-linearly with batch (Fig. 1a)
        let dense = self.dense_min / s.min(8.0).max(1.0);
        let embed = self.embed_min / s.powf(self.embed_batch_exponent);
        let comm = self.comm_min_per_gpu * (gpus.saturating_sub(1)) as f64;
        dense + embed + comm
    }

    /// The paper's GPU ladder: batch 1K on 1 GPU, 2K on 2, 4K on 4.
    pub fn paper_gpus_for_batch(batch: usize) -> usize {
        (batch / 1024).clamp(1, 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitted_constants_land_near_paper_table6() {
        // (system, batch, gpus, paper minutes, tolerance)
        let rows = [
            (BaselineSystem::Xdl, 1024, 1, 196.0, 20.0),
            (BaselineSystem::Xdl, 2048, 2, 179.0, 25.0),
            (BaselineSystem::Xdl, 4096, 4, 160.0, 30.0),
            (BaselineSystem::Fae, 1024, 1, 122.0, 15.0),
            (BaselineSystem::Fae, 4096, 4, 104.0, 25.0),
            (BaselineSystem::Dlrm, 1024, 1, 196.0, 20.0),
            (BaselineSystem::Dlrm, 4096, 4, 76.0, 20.0),
            (BaselineSystem::Hotline, 1024, 1, 53.0, 8.0),
            (BaselineSystem::Hotline, 4096, 4, 39.0, 12.0),
        ];
        for (sys, batch, gpus, want, tol) in rows {
            let got = SimCostModel::for_system(sys).minutes(batch, gpus);
            assert!(
                (got - want).abs() < tol,
                "{}: b={batch} gpus={gpus}: {got:.0} vs paper {want}",
                sys.label()
            );
        }
    }

    #[test]
    fn who_wins_ordering_preserved() {
        // Hotline < FAE < XDL at 1K/1GPU (paper ordering)
        let at_1k = |s: BaselineSystem| SimCostModel::for_system(s).minutes(1024, 1);
        assert!(at_1k(BaselineSystem::Hotline) < at_1k(BaselineSystem::Fae));
        assert!(at_1k(BaselineSystem::Fae) < at_1k(BaselineSystem::Xdl));
    }

    #[test]
    fn gpu_ladder() {
        assert_eq!(SimCostModel::paper_gpus_for_batch(1024), 1);
        assert_eq!(SimCostModel::paper_gpus_for_batch(2048), 2);
        assert_eq!(SimCostModel::paper_gpus_for_batch(4096), 4);
    }
}
