//! Byte-level wire layer shared by distributed training, checkpoint
//! files, and the serving request path.
//!
//! - [`frame`] — length-prefixed `header ‖ payload` frames with magic,
//!   version, kind, and CRC-32 integrity, over any `Read`/`Write`.
//! - [`codec`] — little-endian encode/decode primitives and the
//!   versioned payload codecs: `Contribution` (with optional u16/u8
//!   sparse-gradient quantization), the worker handshake, and serving
//!   score messages. The checkpoint readers (`CCKP`/`CCKS`) stream
//!   through the same primitives.
//! - [`link`] — a reliable frame channel over any stream: CRC-corrupt
//!   frames are healed by a bounded Nack/Resend exchange instead of
//!   killing the connection.
//!
//! ## Protocol version 2
//!
//! PR 10 bumped [`frame::WIRE_VERSION`] from 1 to 2 for fault
//! tolerance. The changes relative to v1:
//!
//! - `Hello` carries two new trailing fields, `last_step` and
//!   `fingerprint`, turning the handshake into a versioned **rejoin**
//!   handshake (a reconnecting worker names the last step it applied
//!   and proves its config matches the run).
//! - `Welcome` carries the coordinator's last `committed` step, which
//!   the worker uses to replay forward deterministically before
//!   resuming.
//! - Two control frame kinds, `Nack` (11) and `Resend` (12), support
//!   bounded retransmission of corrupt frames inside [`link`].
//!
//! v1 and v2 payloads are not wire-compatible (the handshake grew), so
//! the version byte check refuses v1 peers outright rather than
//! negotiating down.

pub mod codec;
pub mod frame;
pub mod link;

pub use codec::{
    contribution_wire_len, decode_contribution, encode_contribution, Compression, ContribStats,
    Hello, Welcome,
};
pub use frame::{
    read_frame, read_frame_checked, write_frame, FrameKind, FrameRead, FRAME_HEADER_LEN,
    MAX_FRAME_LEN,
};
pub use link::FrameLink;
