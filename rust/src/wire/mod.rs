//! Byte-level wire layer shared by distributed training, checkpoint
//! files, and the serving request path.
//!
//! - [`frame`] — length-prefixed `header ‖ payload` frames with magic,
//!   version, kind, and CRC-32 integrity, over any `Read`/`Write`.
//! - [`codec`] — little-endian encode/decode primitives and the
//!   versioned payload codecs: `Contribution` (with optional u16/u8
//!   sparse-gradient quantization), the worker handshake, and serving
//!   score messages. The checkpoint readers (`CCKP`/`CCKS`) stream
//!   through the same primitives.

pub mod codec;
pub mod frame;

pub use codec::{
    contribution_wire_len, decode_contribution, encode_contribution, Compression, ContribStats,
    Hello, Welcome,
};
pub use frame::{read_frame, write_frame, FrameKind, FRAME_HEADER_LEN, MAX_FRAME_LEN};
