//! Shared byte-level codec for the distributed wire protocol, checkpoint
//! files, and serving's request path.
//!
//! Everything that crosses a socket or lives in a `CCKS`/`CCKP` file goes
//! through the little-endian primitives here: `put_*` writers over a
//! `Vec<u8>`, bounds-checked [`Reader`] decoding, CRC-32 (IEEE)
//! integrity, and the versioned payload codecs for [`Contribution`],
//! the worker handshake, and serving score messages. Centralising the
//! layer means the reducer, the checkpoint store, and the serve
//! front-end cannot drift apart on byte layout.
//!
//! # Compression
//!
//! [`encode_contribution`] optionally quantizes *sparse gradient values*
//! to u16 or u8 codes (symmetric linear, per-tensor scale). Everything
//! else — touched-id lists, per-id counts, dense MLP gradients, the
//! loss/weight scalars — is always lossless, so the clip thresholds and
//! update *structure* stay exact and only sparse-gradient magnitudes see
//! quantization noise. Workers compensate that noise with per-rank
//! error-feedback residuals (see `coordinator::dist`), computed with the
//! same [`quant_code`] / [`dequant`] primitives the encoder uses, so the
//! residual is exactly the rounding error of the bytes on the wire.
//!
//! With [`Compression::None`] the payload is pure little-endian f32/u32
//! words: encode → decode round-trips bitwise, which is what lets the
//! distributed path reproduce the sequential trainer bit for bit.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::allreduce::Contribution;
use crate::serve::{Request, Scored};
use crate::tensor::{GradTensor, SparseRows, Tensor};

/// Version byte leading every [`Contribution`] payload.
pub const CONTRIB_VERSION: u8 = 1;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3 polynomial, reflected) — frame integrity.
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes`; the check value of `b"123456789"` is
/// `0xCBF4_3926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Little-endian writers over a growable buffer.
// ---------------------------------------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// LEB128 unsigned varint: 7 value bits per byte, high bit = continue.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

// ---------------------------------------------------------------------------
// Bounds-checked reader over a decoded payload.
// ---------------------------------------------------------------------------

/// Cursor over a byte slice whose every access is bounds-checked: a
/// truncated or forged payload surfaces as an error, never a panic.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("codec: length overflow")?;
        let slice = self.buf.get(self.pos..end).with_context(|| {
            format!(
                "codec: truncated payload (need {n} bytes at offset {}, have {})",
                self.pos,
                self.buf.len()
            )
        })?;
        self.pos = end;
        Ok(slice)
    }

    pub fn u8(&mut self) -> Result<u8> {
        let [b]: [u8; 1] = self.take(1)?.try_into().context("codec: u8")?;
        Ok(b)
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().context("codec: u16")?))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().context("codec: u32")?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().context("codec: u64")?))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().context("codec: i32")?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().context("codec: f32")?))
    }

    /// LEB128 unsigned varint (up to 10 bytes).
    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        for shift in (0..64).step_by(7) {
            let b = self.u8()?;
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        bail!("codec: varint longer than 10 bytes")
    }

    /// Consume `n` little-endian f32 words.
    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).context("codec: f32 vec overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Consume `n` little-endian u32 words.
    pub fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = self.take(n.checked_mul(4).context("codec: u32 vec overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the payload was consumed exactly.
    pub fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "codec: {} trailing bytes after payload",
            self.remaining()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// std::io mirrors of the primitives — the checkpoint readers stream from
// a `File` instead of decoding an in-memory payload.
// ---------------------------------------------------------------------------

pub fn write_u32_le<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("codec: write u32")
}

pub fn write_u64_le<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("codec: write u64")
}

pub fn read_u32_le<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("codec: read u32")?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64_le<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("codec: read u64")?;
    Ok(u64::from_le_bytes(b))
}

/// Read `n` little-endian f32 words from a stream.
pub fn read_f32_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n.checked_mul(4).context("codec: f32 vec overflow")?];
    r.read_exact(&mut bytes).context("codec: read f32 block")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read `n` little-endian u32 words from a stream.
pub fn read_u32_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n.checked_mul(4).context("codec: u32 vec overflow")?];
    r.read_exact(&mut bytes).context("codec: read u32 block")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ---------------------------------------------------------------------------
// Compression mode + quantization primitives.
// ---------------------------------------------------------------------------

/// Wire compression applied to sparse gradient values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// Raw little-endian f32 everywhere: bitwise round-trip.
    None,
    /// 16-bit symmetric linear quantization (Q = 32767).
    U16,
    /// 8-bit symmetric linear quantization (Q = 127).
    U8,
}

impl Compression {
    pub fn tag(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::U16 => 1,
            Compression::U8 => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Result<Compression> {
        match tag {
            0 => Ok(Compression::None),
            1 => Ok(Compression::U16),
            2 => Ok(Compression::U8),
            other => bail!("codec: unknown compression tag {other}"),
        }
    }

    /// Quantization level count `Q` (codes span `[-Q, Q]`), or `None`
    /// for the lossless mode.
    pub fn levels(self) -> Option<u32> {
        match self {
            Compression::None => None,
            Compression::U16 => Some(32767),
            Compression::U8 => Some(127),
        }
    }
}

impl std::str::FromStr for Compression {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Compression> {
        match s {
            "none" => Ok(Compression::None),
            "u16" => Ok(Compression::U16),
            "u8" => Ok(Compression::U8),
            other => bail!("unknown compression {other:?} (expected none|u16|u8)"),
        }
    }
}

impl std::fmt::Display for Compression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Compression::None => "none",
            Compression::U16 => "u16",
            Compression::U8 => "u8",
        })
    }
}

/// Per-tensor symmetric quantization scale: `max|v| / Q`, or `0.0` for
/// an all-zero tensor (every code is then 0).
pub fn quant_scale(vals: &[f32], q: u32) -> f32 {
    let max_abs = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        0.0
    } else {
        max_abs / q as f32
    }
}

/// Quantization code of one value: `clamp(round(v / scale), -Q, Q)`.
///
/// Error feedback in `coordinator::dist` calls this (and [`dequant`])
/// with the exact arguments the encoder used, so the residual it folds
/// forward is bit-for-bit the rounding error the coordinator saw.
pub fn quant_code(v: f32, scale: f32, q: u32) -> i32 {
    if scale == 0.0 {
        return 0;
    }
    let qf = q as f32;
    (v / scale).round().clamp(-qf, qf) as i32
}

/// Reconstruction of a quantization code.
pub fn dequant(code: i32, scale: f32) -> f32 {
    code as f32 * scale
}

// ---------------------------------------------------------------------------
// Sparse-section helpers.
// ---------------------------------------------------------------------------

fn put_ids(out: &mut Vec<u8>, ids: &[u32], compress: Compression) {
    if compress == Compression::None {
        for &id in ids {
            put_u32(out, id);
        }
    } else {
        // Ids are sorted strictly ascending: first absolute, then
        // deltas, varint-coded. Lossless.
        let mut prev = 0u64;
        for (k, &id) in ids.iter().enumerate() {
            let v = id as u64;
            put_varint(out, if k == 0 { v } else { v - prev });
            prev = v;
        }
    }
}

fn read_ids(r: &mut Reader, nnz: usize, n_rows: usize, compress: Compression) -> Result<Vec<u32>> {
    if compress == Compression::None {
        return r.u32_vec(nnz);
    }
    let mut ids = Vec::with_capacity(nnz.min(r.remaining()));
    let mut prev = 0u64;
    for k in 0..nnz {
        let delta = r.varint()?;
        let v = if k == 0 {
            delta
        } else {
            prev.checked_add(delta).context("codec: row id overflow")?
        };
        ensure!(
            v < n_rows as u64 && v <= u32::MAX as u64,
            "codec: row id {v} out of range (n_rows {n_rows})"
        );
        ids.push(v as u32);
        prev = v;
    }
    Ok(ids)
}

fn put_count_vals(out: &mut Vec<u8>, vals: &[f32], compress: Compression) {
    if compress == Compression::None {
        for &v in vals {
            put_f32(out, v);
        }
        return;
    }
    // Counts are small non-negative integers in practice; varint-code
    // them when that round-trips exactly, raw f32 otherwise. Either way
    // the decode is lossless.
    let integral = vals
        .iter()
        .all(|&v| v >= 0.0 && v <= (1u64 << 63) as f32 && v.fract() == 0.0);
    put_u8(out, u8::from(integral));
    if integral {
        for &v in vals {
            put_varint(out, v as u64);
        }
    } else {
        for &v in vals {
            put_f32(out, v);
        }
    }
}

fn read_count_vals(r: &mut Reader, n: usize, compress: Compression) -> Result<Vec<f32>> {
    if compress == Compression::None {
        return r.f32_vec(n);
    }
    match r.u8()? {
        1 => {
            let mut vals = Vec::with_capacity(n.min(r.remaining()));
            for _ in 0..n {
                vals.push(r.varint()? as f32);
            }
            Ok(vals)
        }
        0 => r.f32_vec(n),
        other => bail!("codec: unknown count-value encoding {other}"),
    }
}

fn put_quantized(out: &mut Vec<u8>, vals: &[f32], q: u32) {
    let scale = quant_scale(vals, q);
    put_f32(out, scale);
    for &v in vals {
        let stored = (quant_code(v, scale, q) + q as i32) as u32;
        if q > u8::MAX as u32 {
            put_u16(out, stored as u16);
        } else {
            put_u8(out, stored as u8);
        }
    }
}

fn read_quantized(r: &mut Reader, n: usize, q: u32) -> Result<Vec<f32>> {
    let scale = r.f32()?;
    ensure!(scale.is_finite() && scale >= 0.0, "codec: bad quant scale {scale}");
    let cap = 2 * q;
    if q > u8::MAX as u32 {
        let bytes = r.take(n.checked_mul(2).context("codec: quantized vals overflow")?)?;
        let mut vals = Vec::with_capacity(n);
        for c in bytes.chunks_exact(2) {
            let stored = u16::from_le_bytes([c[0], c[1]]) as u32;
            ensure!(stored <= cap, "codec: quant code {stored} out of range");
            vals.push(dequant(stored as i32 - q as i32, scale));
        }
        Ok(vals)
    } else {
        let bytes = r.take(n)?;
        let mut vals = Vec::with_capacity(n);
        for &b in bytes {
            let stored = b as u32;
            ensure!(stored <= cap, "codec: quant code {stored} out of range");
            vals.push(dequant(stored as i32 - q as i32, scale));
        }
        Ok(vals)
    }
}

fn put_sparse_counts(out: &mut Vec<u8>, s: &SparseRows, compress: Compression) -> Result<()> {
    ensure!(s.nnz() <= u32::MAX as usize, "codec: counts nnz overflow");
    put_u64(out, s.n_rows() as u64);
    put_u32(out, s.d() as u32);
    put_u32(out, s.nnz() as u32);
    put_ids(out, s.ids(), compress);
    put_count_vals(out, s.vals(), compress);
    Ok(())
}

fn read_sparse_counts(r: &mut Reader, compress: Compression) -> Result<SparseRows> {
    let n_rows = usize::try_from(r.u64()?).context("codec: counts n_rows")?;
    let d = r.u32()? as usize;
    ensure!(d > 0, "codec: counts d == 0");
    let nnz = r.u32()? as usize;
    ensure!(nnz <= n_rows, "codec: counts nnz {nnz} > n_rows {n_rows}");
    let ids = read_ids(r, nnz, n_rows, compress)?;
    let n = nnz.checked_mul(d).context("codec: counts vals overflow")?;
    let vals = read_count_vals(r, n, compress)?;
    SparseRows::validated(n_rows, d, ids, vals)
}

// ---------------------------------------------------------------------------
// Contribution payload (version 1).
// ---------------------------------------------------------------------------

/// Byte accounting of one encoded / decoded [`Contribution`].
///
/// `raw_bytes` is the [`Compression::None`] length of the same payload
/// (the traffic-model numerator); `wire_bytes` is what actually hit the
/// socket. The `sparse_*` pair restricts both to the sparse sections
/// (counts + sparse gradients) — the ≥4× compression gate is judged on
/// that ratio, since dense MLP gradients are never quantized.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContribStats {
    pub raw_bytes: u64,
    pub wire_bytes: u64,
    pub sparse_raw: u64,
    pub sparse_wire: u64,
}

impl ContribStats {
    pub fn add(&mut self, other: &ContribStats) {
        self.raw_bytes += other.raw_bytes;
        self.wire_bytes += other.wire_bytes;
        self.sparse_raw += other.sparse_raw;
        self.sparse_wire += other.sparse_wire;
    }
}

/// Exact encoded length, in bytes, of `c` under [`Compression::None`].
///
/// This is the *raw* on-wire size: the traffic model's per-merge byte
/// count and the numerator of the compression ratio. Kept alloc-free —
/// the reducer's hot merge path calls it per merge.
pub fn contribution_wire_len(c: &Contribution) -> u64 {
    // version + compression tag + loss_weighted + weight
    let mut n = 1 + 1 + 4 + 4u64;
    // counts: n_rows u64, d u32, nnz u32, raw u32 ids, raw f32 vals
    n += 8 + 4 + 4;
    n += c.counts.nnz() as u64 * 4;
    n += c.counts.vals().len() as u64 * 4;
    // grad count
    n += 4;
    for g in &c.grads {
        match g {
            GradTensor::Dense(t) => {
                // kind, ndim u32, dims u64 each, raw f32 data
                n += 1 + 4 + 8 * t.shape().len() as u64 + 4 * t.len() as u64;
            }
            GradTensor::Sparse(s) => {
                // kind, n_rows u64, d u32, ids-mode u8
                n += 1 + 8 + 4 + 1;
                let same = s.n_rows() == c.counts.n_rows() && s.ids() == c.counts.ids();
                if !same {
                    // nnz u32 + raw u32 ids
                    n += 4 + s.ids().len() as u64 * 4;
                }
                // value-encoding u8 + raw f32 vals
                n += 1 + s.vals().len() as u64 * 4;
            }
        }
    }
    n
}

/// Encode a [`Contribution`] as a versioned payload.
///
/// Sparse gradients whose id list equals the counts' id list (the
/// normal case: every per-table gradient and the counts are indexed by
/// the same touched ids) omit their ids entirely and reference the
/// counts section instead.
pub fn encode_contribution(
    c: &Contribution,
    compress: Compression,
) -> Result<(Vec<u8>, ContribStats)> {
    let raw_bytes = contribution_wire_len(c);
    let mut out = Vec::with_capacity(raw_bytes as usize);
    put_u8(&mut out, CONTRIB_VERSION);
    put_u8(&mut out, compress.tag());
    put_f32(&mut out, c.loss_weighted);
    put_f32(&mut out, c.weight);

    let mut sparse_raw = 0u64;
    let mut sparse_wire = 0u64;

    let start = out.len();
    put_sparse_counts(&mut out, &c.counts, compress)?;
    sparse_raw += c.counts.payload_bytes();
    sparse_wire += (out.len() - start) as u64;

    ensure!(c.grads.len() <= u32::MAX as usize, "codec: grad count overflow");
    put_u32(&mut out, c.grads.len() as u32);
    for g in &c.grads {
        match g {
            GradTensor::Dense(t) => {
                put_u8(&mut out, 0);
                let shape = t.shape();
                ensure!(shape.len() <= 8, "codec: dense grad rank {} > 8", shape.len());
                put_u32(&mut out, shape.len() as u32);
                for &dim in shape {
                    put_u64(&mut out, dim as u64);
                }
                for &v in t.as_f32()? {
                    put_f32(&mut out, v);
                }
            }
            GradTensor::Sparse(s) => {
                put_u8(&mut out, 1);
                let start = out.len();
                put_u64(&mut out, s.n_rows() as u64);
                put_u32(&mut out, s.d() as u32);
                let same = s.n_rows() == c.counts.n_rows() && s.ids() == c.counts.ids();
                put_u8(&mut out, u8::from(same));
                if !same {
                    ensure!(s.nnz() <= u32::MAX as usize, "codec: sparse grad nnz overflow");
                    put_u32(&mut out, s.nnz() as u32);
                    put_ids(&mut out, s.ids(), compress);
                }
                match compress.levels() {
                    None => {
                        put_u8(&mut out, 0);
                        for &v in s.vals() {
                            put_f32(&mut out, v);
                        }
                    }
                    Some(q) => {
                        put_u8(&mut out, compress.tag());
                        put_quantized(&mut out, s.vals(), q);
                    }
                }
                sparse_raw += s.payload_bytes();
                sparse_wire += (out.len() - start) as u64;
            }
        }
    }

    let stats = ContribStats {
        raw_bytes,
        wire_bytes: out.len() as u64,
        sparse_raw,
        sparse_wire,
    };
    Ok((out, stats))
}

/// Decode a [`Contribution`] payload produced by [`encode_contribution`].
pub fn decode_contribution(buf: &[u8]) -> Result<(Contribution, ContribStats)> {
    let mut r = Reader::new(buf);
    let version = r.u8()?;
    ensure!(
        version == CONTRIB_VERSION,
        "codec: contribution payload v{version}, supported v{CONTRIB_VERSION}"
    );
    let compress = Compression::from_tag(r.u8()?)?;
    let loss_weighted = r.f32()?;
    let weight = r.f32()?;

    let mut sparse_raw = 0u64;
    let mut sparse_wire = 0u64;

    let start = r.pos();
    let counts = read_sparse_counts(&mut r, compress)?;
    sparse_raw += counts.payload_bytes();
    sparse_wire += (r.pos() - start) as u64;

    let n_grads = r.u32()? as usize;
    ensure!(n_grads <= 65536, "codec: implausible grad count {n_grads}");
    let mut grads = Vec::with_capacity(n_grads);
    for _ in 0..n_grads {
        match r.u8()? {
            0 => {
                let ndim = r.u32()? as usize;
                ensure!(ndim <= 8, "codec: dense grad rank {ndim} > 8");
                let mut shape = Vec::with_capacity(ndim);
                let mut numel = 1usize;
                for _ in 0..ndim {
                    let dim = usize::try_from(r.u64()?).context("codec: dense grad dim")?;
                    numel = numel.checked_mul(dim).context("codec: dense grad numel overflow")?;
                    shape.push(dim);
                }
                let data = r.f32_vec(numel)?;
                grads.push(GradTensor::Dense(Tensor::f32(shape, data)));
            }
            1 => {
                let start = r.pos();
                let n_rows = usize::try_from(r.u64()?).context("codec: sparse grad n_rows")?;
                let d = r.u32()? as usize;
                ensure!(d > 0, "codec: sparse grad d == 0");
                let ids = match r.u8()? {
                    1 => {
                        ensure!(
                            n_rows == counts.n_rows(),
                            "codec: shared-id grad n_rows {n_rows} != counts {}",
                            counts.n_rows()
                        );
                        counts.ids().to_vec()
                    }
                    0 => {
                        let nnz = r.u32()? as usize;
                        ensure!(nnz <= n_rows, "codec: sparse grad nnz {nnz} > n_rows {n_rows}");
                        read_ids(&mut r, nnz, n_rows, compress)?
                    }
                    other => bail!("codec: unknown ids mode {other}"),
                };
                let n = ids.len().checked_mul(d).context("codec: sparse grad vals overflow")?;
                let val_enc = r.u8()?;
                let vals = match Compression::from_tag(val_enc)?.levels() {
                    None => r.f32_vec(n)?,
                    Some(q) => read_quantized(&mut r, n, q)?,
                };
                let s = SparseRows::validated(n_rows, d, ids, vals)?;
                sparse_raw += s.payload_bytes();
                sparse_wire += (r.pos() - start) as u64;
                grads.push(GradTensor::Sparse(s));
            }
            other => bail!("codec: unknown grad kind {other}"),
        }
    }
    r.done()?;

    let c = Contribution {
        grads,
        counts,
        loss_weighted,
        weight,
    };
    let stats = ContribStats {
        raw_bytes: contribution_wire_len(&c),
        wire_bytes: buf.len() as u64,
        sparse_raw,
        sparse_wire,
    };
    Ok((c, stats))
}

// ---------------------------------------------------------------------------
// Handshake payloads.
// ---------------------------------------------------------------------------

/// Worker → coordinator handshake: identity plus the run parameters the
/// coordinator cross-checks so mismatched processes fail fast instead of
/// silently diverging.
///
/// Wire v2 made this double as the **rejoin** handshake: `last_step` is
/// the worker's last fully applied step (0 for a cold start) and
/// `fingerprint` is `TrainConfig::fingerprint()`, so a reconnecting
/// replica whose config drifted from the run is refused instead of
/// silently corrupting the reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub rank: u32,
    pub ranks: u32,
    pub batch: u64,
    pub seed: u64,
    pub total_steps: u64,
    /// Last step this replica has applied; 0 on a cold start.
    pub last_step: u64,
    /// `TrainConfig::fingerprint()` of the worker's config.
    pub fingerprint: u64,
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 4 + 8 * 5);
    put_u32(&mut out, h.rank);
    put_u32(&mut out, h.ranks);
    put_u64(&mut out, h.batch);
    put_u64(&mut out, h.seed);
    put_u64(&mut out, h.total_steps);
    put_u64(&mut out, h.last_step);
    put_u64(&mut out, h.fingerprint);
    out
}

pub fn decode_hello(buf: &[u8]) -> Result<Hello> {
    let mut r = Reader::new(buf);
    let h = Hello {
        rank: r.u32()?,
        ranks: r.u32()?,
        batch: r.u64()?,
        seed: r.u64()?,
        total_steps: r.u64()?,
        last_step: r.u64()?,
        fingerprint: r.u64()?,
    };
    r.done()?;
    Ok(h)
}

/// Coordinator → worker handshake reply: the negotiated wire settings.
///
/// Wire v2 added `committed`, the coordinator's last committed step: a
/// rejoining worker replays `last_step+1..=committed` locally from its
/// deterministic batch stream before resuming the socket protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Welcome {
    pub compress: Compression,
    pub total_steps: u64,
    /// The coordinator's last committed step (0 before the first).
    pub committed: u64,
}

pub fn encode_welcome(w: &Welcome) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 8 + 8);
    put_u8(&mut out, w.compress.tag());
    put_u64(&mut out, w.total_steps);
    put_u64(&mut out, w.committed);
    out
}

pub fn decode_welcome(buf: &[u8]) -> Result<Welcome> {
    let mut r = Reader::new(buf);
    let w = Welcome {
        compress: Compression::from_tag(r.u8()?)?,
        total_steps: r.u64()?,
        committed: r.u64()?,
    };
    r.done()?;
    Ok(w)
}

/// Error frames carry a UTF-8 message.
pub fn encode_error(msg: &str) -> Vec<u8> {
    msg.as_bytes().to_vec()
}

pub fn decode_error(buf: &[u8]) -> Result<String> {
    String::from_utf8(buf.to_vec()).context("codec: error payload is not UTF-8")
}

// ---------------------------------------------------------------------------
// Serving score payloads — the network shape of `serve::Request` /
// `serve::Scored`, shared with the future socket front-end.
// ---------------------------------------------------------------------------

pub fn encode_score(req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 4 + 4 + 4 * (req.cat.len() + req.dense.len()));
    put_u64(&mut out, req.id);
    put_u32(&mut out, req.cat.len() as u32);
    put_u32(&mut out, req.dense.len() as u32);
    for &c in &req.cat {
        put_i32(&mut out, c);
    }
    for &v in &req.dense {
        put_f32(&mut out, v);
    }
    out
}

pub fn decode_score(buf: &[u8]) -> Result<Request> {
    let mut r = Reader::new(buf);
    let id = r.u64()?;
    let n_cat = r.u32()? as usize;
    let n_dense = r.u32()? as usize;
    ensure!(
        n_cat <= 4096 && n_dense <= 4096,
        "codec: implausible score-request arity ({n_cat} cat, {n_dense} dense)"
    );
    let cat_bytes = r.take(n_cat * 4)?;
    let cat = cat_bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let dense = r.f32_vec(n_dense)?;
    r.done()?;
    Ok(Request { id, cat, dense })
}

pub fn encode_scored(s: &Scored) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 4 + 4);
    put_u64(&mut out, s.id);
    put_f32(&mut out, s.logit);
    put_f32(&mut out, s.prob);
    out
}

pub fn decode_scored(buf: &[u8]) -> Result<Scored> {
    let mut r = Reader::new(buf);
    let s = Scored {
        id: r.u64()?,
        logit: r.f32()?,
        prob: r.f32()?,
    };
    r.done()?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_contrib_eq(a: &Contribution, b: &Contribution) {
        assert_eq!(a.loss_weighted.to_bits(), b.loss_weighted.to_bits());
        assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.grads.len(), b.grads.len());
        for (ga, gb) in a.grads.iter().zip(&b.grads) {
            match (ga, gb) {
                (GradTensor::Dense(ta), GradTensor::Dense(tb)) => assert_eq!(ta, tb),
                (GradTensor::Sparse(sa), GradTensor::Sparse(sb)) => assert_eq!(sa, sb),
                other => panic!("grad kind mismatch: {other:?}"),
            }
        }
    }

    /// Small mixed contribution: an embedding grad sharing the counts'
    /// ids, a wide grad with its own ids, and a dense MLP grad.
    fn sample_contribution() -> Contribution {
        let counts = SparseRows::new(100, 1, vec![3, 7, 42], vec![1.0, 2.0, 5.0]);
        let embed = SparseRows::new(
            100,
            4,
            vec![3, 7, 42],
            vec![
                0.5, -0.25, 0.125, -1.5, 2.0, -0.75, 0.0625, -0.5, 1.0, 0.25, -2.0, 0.375,
            ],
        );
        let wide = SparseRows::new(100, 1, vec![3, 9], vec![0.75, -0.375]);
        let dense = Tensor::f32(vec![2, 3], vec![0.1, -0.2, 0.3, -0.4, 0.5, -0.6]);
        Contribution {
            grads: vec![
                GradTensor::Sparse(embed),
                GradTensor::Sparse(wide),
                GradTensor::Dense(dense),
            ],
            counts,
            loss_weighted: 0.693,
            weight: 0.5,
        }
    }

    /// Larger contribution with ids shared across all sparse sections —
    /// the trainer-path shape the compression-ratio gate is judged on.
    fn wide_contribution() -> Contribution {
        let nnz = 256usize;
        let ids: Vec<u32> = (0..nnz as u32).map(|i| i * 3).collect();
        let embed_vals: Vec<f32> = (0..nnz * 10)
            .map(|i| ((i as f32) * 0.37).sin() * 0.01)
            .collect();
        let wide_vals: Vec<f32> = (0..nnz).map(|i| ((i as f32) * 0.11).cos() * 0.02).collect();
        let count_vals: Vec<f32> = (0..nnz).map(|i| (i % 7 + 1) as f32).collect();
        let n_rows = 1024;
        Contribution {
            grads: vec![
                GradTensor::Sparse(SparseRows::new(n_rows, 10, ids.clone(), embed_vals)),
                GradTensor::Sparse(SparseRows::new(n_rows, 1, ids.clone(), wide_vals)),
            ],
            counts: SparseRows::new(n_rows, 1, ids, count_vals),
            loss_weighted: 0.25,
            weight: 1.0,
        }
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn varint_roundtrip() {
        let cases = [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf);
            assert_eq!(r.varint().unwrap(), v);
            r.done().unwrap();
        }
    }

    #[test]
    fn reader_rejects_truncation_and_trailing() {
        let short = [1u8, 2, 3];
        let mut r = Reader::new(&short);
        assert!(r.u32().is_err());
        let buf = [1u8, 0, 0, 0, 9];
        let mut r = Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 1);
        assert!(r.done().is_err());
    }

    #[test]
    fn contribution_roundtrip_none_is_bitwise() {
        let c = sample_contribution();
        let (buf, stats) = encode_contribution(&c, Compression::None).unwrap();
        assert_eq!(stats.wire_bytes, buf.len() as u64);
        assert_eq!(stats.raw_bytes, stats.wire_bytes);
        assert_eq!(contribution_wire_len(&c), buf.len() as u64);
        let (back, dstats) = decode_contribution(&buf).unwrap();
        assert_contrib_eq(&c, &back);
        assert_eq!(stats, dstats);
    }

    #[test]
    fn contribution_roundtrip_u8_structure_lossless_values_bounded() {
        let c = sample_contribution();
        let (buf, _) = encode_contribution(&c, Compression::U8).unwrap();
        let (back, _) = decode_contribution(&buf).unwrap();
        // Structure (ids, counts, dense grads, scalars) is lossless.
        assert_eq!(back.counts, c.counts);
        assert_eq!(back.loss_weighted.to_bits(), c.loss_weighted.to_bits());
        for (ga, gb) in c.grads.iter().zip(&back.grads) {
            match (ga, gb) {
                (GradTensor::Dense(ta), GradTensor::Dense(tb)) => assert_eq!(ta, tb),
                (GradTensor::Sparse(sa), GradTensor::Sparse(sb)) => {
                    assert_eq!(sa.ids(), sb.ids());
                    assert_eq!(sa.n_rows(), sb.n_rows());
                    // Values are within half a quantization step.
                    let q = Compression::U8.levels().unwrap();
                    let scale = quant_scale(sa.vals(), q);
                    for (&va, &vb) in sa.vals().iter().zip(sb.vals()) {
                        assert!(
                            (va - vb).abs() <= 0.5 * scale + 1e-7,
                            "|{va} - {vb}| > step/2 ({scale})"
                        );
                    }
                }
                other => panic!("grad kind mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn u16_is_tighter_than_u8() {
        let c = wide_contribution();
        let q16 = Compression::U16.levels().unwrap();
        let q8 = Compression::U8.levels().unwrap();
        for g in &c.grads {
            if let GradTensor::Sparse(s) = g {
                let s16 = quant_scale(s.vals(), q16);
                let s8 = quant_scale(s.vals(), q8);
                assert!(s16 < s8);
                for &v in s.vals() {
                    let e16 = (v - dequant(quant_code(v, s16, q16), s16)).abs();
                    assert!(e16 <= 0.5 * s16 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn u8_compression_hits_4x_on_sparse_sections() {
        let c = wide_contribution();
        let (buf, stats) = encode_contribution(&c, Compression::U8).unwrap();
        let (back, dstats) = decode_contribution(&buf).unwrap();
        // Ids and counts survive exactly.
        assert_eq!(back.counts, c.counts);
        assert_eq!(stats.sparse_raw, dstats.sparse_raw);
        assert_eq!(stats.sparse_wire, dstats.sparse_wire);
        let ratio = stats.sparse_raw as f64 / stats.sparse_wire as f64;
        assert!(ratio >= 4.0, "sparse compression ratio {ratio:.2} < 4.0");
        assert!(stats.wire_bytes < stats.raw_bytes);
    }

    #[test]
    fn shared_ids_are_omitted_from_the_wire() {
        let c = wide_contribution();
        let (with_sharing, _) = encode_contribution(&c, Compression::None).unwrap();
        // Same payload, but with the wide grad's ids perturbed so they
        // no longer match the counts: the encoding must grow by the
        // explicit id list.
        let mut ids: Vec<u32> = c.counts.ids().to_vec();
        let last = ids.pop().unwrap();
        ids.push(last + 1);
        let mut c2 = Contribution {
            grads: c.grads.clone(),
            counts: c.counts.clone(),
            loss_weighted: c.loss_weighted,
            weight: c.weight,
        };
        if let Some(GradTensor::Sparse(s)) = c2.grads.pop() {
            c2.grads.push(GradTensor::Sparse(SparseRows::new(
                1024,
                1,
                ids,
                s.vals().to_vec(),
            )));
        }
        let (without_sharing, _) = encode_contribution(&c2, Compression::None).unwrap();
        assert_eq!(without_sharing.len(), with_sharing.len() + 4 + 256 * 4);
        let (back, _) = decode_contribution(&with_sharing).unwrap();
        assert_contrib_eq(&c, &back);
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        let c = sample_contribution();
        let (mut buf, _) = encode_contribution(&c, Compression::None).unwrap();
        assert!(decode_contribution(&buf[..8]).is_err(), "truncation");
        buf[0] = 99;
        assert!(decode_contribution(&buf).is_err(), "bad version");
        buf[0] = CONTRIB_VERSION;
        buf[1] = 99;
        assert!(decode_contribution(&buf).is_err(), "bad compression tag");
    }

    #[test]
    fn hello_welcome_roundtrip() {
        let h = Hello {
            rank: 3,
            ranks: 4,
            batch: 1024,
            seed: 42,
            total_steps: 100,
            last_step: 17,
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
        };
        assert_eq!(decode_hello(&encode_hello(&h)).unwrap(), h);
        let w = Welcome {
            compress: Compression::U8,
            total_steps: 100,
            committed: 18,
        };
        assert_eq!(decode_welcome(&encode_welcome(&w)).unwrap(), w);
        assert!(decode_hello(&[1, 2, 3]).is_err());
    }

    #[test]
    fn score_roundtrip() {
        let req = Request {
            id: 7,
            cat: vec![1, -2, 300],
            dense: vec![0.5, -1.5],
        };
        let back = decode_score(&encode_score(&req)).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.cat, req.cat);
        assert_eq!(back.dense, req.dense);
        let s = Scored {
            id: 7,
            logit: 0.25,
            prob: 0.562,
        };
        let back = decode_scored(&encode_scored(&s)).unwrap();
        assert_eq!(back.id, s.id);
        assert_eq!(back.logit.to_bits(), s.logit.to_bits());
        assert_eq!(back.prob.to_bits(), s.prob.to_bits());
    }

    #[test]
    fn compression_parses_and_displays() {
        for (s, c) in [
            ("none", Compression::None),
            ("u16", Compression::U16),
            ("u8", Compression::U8),
        ] {
            assert_eq!(s.parse::<Compression>().unwrap(), c);
            assert_eq!(c.to_string(), s);
            assert_eq!(Compression::from_tag(c.tag()).unwrap(), c);
        }
        assert!("zstd".parse::<Compression>().is_err());
    }
}
