//! Reliable frame channel: bounded retransmission over a lossy stream.
//!
//! [`FrameLink`] wraps any `Read + Write` byte stream and upgrades the
//! frame protocol's CRC check from "hard error" to "heal within a
//! budget". The dist protocol is strict request/reply on every
//! connection, which makes the recovery rule simple:
//!
//! - On receiving a CRC-corrupt frame (the stream is still aligned —
//!   see [`read_frame_checked`]), send [`FrameKind::Nack`] and read
//!   again.
//! - On receiving a Nack, retransmit the last application frame sent,
//!   wrapped in [`FrameKind::Resend`] (original kind tag ‖ original
//!   payload) so a retransmission can never be mistaken for a fresh
//!   frame.
//! - After `budget` corrupt receptions of the same logical frame, give
//!   up with a named "retransmit budget exhausted" error; the dist
//!   layer then treats the peer as lost and runs its own recovery.
//!
//! Nack and Resend never escape this module: callers see exactly the
//! frame kinds they would have seen on a clean stream.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::frame::{read_frame_checked, write_frame, FrameKind, FrameRead};

/// A framed connection with bounded Nack/Resend retransmission.
pub struct FrameLink<S> {
    stream: S,
    /// Last application frame sent, kept so a peer Nack can be answered.
    last_sent: Option<(FrameKind, Vec<u8>)>,
    /// Corrupt receptions tolerated per logical frame before giving up.
    budget: u32,
    /// Retransmission events (Nacks sent + Resends performed) since the
    /// last [`FrameLink::drain_retransmits`] call.
    retransmits: u64,
}

impl<S: Read + Write> FrameLink<S> {
    pub fn new(stream: S, budget: u32) -> FrameLink<S> {
        FrameLink { stream, last_sent: None, budget, retransmits: 0 }
    }

    /// The wrapped stream (e.g. to adjust io deadlines).
    pub fn stream(&self) -> &S {
        &self.stream
    }

    pub fn stream_mut(&mut self) -> &mut S {
        &mut self.stream
    }

    pub fn into_stream(self) -> S {
        self.stream
    }

    /// Take (and reset) the retransmission-event count.
    pub fn drain_retransmits(&mut self) -> u64 {
        std::mem::take(&mut self.retransmits)
    }

    /// Send one application frame, remembering it for a possible resend.
    pub fn send(&mut self, kind: FrameKind, payload: &[u8]) -> Result<()> {
        self.last_sent = Some((kind, payload.to_vec()));
        write_frame(&mut self.stream, kind, payload)
    }

    /// Receive one application frame, transparently healing CRC-corrupt
    /// receptions (ours via Nack, the peer's via Resend) within the
    /// budget.
    pub fn recv(&mut self) -> Result<(FrameKind, Vec<u8>)> {
        let mut corrupt: u32 = 0;
        loop {
            match read_frame_checked(&mut self.stream)? {
                FrameRead::Frame(FrameKind::Nack, _) => {
                    let (kind, payload) = match &self.last_sent {
                        Some((k, p)) => (*k, p.clone()),
                        None => bail!("wire: peer Nacked but nothing has been sent on this link"),
                    };
                    let mut wrapped = Vec::with_capacity(1 + payload.len());
                    wrapped.push(kind.tag());
                    wrapped.extend_from_slice(&payload);
                    self.retransmits += 1;
                    write_frame(&mut self.stream, FrameKind::Resend, &wrapped)
                        .context("wire: retransmit after Nack")?;
                }
                FrameRead::Frame(FrameKind::Resend, wrapped) => {
                    let (tag, payload) = match wrapped.split_first() {
                        Some((t, p)) => (*t, p.to_vec()),
                        None => bail!("wire: empty Resend frame"),
                    };
                    let kind = FrameKind::from_tag(tag).context("wire: Resend inner kind")?;
                    return Ok((kind, payload));
                }
                FrameRead::Frame(kind, payload) => return Ok((kind, payload)),
                FrameRead::Corrupt { kind, got, want } => {
                    corrupt += 1;
                    if corrupt > self.budget {
                        bail!(
                            "wire: retransmit budget exhausted ({corrupt} corrupt {kind:?} \
                             frames > budget {}; last CRC got {got:#010x}, want {want:#010x})",
                            self.budget
                        );
                    }
                    self.retransmits += 1;
                    write_frame(&mut self.stream, FrameKind::Nack, &[])
                        .context("wire: send Nack for corrupt frame")?;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::os::unix::net::UnixStream;

    /// Corrupt one payload byte of the last frame in `buf`.
    fn flip_last_byte(buf: &mut [u8]) {
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
    }

    #[test]
    fn clean_frames_pass_through() {
        let (a, b) = UnixStream::pair().unwrap();
        let mut tx = FrameLink::new(a, 3);
        let mut rx = FrameLink::new(b, 3);
        tx.send(FrameKind::Contrib, b"payload").unwrap();
        let (kind, payload) = rx.recv().unwrap();
        assert_eq!(kind, FrameKind::Contrib);
        assert_eq!(payload, b"payload");
        assert_eq!(tx.drain_retransmits(), 0);
        assert_eq!(rx.drain_retransmits(), 0);
    }

    #[test]
    fn corrupt_frame_heals_via_nack_resend() {
        let (a, b) = UnixStream::pair().unwrap();
        let mut tx = FrameLink::new(a, 3);
        let mut rx = FrameLink::new(b, 3);
        // Send a frame whose on-wire bytes we then corrupt by writing a
        // pre-damaged copy directly, while `tx` still remembers the
        // clean original for the resend.
        let mut raw = Vec::new();
        write_frame(&mut raw, FrameKind::Contrib, b"gradient bytes").unwrap();
        flip_last_byte(&mut raw);
        tx.last_sent = Some((FrameKind::Contrib, b"gradient bytes".to_vec()));
        use std::io::Write as _;
        tx.stream_mut().write_all(&raw).unwrap();
        // rx sees the corrupt frame, Nacks; tx (blocked in recv) answers
        // the Nack with a Resend. Run rx in this thread, tx in another.
        let h = std::thread::spawn(move || {
            // tx waits for the Nack and serves the retransmission; the
            // subsequent Shutdown read returns the close-out frame.
            tx.recv()
        });
        let (kind, payload) = rx.recv().unwrap();
        assert_eq!(kind, FrameKind::Contrib);
        assert_eq!(payload, b"gradient bytes");
        assert_eq!(rx.drain_retransmits(), 1);
        // Unblock tx's recv with a clean frame.
        rx.send(FrameKind::Shutdown, &[]).unwrap();
        let (kind, _) = h.join().unwrap().unwrap();
        assert_eq!(kind, FrameKind::Shutdown);
    }

    #[test]
    fn budget_exhaustion_is_a_named_error() {
        // A stream of nothing but corrupt frames: with budget 2 the
        // third corrupt reception must fail by name. Use a Cursor so no
        // peer is needed (Nacks are written into the cursor's tail and
        // never answered; reads continue from the corrupt backlog).
        let mut raw = Vec::new();
        for _ in 0..4 {
            let mut one = Vec::new();
            write_frame(&mut one, FrameKind::Total, b"corrupted total").unwrap();
            flip_last_byte(&mut one);
            raw.extend_from_slice(&one);
        }
        let mut link = FrameLink::new(Cursor::new(raw), 2);
        let err = link.recv().unwrap_err();
        assert!(
            err.to_string().contains("retransmit budget exhausted"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn resend_of_empty_payload_roundtrips() {
        let (a, b) = UnixStream::pair().unwrap();
        let mut tx = FrameLink::new(a, 1);
        let mut rx = FrameLink::new(b, 1);
        tx.send(FrameKind::Shutdown, &[]).unwrap();
        // Drop the clean copy, then simulate the peer's Nack path by
        // feeding a Nack to tx and reading the Resend from rx's side.
        let (_, _) = rx.recv().unwrap();
        rx.send(FrameKind::Nack, &[]).unwrap();
        let h = std::thread::spawn(move || tx.recv());
        let (kind, payload) = rx.recv().unwrap();
        assert_eq!(kind, FrameKind::Shutdown);
        assert!(payload.is_empty());
        rx.send(FrameKind::Shutdown, &[]).unwrap();
        h.join().unwrap().unwrap();
    }
}
