//! Length-prefixed frame protocol for the distributed socket transport.
//!
//! Every message is `header ‖ payload`. The 16-byte header is
//!
//! ```text
//! offset  0  1  2        3     4..8      8..12   12..16
//!         'C' 'W' version kind  len (LE)  crc32   reserved
//! ```
//!
//! `len` is the payload byte count (capped at [`MAX_FRAME_LEN`]) and
//! `crc32` is the IEEE CRC of the payload, verified on read so a torn or
//! corrupted stream surfaces as an error instead of a silently wrong
//! gradient. Payload bytes are opaque here; `wire::codec` gives them
//! meaning per [`FrameKind`].
//!
//! A CRC mismatch is special: by the time it is detected the full
//! payload has already been consumed, so the stream is still aligned on
//! a frame boundary and the damage is confined to one frame.
//! [`read_frame_checked`] surfaces that case as a recoverable
//! [`FrameRead::Corrupt`] value instead of an error, which is what lets
//! `wire::link` heal it with a bounded Nack/Resend exchange. Bad magic,
//! an unknown version or kind, or a truncated stream remain hard
//! errors — the reader no longer knows where the next frame starts.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use super::codec::crc32;

const MAGIC0: u8 = b'C';
const MAGIC1: u8 = b'W';

/// Protocol version stamped into every frame header.
///
/// History:
/// - **1** (PR 8): initial framed protocol, kinds 1–10.
/// - **2** (PR 10): fault tolerance. `Hello` gained `last_step` +
///   `fingerprint` and `Welcome` gained `committed` (the versioned
///   rejoin handshake), and the [`FrameKind::Nack`] /
///   [`FrameKind::Resend`] control kinds were added for bounded
///   retransmission of CRC-corrupt frames. v1 peers are refused at
///   the header check.
pub const WIRE_VERSION: u8 = 2;

/// Header length in bytes.
pub const FRAME_HEADER_LEN: usize = 16;

/// Largest accepted payload (256 MiB) — a forged length field cannot
/// force an unbounded allocation.
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// Message discriminant carried in byte 3 of the header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → coordinator: handshake (`codec::Hello`).
    Hello,
    /// Coordinator → worker: handshake reply (`codec::Welcome`).
    Welcome,
    /// Worker → coordinator: one step's `Contribution`.
    Contrib,
    /// Coordinator → worker: the reduced total `Contribution`.
    Total,
    /// Coordinator → worker: clean end of run.
    Shutdown,
    /// Either direction: fatal error, UTF-8 message payload.
    Error,
    /// Client → server: a serving score request (`codec::encode_score`).
    Score,
    /// Server → client: a serving score reply (`codec::encode_scored`).
    Scored,
    /// Client → process: one-shot metrics pull (`cowclip metrics`), empty payload.
    MetricsReq,
    /// Process → client: metrics snapshot, JSON (`cowclip-metrics-v1`) payload.
    Metrics,
    /// Either direction: "your last frame arrived CRC-corrupt, resend
    /// it". Empty payload. Handled inside `wire::link`, never
    /// surfaced to the dist loop.
    Nack,
    /// Either direction: retransmission of the previous frame in reply
    /// to a [`FrameKind::Nack`]. Payload is the original kind tag
    /// followed by the original payload, so a retransmitted frame is
    /// always distinguishable from a fresh one.
    Resend,
}

impl FrameKind {
    pub fn tag(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Welcome => 2,
            FrameKind::Contrib => 3,
            FrameKind::Total => 4,
            FrameKind::Shutdown => 5,
            FrameKind::Error => 6,
            FrameKind::Score => 7,
            FrameKind::Scored => 8,
            FrameKind::MetricsReq => 9,
            FrameKind::Metrics => 10,
            FrameKind::Nack => 11,
            FrameKind::Resend => 12,
        }
    }

    pub fn from_tag(tag: u8) -> Result<FrameKind> {
        match tag {
            1 => Ok(FrameKind::Hello),
            2 => Ok(FrameKind::Welcome),
            3 => Ok(FrameKind::Contrib),
            4 => Ok(FrameKind::Total),
            5 => Ok(FrameKind::Shutdown),
            6 => Ok(FrameKind::Error),
            7 => Ok(FrameKind::Score),
            8 => Ok(FrameKind::Scored),
            9 => Ok(FrameKind::MetricsReq),
            10 => Ok(FrameKind::Metrics),
            11 => Ok(FrameKind::Nack),
            12 => Ok(FrameKind::Resend),
            other => bail!("wire: unknown frame kind {other}"),
        }
    }
}

/// Write one frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, kind: FrameKind, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_LEN,
        "wire: frame of {} bytes exceeds the {} byte cap",
        payload.len(),
        MAX_FRAME_LEN
    );
    let [l0, l1, l2, l3] = (payload.len() as u32).to_le_bytes();
    let [c0, c1, c2, c3] = crc32(payload).to_le_bytes();
    let header: [u8; FRAME_HEADER_LEN] = [
        MAGIC0,
        MAGIC1,
        WIRE_VERSION,
        kind.tag(),
        l0,
        l1,
        l2,
        l3,
        c0,
        c1,
        c2,
        c3,
        0,
        0,
        0,
        0,
    ];
    w.write_all(&header).context("wire: write frame header")?;
    w.write_all(payload).context("wire: write frame payload")?;
    w.flush().context("wire: flush frame")?;
    Ok(())
}

/// Outcome of [`read_frame_checked`]: either an intact frame or a
/// recoverable single-frame corruption.
#[derive(Debug)]
pub enum FrameRead {
    /// Header and CRC checked out; the frame is intact.
    Frame(FrameKind, Vec<u8>),
    /// The header was well formed and the payload fully consumed, but
    /// its CRC did not match. The stream is still aligned on a frame
    /// boundary, so the caller may Nack and keep reading.
    Corrupt { kind: FrameKind, got: u32, want: u32 },
}

/// Read one frame, reporting a payload CRC mismatch as a recoverable
/// [`FrameRead::Corrupt`] instead of an error. Everything that desyncs
/// the stream (bad magic, version, kind, oversize length, truncation)
/// is still a hard error.
pub fn read_frame_checked<R: Read>(r: &mut R) -> Result<FrameRead> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut header).context("wire: read frame header")?;
    let [m0, m1, version, kind_tag, l0, l1, l2, l3, c0, c1, c2, c3, _, _, _, _] = header;
    ensure!(
        m0 == MAGIC0 && m1 == MAGIC1,
        "wire: bad frame magic {m0:#04x} {m1:#04x}"
    );
    ensure!(
        version == WIRE_VERSION,
        "wire: frame version {version}, supported {WIRE_VERSION}"
    );
    let kind = FrameKind::from_tag(kind_tag)?;
    let len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
    ensure!(
        len <= MAX_FRAME_LEN,
        "wire: frame of {len} bytes exceeds the {MAX_FRAME_LEN} byte cap"
    );
    let want = u32::from_le_bytes([c0, c1, c2, c3]);
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("wire: read frame payload")?;
    let got = crc32(&payload);
    if got != want {
        return Ok(FrameRead::Corrupt { kind, got, want });
    }
    Ok(FrameRead::Frame(kind, payload))
}

/// Read one frame; the payload's CRC is verified before it is returned
/// and a mismatch is a hard error. Callers that can retransmit should
/// use [`read_frame_checked`] (via `wire::link`) instead.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(FrameKind, Vec<u8>)> {
    match read_frame_checked(r)? {
        FrameRead::Frame(kind, payload) => Ok((kind, payload)),
        FrameRead::Corrupt { got, want, .. } => {
            bail!("wire: frame CRC mismatch (got {got:#010x}, want {want:#010x})")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_all_kinds() {
        let kinds = [
            FrameKind::Hello,
            FrameKind::Welcome,
            FrameKind::Contrib,
            FrameKind::Total,
            FrameKind::Shutdown,
            FrameKind::Error,
            FrameKind::Score,
            FrameKind::Scored,
            FrameKind::MetricsReq,
            FrameKind::Metrics,
            FrameKind::Nack,
            FrameKind::Resend,
        ];
        let mut buf = Vec::new();
        for (i, &k) in kinds.iter().enumerate() {
            let payload: Vec<u8> = (0..i as u8).collect();
            write_frame(&mut buf, k, &payload).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for (i, &k) in kinds.iter().enumerate() {
            let (kind, payload) = read_frame(&mut cur).unwrap();
            assert_eq!(kind, k);
            assert_eq!(payload.len(), i);
        }
        assert_eq!(cur.position() as usize, cur.get_ref().len());
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Contrib, b"hello world").unwrap();
        let last = buf.len() - 1;
        if let Some(b) = buf.get_mut(last) {
            *b ^= 0xFF;
        }
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn checked_read_reports_corruption_and_stays_in_sync() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Contrib, b"first").unwrap();
        let corrupt_at = buf.len() - 1;
        write_frame(&mut buf, FrameKind::Total, b"second").unwrap();
        if let Some(b) = buf.get_mut(corrupt_at) {
            *b ^= 0x01;
        }
        let mut cur = Cursor::new(buf);
        match read_frame_checked(&mut cur).unwrap() {
            FrameRead::Corrupt { kind, got, want } => {
                assert_eq!(kind, FrameKind::Contrib);
                assert_ne!(got, want);
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // The corrupt payload was fully consumed: the next frame reads clean.
        let (kind, payload) = read_frame(&mut cur).unwrap();
        assert_eq!(kind, FrameKind::Total);
        assert_eq!(payload, b"second");
    }

    #[test]
    fn bad_magic_version_and_kind_rejected() {
        let mut good = Vec::new();
        write_frame(&mut good, FrameKind::Hello, b"x").unwrap();

        let mut bad = good.clone();
        if let Some(b) = bad.first_mut() {
            *b = b'X';
        }
        assert!(read_frame(&mut Cursor::new(bad)).is_err());

        let mut bad = good.clone();
        if let Some(b) = bad.get_mut(2) {
            *b = 99;
        }
        assert!(read_frame(&mut Cursor::new(bad)).is_err());

        let mut bad = good.clone();
        if let Some(b) = bad.get_mut(3) {
            *b = 0;
        }
        assert!(read_frame(&mut Cursor::new(bad)).is_err());
    }

    #[test]
    fn oversize_length_field_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Error, b"").unwrap();
        // Forge a 1 GiB length into the header.
        let forged = (1u32 << 30).to_le_bytes();
        buf.splice(4..8, forged);
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn truncated_stream_is_an_error_not_a_hang() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Contrib, &[0u8; 64]).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }
}
