//! Synthetic CTR data generator: Zipf-distributed ids + hidden teacher.
//!
//! Two properties of the real datasets matter to CowClip and must survive
//! the substitution (DESIGN.md §4):
//!
//! 1. **Frequency imbalance** — the paper's Figure 4 shows per-field id
//!    frequencies spanning decades with an exponential/Zipf envelope. We
//!    sample each field's id from Zipf(alpha) with per-field alpha, so a
//!    handful of ids absorb most of the mass and the tail is rarely seen —
//!    exactly the `P(id in B) ≈ b·P(id in x)` regime of Eq. (1).
//! 2. **Learnable structure** — labels are drawn from a hidden "teacher"
//!    model (per-id biases + low-rank pairwise interactions + a dense-
//!    feature term + noise), so a better-optimized student scores higher
//!    AUC; pure random labels would make every scaling rule look alike.

use super::dataset::Dataset;
use super::schema::Schema;
use crate::util::Rng;

/// Generator knobs.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n: usize,
    pub seed: u64,
    /// Zipf exponent per field cycles through this list.
    pub alphas: Vec<f64>,
    /// Teacher latent dimension for pairwise interactions.
    pub teacher_dim: usize,
    /// Scale of teacher logits (higher = more separable = higher AUC cap).
    pub signal_scale: f64,
    /// Logit offset controlling the base CTR (≈ sigmoid(offset)).
    pub base_logit: f64,
    /// Std of label noise added to the teacher logit.
    pub noise: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n: 200_000,
            seed: 1234,
            alphas: vec![1.05, 1.2, 1.1, 1.3],
            teacher_dim: 4,
            signal_scale: 1.6,
            base_logit: -1.1, // CTR ≈ 25%, close to Criteo's ~26%
            noise: 0.8,
        }
    }
}

/// Per-field Zipf sampler with a precomputed CDF.
pub struct ZipfField {
    cdf: Vec<f64>,
}

impl ZipfField {
    pub fn new(vocab: usize, alpha: f64) -> ZipfField {
        let mut cdf = Vec::with_capacity(vocab);
        let mut acc = 0.0;
        for k in 0..vocab {
            acc += 1.0 / ((k + 1) as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        ZipfField { cdf }
    }

    /// Draw a local id (0-based rank; rank 0 is the most frequent id).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // first index with cdf >= u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Occurrence probability of rank `k`.
    pub fn prob(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

/// Hidden ground-truth model that labels synthetic rows.
struct Teacher {
    /// Per-global-id scalar bias.
    bias: Vec<f32>,
    /// Per-global-id latent vector `[V, k]`.
    latent: Vec<f32>,
    k: usize,
    /// Per-dense-field weight.
    dense_w: Vec<f32>,
    /// Pairwise interaction weight between fields (flattened upper
    /// triangle), sparsified so only some field pairs interact.
    pair_w: Vec<f32>,
    n_cat: usize,
}

impl Teacher {
    fn new(schema: &Schema, k: usize, rng: &mut Rng) -> Teacher {
        let v = schema.total_vocab();
        let n_cat = schema.n_cat();
        let bias = rng.gaussian_vec(v, 0.35);
        let latent = rng.gaussian_vec(v * k, (1.0 / (k as f32)).sqrt());
        let dense_w = rng.gaussian_vec(schema.n_dense, 0.25);
        let mut pair_w = rng.gaussian_vec(n_cat * n_cat, 0.6);
        // keep ~20% of pairs active: realistic interaction sparsity
        for w in &mut pair_w {
            if rng.next_f64() > 0.2 {
                *w = 0.0;
            }
        }
        Teacher { bias, latent, k, dense_w, pair_w, n_cat }
    }

    fn logit(&self, cat_row: &[i32], dense_row: &[f32]) -> f64 {
        let mut score = 0.0f64;
        for &id in cat_row {
            score += self.bias[id as usize] as f64;
        }
        for (j, &x) in dense_row.iter().enumerate() {
            score += (self.dense_w[j] * x.tanh()) as f64;
        }
        let k = self.k;
        for a in 0..self.n_cat {
            for b in (a + 1)..self.n_cat {
                let w = self.pair_w[a * self.n_cat + b];
                if w == 0.0 {
                    continue;
                }
                let ia = cat_row[a] as usize * k;
                let ib = cat_row[b] as usize * k;
                let mut dot = 0.0f32;
                for t in 0..k {
                    dot += self.latent[ia + t] * self.latent[ib + t];
                }
                score += (w * dot) as f64;
            }
        }
        score
    }
}

/// The per-field id model shared by [`generate`] and [`RowSampler`]:
/// Zipf samplers plus the rank→id shuffles (seeded from `rng_fields`, so
/// the "hot" id isn't always local id 0 — matters for the top-k collapse
/// transform). Keeping this in one place is what makes load generation
/// and training synthesis draw from **one** id distribution.
fn field_model(
    schema: &Schema,
    cfg: &SynthConfig,
    rng_fields: &mut Rng,
) -> (Vec<ZipfField>, Vec<Vec<usize>>) {
    let samplers: Vec<ZipfField> = schema
        .vocab_sizes
        .iter()
        .enumerate()
        .map(|(f, &v)| ZipfField::new(v, cfg.alphas[f % cfg.alphas.len()]))
        .collect();
    let rank_to_id: Vec<Vec<usize>> = schema
        .vocab_sizes
        .iter()
        .map(|&v| {
            let mut ids: Vec<usize> = (0..v).collect();
            rng_fields.shuffle(&mut ids);
            ids
        })
        .collect();
    (samplers, rank_to_id)
}

/// Seeded single-row stream drawn from the **same** per-field Zipf
/// samplers and rank shuffles as [`generate`] — the serving tier's load
/// generator and the training synthesizer share one id-frequency model,
/// so a serving benchmark hits the embedding table with the skew the
/// model was trained under. Each draw yields `(cat_ids, dense)` with
/// global categorical ids; labels are not generated (requests don't
/// have them).
pub struct RowSampler {
    samplers: Vec<ZipfField>,
    rank_to_id: Vec<Vec<usize>>,
    offsets: Vec<usize>,
    n_dense: usize,
    rng: Rng,
}

impl RowSampler {
    /// Same seeding discipline as [`generate`]: `cfg.seed` derives the
    /// field shuffles (`split(1)`) and the row stream (`split(3)`), so a
    /// sampler built from a dataset's `SynthConfig` draws ids with that
    /// dataset's exact per-field distribution.
    pub fn new(schema: &Schema, cfg: &SynthConfig) -> RowSampler {
        let mut root = Rng::new(cfg.seed);
        let mut rng_fields = root.split(1);
        let _rng_teacher = root.split(2); // keep the stream family aligned
        let rng = root.split(3);
        let (samplers, rank_to_id) = field_model(schema, cfg, &mut rng_fields);
        RowSampler {
            samplers,
            rank_to_id,
            offsets: schema.offsets(),
            n_dense: schema.n_dense,
            rng,
        }
    }

    /// Draw one request row: global categorical ids + dense features.
    pub fn next_row(&mut self) -> (Vec<i32>, Vec<f32>) {
        let mut cat = Vec::with_capacity(self.samplers.len());
        for (f, sampler) in self.samplers.iter().enumerate() {
            let rank = sampler.sample(&mut self.rng);
            cat.push((self.offsets[f] + self.rank_to_id[f][rank]) as i32);
        }
        let dense: Vec<f32> =
            (0..self.n_dense).map(|_| self.rng.next_gaussian() as f32).collect();
        (cat, dense)
    }
}

impl Iterator for RowSampler {
    type Item = (Vec<i32>, Vec<f32>);

    fn next(&mut self) -> Option<Self::Item> {
        Some(self.next_row())
    }
}

/// Generate a dataset according to `cfg`.
pub fn generate(schema: &Schema, cfg: &SynthConfig) -> Dataset {
    let mut root = Rng::new(cfg.seed);
    let mut rng_fields = root.split(1);
    let mut rng_teacher = root.split(2);
    let mut rng_rows = root.split(3);

    let (samplers, rank_to_id) = field_model(schema, cfg, &mut rng_fields);

    let teacher = Teacher::new(schema, cfg.teacher_dim, &mut rng_teacher);
    let offsets = schema.offsets();

    let mut ds = Dataset::with_capacity(schema.clone(), cfg.n);
    let n_cat = schema.n_cat();
    let mut cat_row = vec![0i32; n_cat];
    let mut dense_row = vec![0f32; schema.n_dense];

    for i in 0..cfg.n {
        for f in 0..n_cat {
            let rank = samplers[f].sample(&mut rng_rows);
            cat_row[f] = (offsets[f] + rank_to_id[f][rank]) as i32;
        }
        for d in dense_row.iter_mut() {
            *d = rng_rows.next_gaussian() as f32;
        }
        let logit = cfg.base_logit
            + cfg.signal_scale * teacher.logit(&cat_row, &dense_row)
            + cfg.noise * rng_rows.next_gaussian();
        let y = rng_rows.bernoulli(sigmoid(logit)) as u8;

        ds.x_cat.extend_from_slice(&cat_row);
        ds.x_dense.extend_from_slice(&dense_row);
        ds.y.push(y);
        // timestamps: uniform "seven days" so sequential split ≈ 6/7.
        ds.ts.push((i as u64 * 7 * 86_400 / cfg.n.max(1) as u64) as u32);
    }
    ds
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::{avazu_synth, criteo_synth};

    #[test]
    fn zipf_is_heavily_skewed() {
        let z = ZipfField::new(1000, 1.2);
        let mut rng = Rng::new(0);
        let mut counts = vec![0u32; 1000];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // head rank absorbs far more than uniform share
        assert!(counts[0] > 1000, "head count {}", counts[0]);
        // the tail half should be nearly empty
        let tail: u32 = counts[500..].iter().sum();
        assert!(tail < 2000, "tail count {tail}");
    }

    #[test]
    fn zipf_probs_sum_to_one() {
        let z = ZipfField::new(100, 1.05);
        let total: f64 = (0..100).map(|k| z.prob(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn generated_dataset_is_valid_and_reproducible() {
        let schema = criteo_synth();
        let cfg = SynthConfig { n: 500, ..Default::default() };
        let a = generate(&schema, &cfg);
        let b = generate(&schema, &cfg);
        a.validate().unwrap();
        assert_eq!(a.x_cat, b.x_cat);
        assert_eq!(a.y, b.y);
        assert_eq!(a.n(), 500);
    }

    #[test]
    fn different_seeds_differ() {
        let schema = avazu_synth();
        let a = generate(&schema, &SynthConfig { n: 200, seed: 1, ..Default::default() });
        let b = generate(&schema, &SynthConfig { n: 200, seed: 2, ..Default::default() });
        assert_ne!(a.x_cat, b.x_cat);
    }

    #[test]
    fn base_ctr_in_plausible_band() {
        let schema = criteo_synth();
        let ds = generate(&schema, &SynthConfig { n: 20_000, ..Default::default() });
        let ctr = ds.ctr();
        assert!(ctr > 0.1 && ctr < 0.5, "ctr {ctr}");
    }

    #[test]
    fn row_sampler_matches_generate_distribution() {
        // Same seed -> same rank shuffles and Zipf CDFs, so per-field id
        // frequencies of the request stream track the dataset's closely.
        let schema = Schema { name: "rs".into(), n_dense: 2, vocab_sizes: vec![50, 20] };
        let cfg = SynthConfig { n: 30_000, seed: 77, ..Default::default() };
        let ds = generate(&schema, &cfg);
        let mut sampler = RowSampler::new(&schema, &cfg);
        let total = schema.total_vocab();
        let mut ds_counts = vec![0u32; total];
        for &id in &ds.x_cat {
            ds_counts[id as usize] += 1;
        }
        let mut rs_counts = vec![0u32; total];
        let offs = schema.offsets();
        for _ in 0..cfg.n {
            let (cat, dense) = sampler.next_row();
            assert_eq!(cat.len(), schema.n_cat());
            assert_eq!(dense.len(), schema.n_dense);
            for (f, &id) in cat.iter().enumerate() {
                let lo = offs[f] as i32;
                let hi = lo + schema.vocab_sizes[f] as i32;
                assert!(id >= lo && id < hi, "field {f}: id {id} outside [{lo},{hi})");
                rs_counts[id as usize] += 1;
            }
        }
        // the head ids (the ones that dominate training) must agree: same
        // argmax per field and similar head mass
        for (off, vs) in schema.fields() {
            let arg = |c: &[u32]| {
                (off..off + vs).max_by_key(|&i| c[i]).unwrap()
            };
            assert_eq!(arg(&ds_counts), arg(&rs_counts), "hot id differs at field offset {off}");
            let head_ds = *ds_counts[off..off + vs].iter().max().unwrap() as f64 / cfg.n as f64;
            let head_rs = *rs_counts[off..off + vs].iter().max().unwrap() as f64 / cfg.n as f64;
            assert!(
                (head_ds - head_rs).abs() < 0.05,
                "head mass {head_ds:.3} vs {head_rs:.3}"
            );
        }
    }

    #[test]
    fn row_sampler_is_deterministic_and_seed_sensitive() {
        let schema = avazu_synth();
        let cfg = SynthConfig::default();
        let a: Vec<_> = RowSampler::new(&schema, &cfg).take(20).collect();
        let b: Vec<_> = RowSampler::new(&schema, &cfg).take(20).collect();
        assert_eq!(a, b);
        let c: Vec<_> =
            RowSampler::new(&schema, &SynthConfig { seed: 999, ..cfg }).take(20).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn timestamps_monotone_nondecreasing() {
        let schema = avazu_synth();
        let ds = generate(&schema, &SynthConfig { n: 1000, ..Default::default() });
        assert!(ds.ts.windows(2).all(|w| w[0] <= w[1]));
    }
}
