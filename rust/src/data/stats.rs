//! Dataset statistics: per-field id-frequency profiles (paper Figure 4)
//! and occurrence-probability summaries used by the analysis in §3.

use super::dataset::Dataset;

/// Frequency profile of one categorical field.
#[derive(Clone, Debug)]
pub struct FieldStats {
    pub field: usize,
    pub vocab: usize,
    /// Occurrence count per local id, sorted descending.
    pub sorted_counts: Vec<u64>,
    /// Ids never seen in the dataset.
    pub n_unseen: usize,
}

impl FieldStats {
    /// Fraction of total occurrences covered by the `k` hottest ids.
    pub fn head_mass(&self, k: usize) -> f64 {
        let total: u64 = self.sorted_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let head: u64 = self.sorted_counts.iter().take(k).sum();
        head as f64 / total as f64
    }

    /// Log-spaced histogram of counts: `(bucket_upper_bound, n_ids)`.
    /// This is the shape plotted in the paper's Figure 4.
    pub fn log_histogram(&self) -> Vec<(u64, usize)> {
        let mut buckets = Vec::new();
        let mut ub = 1u64;
        loop {
            let n = self
                .sorted_counts
                .iter()
                .filter(|&&c| c > ub / 2 && c <= ub)
                .count();
            buckets.push((ub, n));
            if ub >= *self.sorted_counts.first().unwrap_or(&1) {
                break;
            }
            ub *= 2;
        }
        buckets
    }
}

/// Count id occurrences per field.
pub fn field_stats(ds: &Dataset) -> Vec<FieldStats> {
    let offsets = ds.schema.offsets();
    let mut per_field: Vec<Vec<u64>> =
        ds.schema.vocab_sizes.iter().map(|&v| vec![0u64; v]).collect();
    for row in ds.x_cat.chunks(ds.schema.n_cat()) {
        for (f, &gid) in row.iter().enumerate() {
            per_field[f][gid as usize - offsets[f]] += 1;
        }
    }
    per_field
        .into_iter()
        .enumerate()
        .map(|(field, counts)| {
            let n_unseen = counts.iter().filter(|&&c| c == 0).count();
            let mut sorted_counts = counts;
            sorted_counts.sort_unstable_by(|a, b| b.cmp(a));
            FieldStats {
                field,
                vocab: sorted_counts.len(),
                sorted_counts,
                n_unseen,
            }
        })
        .collect()
}

/// Global occurrence counts over the concatenated vocabulary.
pub fn global_counts(ds: &Dataset) -> Vec<u64> {
    let mut counts = vec![0u64; ds.schema.total_vocab()];
    for &gid in &ds.x_cat {
        counts[gid as usize] += 1;
    }
    counts
}

/// Fraction of ids with occurrence probability below `1/batch` — the
/// "most ids are infrequent" premise of the paper's scaling analysis.
pub fn infrequent_fraction(ds: &Dataset, batch: usize) -> f64 {
    let counts = global_counts(ds);
    let n = ds.n() as f64;
    let thresh = 1.0 / batch as f64;
    let infreq = counts.iter().filter(|&&c| (c as f64 / n) < thresh).count();
    infreq as f64 / counts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::criteo_synth;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn counts_sum_to_rows() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 2000, ..Default::default() });
        let stats = field_stats(&ds);
        for s in &stats {
            assert_eq!(s.sorted_counts.iter().sum::<u64>(), 2000);
        }
        assert_eq!(stats.len(), 26);
    }

    #[test]
    fn zipf_head_dominates() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 20_000, ..Default::default() });
        let stats = field_stats(&ds);
        // in a big-vocab field, the 10 hottest ids must hold a large share
        assert!(stats[0].head_mass(10) > 0.3, "head mass {}", stats[0].head_mass(10));
        assert!(stats[0].n_unseen > 0, "zipf tail should leave unseen ids");
    }

    #[test]
    fn infrequent_fraction_decreases_with_batch() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 10_000, ..Default::default() });
        let f64_ = infrequent_fraction(&ds, 64);
        let f4096 = infrequent_fraction(&ds, 4096);
        assert!(f64_ >= f4096);
        assert!(f64_ > 0.5, "most ids should be infrequent at b=64: {f64_}");
    }

    #[test]
    fn log_histogram_covers_all_seen_ids() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 5000, ..Default::default() });
        let stats = field_stats(&ds);
        let s = &stats[2];
        let histo_total: usize = s.log_histogram().iter().map(|&(_, n)| n).sum();
        let seen = s.vocab - s.n_unseen;
        assert_eq!(histo_total, seen);
    }
}
