//! Train/test splits: random (Criteo 90/10, Avazu 80/20) and sequential
//! (Criteo-seq: first six days train, last day test).

use super::dataset::Dataset;
use crate::util::Rng;

/// Random split: `train_frac` of rows to train, rest to test.
pub fn random_split(ds: &Dataset, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..=1.0).contains(&train_frac));
    let mut idx: Vec<usize> = (0..ds.n()).collect();
    Rng::new(seed).shuffle(&mut idx);
    let cut = (ds.n() as f64 * train_frac).round() as usize;
    (ds.select(&idx[..cut]), ds.select(&idx[cut..]))
}

/// Sequential split on timestamps: rows with `ts < cutoff` train, rest
/// test. `frac` picks the cutoff as a quantile of the time range
/// (Criteo-seq uses 6/7).
pub fn sequential_split(ds: &Dataset, frac: f64) -> (Dataset, Dataset) {
    assert!(ds.n() > 0);
    let min = *ds.ts.iter().min().unwrap() as f64;
    let max = *ds.ts.iter().max().unwrap() as f64;
    let cutoff = min + (max - min) * frac;
    let train_idx: Vec<usize> = (0..ds.n()).filter(|&i| (ds.ts[i] as f64) < cutoff).collect();
    let test_idx: Vec<usize> = (0..ds.n()).filter(|&i| (ds.ts[i] as f64) >= cutoff).collect();
    (ds.select(&train_idx), ds.select(&test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Schema;

    fn ds(n: usize) -> Dataset {
        let schema = Schema { name: "t".into(), n_dense: 0, vocab_sizes: vec![2] };
        let mut d = Dataset::with_capacity(schema, n);
        for i in 0..n {
            d.x_cat.push((i % 2) as i32);
            d.y.push(0);
            d.ts.push(i as u32);
        }
        d
    }

    #[test]
    fn random_split_sizes_and_disjoint() {
        let d = ds(100);
        let (tr, te) = random_split(&d, 0.9, 0);
        assert_eq!(tr.n(), 90);
        assert_eq!(te.n(), 10);
        // each original row lands in exactly one side: count multiset of ts
        let mut all: Vec<u32> = tr.ts.iter().chain(te.ts.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_split_respects_time_order() {
        let d = ds(70);
        let (tr, te) = sequential_split(&d, 6.0 / 7.0);
        assert!(!tr.ts.is_empty() && !te.ts.is_empty());
        let max_train = *tr.ts.iter().max().unwrap();
        let min_test = *te.ts.iter().min().unwrap();
        assert!(max_train < min_test);
        assert!((tr.n() as f64 / d.n() as f64 - 6.0 / 7.0).abs() < 0.05);
    }
}
