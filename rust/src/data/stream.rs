//! Streaming `.ctr` reader: iterate fixed-size batches straight from
//! disk without materializing the dataset.
//!
//! The paper's industrial setting trains on hundreds of billions of rows
//! that never fit in memory; this reader gives the coordinator the same
//! shape of access on this testbed — sequential chunked reads with an
//! epoch-level shuffle of *chunks* (a standard out-of-core compromise:
//! within-chunk order is preserved, chunk order is randomized per epoch).

use std::fs::File;
use std::io::{Read, Seek};
use std::path::{Path, PathBuf};
#[cfg(not(unix))]
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::batcher::Batch;
use super::dataset::Dataset;
use super::schema::Schema;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Streaming reader over a `.ctr` file.
///
/// The header-parsed file handle is kept open and reused by every
/// `read_rows` call via **positioned reads** (`pread(2)` on Unix): each
/// read names its absolute offset, so there is no shared cursor, no
/// lock, and concurrent readers — the [`super::Prefetch`] thread, eval
/// threads, distributed worker replicas — never serialize on the
/// handle. (The seed implementation paid three `File::open` syscalls
/// per batch; the first fix funneled everything through one
/// `Mutex<File>`, which made every reader queue behind one cursor.)
/// Non-Unix hosts fall back to seek+read behind a cursor mutex.
pub struct StreamReader {
    path: PathBuf,
    pub schema: Schema,
    pub n: usize,
    /// byte offsets of the four payload sections
    cat_off: u64,
    dense_off: u64,
    y_off: u64,
    /// Reusable read handle; all three sections are read through it at
    /// explicit offsets.
    file: File,
    /// Shared-cursor guard for the non-Unix seek+read fallback only.
    #[cfg(not(unix))]
    cursor: Mutex<()>,
}

impl StreamReader {
    /// Open the file and parse the header (payload stays on disk).
    pub fn open(path: &Path) -> Result<StreamReader> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"CTRD" {
            bail!("{}: not a .ctr file", path.display());
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?; // version
        if u32::from_le_bytes(u32b) != 1 {
            bail!("unsupported .ctr version");
        }
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let n = u64::from_le_bytes(u64b) as usize;
        f.read_exact(&mut u32b)?;
        let n_cat = u32::from_le_bytes(u32b) as usize;
        f.read_exact(&mut u32b)?;
        let n_dense = u32::from_le_bytes(u32b) as usize;
        f.read_exact(&mut u32b)?;
        let n_vs = u32::from_le_bytes(u32b) as usize;
        let mut vocab_sizes = Vec::with_capacity(n_vs);
        for _ in 0..n_vs {
            f.read_exact(&mut u64b)?;
            vocab_sizes.push(u64::from_le_bytes(u64b) as usize);
        }
        let schema = Schema {
            name: String::from_utf8(name)?,
            n_dense,
            vocab_sizes,
        };
        if schema.n_cat() != n_cat {
            bail!("header n_cat mismatch");
        }
        let cat_off = f.stream_position()?;
        let dense_off = cat_off + (n * n_cat * 4) as u64;
        let y_off = dense_off + (n * n_dense * 4) as u64;
        Ok(StreamReader {
            path: path.to_path_buf(),
            schema,
            n,
            cat_off,
            dense_off,
            y_off,
            file: f,
            #[cfg(not(unix))]
            cursor: Mutex::new(()),
        })
    }

    /// Fill `buf` from the absolute byte offset `off`: lock-free
    /// `pread(2)` on Unix, seek+read behind the cursor mutex elsewhere.
    fn read_exact_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file
                .read_exact_at(buf, off)
                .with_context(|| format!("{}: read at offset {off}", self.path.display()))
        }
        #[cfg(not(unix))]
        {
            let _cursor = self
                .cursor
                .lock()
                .map_err(|_| anyhow::anyhow!("{}: reader cursor poisoned", self.path.display()))?;
            let mut f = &self.file;
            f.seek(std::io::SeekFrom::Start(off))?;
            f.read_exact(buf)
                .with_context(|| format!("{}: read at offset {off}", self.path.display()))
        }
    }

    /// Path this reader was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read rows `[lo, hi)` into an owned batch (no padding).
    pub fn read_rows(&self, lo: usize, hi: usize) -> Result<Batch> {
        if hi > self.n || lo >= hi {
            bail!("rows [{lo},{hi}) out of range (n={})", self.n);
        }
        let rows = hi - lo;
        let f_cat = self.schema.n_cat();
        let f_dense = self.schema.n_dense;

        let mut cat_bytes = vec![0u8; rows * f_cat * 4];
        self.read_exact_at(&mut cat_bytes, self.cat_off + (lo * f_cat * 4) as u64)?;
        let x_cat: Vec<i32> = cat_bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut dense = vec![0f32; rows * f_dense];
        if f_dense > 0 {
            let mut dense_bytes = vec![0u8; rows * f_dense * 4];
            self.read_exact_at(&mut dense_bytes, self.dense_off + (lo * f_dense * 4) as u64)?;
            for (o, c) in dense.iter_mut().zip(dense_bytes.chunks_exact(4)) {
                *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }

        let mut y_bytes = vec![0u8; rows];
        self.read_exact_at(&mut y_bytes, self.y_off + lo as u64)?;
        let y: Vec<f32> = y_bytes.iter().map(|&b| b as f32).collect();

        Ok(Batch::new(
            Tensor::i32(vec![rows, f_cat], x_cat),
            Tensor::f32(vec![rows, f_dense], dense),
            Tensor::f32(vec![rows], y),
            rows,
        ))
    }

    /// Chunk-shuffled epoch iterator of fixed-size batches (drop-last).
    pub fn epoch(&self, batch: usize, seed: u64) -> StreamEpoch<'_> {
        assert!(batch > 0 && batch <= self.n);
        let n_chunks = self.n / batch;
        let mut order: Vec<usize> = (0..n_chunks).collect();
        Rng::new(seed).shuffle(&mut order);
        StreamEpoch { reader: self, batch, order, next: 0 }
    }
}

/// One epoch of streamed batches.
pub struct StreamEpoch<'a> {
    reader: &'a StreamReader,
    batch: usize,
    order: Vec<usize>,
    next: usize,
}

impl<'a> Iterator for StreamEpoch<'a> {
    type Item = Result<Batch>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.order.len() {
            return None;
        }
        let chunk = self.order[self.next];
        self.next += 1;
        let lo = chunk * self.batch;
        Some(self.reader.read_rows(lo, lo + self.batch))
    }
}

/// Convenience: stream-verify that a file round-trips a dataset.
pub fn verify_against(ds: &Dataset, path: &Path) -> Result<()> {
    let r = StreamReader::open(path)?;
    if r.n != ds.n() || r.schema != ds.schema {
        bail!("stream header mismatch");
    }
    let b = r.read_rows(0, ds.n().min(16))?;
    let want = &ds.x_cat[..b.x_cat.len()];
    if b.x_cat.as_i32()? != want {
        bail!("stream payload mismatch");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::criteo_synth;
    use crate::data::synth::{generate, SynthConfig};

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ctr_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn streamed_rows_match_in_memory() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 300, ..Default::default() });
        let path = tmpfile("a.ctr");
        ds.save(&path).unwrap();
        let r = StreamReader::open(&path).unwrap();
        assert_eq!(r.n, 300);
        assert_eq!(r.schema, ds.schema);
        let b = r.read_rows(100, 164).unwrap();
        assert_eq!(b.batch_size(), 64);
        assert_eq!(b.x_cat.as_i32().unwrap(), &ds.x_cat[100 * 26..164 * 26]);
        assert_eq!(b.x_dense.as_f32().unwrap(), &ds.x_dense[100 * 13..164 * 13]);
        let y = b.y.as_f32().unwrap();
        for (i, &v) in y.iter().enumerate() {
            assert_eq!(v, ds.y[100 + i] as f32);
        }
        verify_against(&ds, &path).unwrap();
    }

    #[test]
    fn epoch_covers_all_chunks_once() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 256, ..Default::default() });
        let path = tmpfile("b.ctr");
        ds.save(&path).unwrap();
        let r = StreamReader::open(&path).unwrap();
        let mut seen_rows = 0;
        let mut first_ids = Vec::new();
        for b in r.epoch(64, 7) {
            let b = b.unwrap();
            seen_rows += b.batch_size();
            first_ids.push(b.x_cat.as_i32().unwrap()[0]);
        }
        assert_eq!(seen_rows, 256);
        // shuffled chunk order differs between epochs with other seeds
        let other: Vec<i32> = r
            .epoch(64, 8)
            .map(|b| b.unwrap().x_cat.as_i32().unwrap()[0])
            .collect();
        assert_eq!(other.len(), 4);
        assert!(first_ids != other || first_ids.len() <= 1);
    }

    #[test]
    fn prefetched_epoch_matches_plain_iterator() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 512, ..Default::default() });
        let path = tmpfile("d.ctr");
        ds.save(&path).unwrap();
        let r = StreamReader::open(&path).unwrap();
        let plain: Vec<Vec<i32>> = r
            .epoch(64, 21)
            .map(|b| b.unwrap().x_cat.as_i32().unwrap().to_vec())
            .collect();
        let prefetched: Vec<Vec<i32>> = std::thread::scope(|s| {
            crate::data::Prefetch::spawn(s, r.epoch(64, 21), 2)
                .map(|b| b.unwrap().x_cat.as_i32().unwrap().to_vec())
                .collect()
        });
        // same chunk-shuffle order and same epoch coverage, batch by batch
        assert_eq!(plain, prefetched);
    }

    /// Positioned reads share no cursor: four threads hammering
    /// overlapping row ranges each get exactly their own rows.
    #[test]
    fn concurrent_readers_do_not_interleave() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 256, ..Default::default() });
        let path = tmpfile("e.ctr");
        ds.save(&path).unwrap();
        let r = StreamReader::open(&path).unwrap();
        let (r, ds) = (&r, &ds);
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    for i in 0..8usize {
                        let lo = (t * 13 + i * 29) % 192;
                        let b = r.read_rows(lo, lo + 64).unwrap();
                        assert_eq!(
                            b.x_cat.as_i32().unwrap(),
                            &ds.x_cat[lo * 26..(lo + 64) * 26],
                            "thread {t} read {i}"
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn out_of_range_rejected() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 64, ..Default::default() });
        let path = tmpfile("c.ctr");
        ds.save(&path).unwrap();
        let r = StreamReader::open(&path).unwrap();
        assert!(r.read_rows(60, 70).is_err());
        assert!(r.read_rows(10, 10).is_err());
    }
}
