//! Double-buffered prefetching over any batch iterator.
//!
//! The paper's 10-minute result depends on never letting the accelerator
//! wait for input; [`Prefetch`] gives the coordinator the same overlap on
//! this testbed: a scoped background thread drains the source iterator
//! into a bounded channel (default depth 2 — classic double buffering),
//! so batch `N+1` is materialized — including [`super::Batch::touched`]'s
//! sort when the producer warms it — while step `N` trains.
//!
//! The wrapper is deliberately generic: the trainer runs it over
//! [`super::Batcher`], and the out-of-core path runs it over
//! [`super::stream::StreamReader::epoch`] (whose items are
//! `Result<Batch>`). Order is preserved exactly — the channel is FIFO and
//! there is a single producer — so prefetching never changes which rows a
//! step sees.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::Scope;

/// A bounded, background-filled queue over an iterator's items.
///
/// Built inside a [`std::thread::scope`] so the source may borrow local
/// data (datasets, stream readers); the producer thread is joined when
/// the scope ends. Dropping the `Prefetch` disconnects the channel and
/// the producer exits on its next send.
pub struct Prefetch<T> {
    rx: Receiver<T>,
}

impl<T: Send> Prefetch<T> {
    /// Spawn a producer thread on `scope` that keeps up to `depth` items
    /// ready (`depth` is clamped to at least 1).
    pub fn spawn<'scope, 'env, I>(
        scope: &'scope Scope<'scope, 'env>,
        source: I,
        depth: usize,
    ) -> Prefetch<T>
    where
        I: IntoIterator<Item = T>,
        I::IntoIter: Send + 'scope,
        T: 'scope,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let mut it = source.into_iter();
        scope.spawn(move || {
            // registered once per producer thread; the per-item path
            // below is a span guard + one relaxed counter bump
            let produced = crate::obs::counter("prefetch.batches");
            loop {
                let item = {
                    // the span covers the source's materialization work
                    // (batch assembly + touched-id sort), not the
                    // channel wait
                    let _s = crate::obs::span(crate::obs::Phase::Prefetch);
                    it.next()
                };
                let Some(item) = item else {
                    break; // source exhausted
                };
                produced.inc();
                if tx.send(item).is_err() {
                    break; // consumer dropped the Prefetch
                }
            }
        });
        Prefetch { rx }
    }

    /// Next item in source order; `None` once the source is exhausted.
    pub fn recv(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send> Iterator for Prefetch<T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_coverage() {
        let items: Vec<usize> = (0..100).collect();
        let got: Vec<usize> = std::thread::scope(|s| {
            Prefetch::spawn(s, items.iter().copied(), 2).collect()
        });
        assert_eq!(got, items);
    }

    #[test]
    fn early_drop_does_not_hang_the_scope() {
        std::thread::scope(|s| {
            let pf = Prefetch::spawn(s, 0..1_000_000usize, 1);
            assert_eq!(pf.recv(), Some(0));
            drop(pf); // producer must notice the hangup and exit
        });
    }

    #[test]
    fn borrows_scope_local_data() {
        let data = vec![3.0f32, 1.0, 4.0];
        let sum: f32 = std::thread::scope(|s| {
            Prefetch::spawn(s, data.iter().map(|&x| x * 2.0), 2).sum()
        });
        assert_eq!(sum, 16.0);
    }
}
