//! Dataset transforms used by the paper's diagnostic experiments.
//!
//! Table 2 (right) ablates frequency imbalance by keeping only the top-3
//! most frequent ids per field and collapsing everything else into a
//! fourth "other" id, making every id frequent — under which the classic
//! scaling rules work again.

use super::dataset::Dataset;
use super::schema::Schema;
use super::stats::field_stats;

/// Collapse each categorical field to its `k` hottest ids plus one
/// "other" bucket (vocab becomes `min(vocab, k+1)` per field).
pub fn topk_collapse(ds: &Dataset, k: usize) -> Dataset {
    assert!(k >= 1);
    let stats = field_stats(ds);
    let offsets = ds.schema.offsets();

    // per field: map local id -> new local id (0..k-1 hot, k = other)
    let mut maps: Vec<Vec<i32>> = Vec::with_capacity(ds.schema.n_cat());
    let mut new_vocab: Vec<usize> = Vec::with_capacity(ds.schema.n_cat());
    for (f, &vocab) in ds.schema.vocab_sizes.iter().enumerate() {
        // recompute counts in local-id order to rank ids
        let mut counts = vec![0u64; vocab];
        for row in ds.x_cat.chunks(ds.schema.n_cat()) {
            counts[row[f] as usize - offsets[f]] += 1;
        }
        let mut ids: Vec<usize> = (0..vocab).collect();
        ids.sort_unstable_by_key(|&i| std::cmp::Reverse(counts[i]));
        let keep = k.min(vocab);
        let has_other = vocab > keep;
        let mut map = vec![keep as i32; vocab]; // default: "other"
        for (rank, &id) in ids.iter().take(keep).enumerate() {
            map[id] = rank as i32;
        }
        maps.push(map);
        new_vocab.push(keep + has_other as usize);
        let _ = &stats; // stats retained for potential diagnostics
    }

    let new_schema = Schema {
        name: format!("{}_top{}", ds.schema.name, k),
        n_dense: ds.schema.n_dense,
        vocab_sizes: new_vocab,
    };
    let new_offsets = new_schema.offsets();

    let mut out = Dataset::with_capacity(new_schema.clone(), ds.n());
    for row in ds.x_cat.chunks(ds.schema.n_cat()) {
        for (f, &gid) in row.iter().enumerate() {
            let local = gid as usize - offsets[f];
            out.x_cat.push(new_offsets[f] as i32 + maps[f][local]);
        }
    }
    out.x_dense = ds.x_dense.clone();
    out.y = ds.y.clone();
    out.ts = ds.ts.clone();
    out
}

/// Remap a collapsed dataset's ids onto a *target* schema (the artifact's
/// schema) so a top-k dataset can run through HLO programs compiled for
/// the full vocabulary: local id `l` of field `f` maps to global
/// `target_offset[f] + l` (always valid since collapsed vocab ≤ target).
pub fn reindex_to_schema(ds: &Dataset, target: &Schema) -> Dataset {
    assert_eq!(ds.schema.n_cat(), target.n_cat());
    assert_eq!(ds.schema.n_dense, target.n_dense);
    for (f, (&a, &b)) in ds.schema.vocab_sizes.iter().zip(&target.vocab_sizes).enumerate() {
        assert!(a <= b, "field {f}: collapsed vocab {a} exceeds target {b}");
    }
    let src_off = ds.schema.offsets();
    let dst_off = target.offsets();
    let mut out = Dataset::with_capacity(target.clone(), ds.n());
    for row in ds.x_cat.chunks(ds.schema.n_cat()) {
        for (f, &gid) in row.iter().enumerate() {
            let local = gid as usize - src_off[f];
            out.x_cat.push((dst_off[f] + local) as i32);
        }
    }
    out.x_dense = ds.x_dense.clone();
    out.y = ds.y.clone();
    out.ts = ds.ts.clone();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::criteo_synth;
    use crate::data::stats::global_counts;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn collapse_bounds_vocab_and_keeps_labels() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 3000, ..Default::default() });
        let top3 = topk_collapse(&ds, 3);
        top3.validate().unwrap();
        assert!(top3.schema.vocab_sizes.iter().all(|&v| v <= 4));
        assert_eq!(top3.y, ds.y);
        assert_eq!(top3.n(), ds.n());
    }

    #[test]
    fn collapse_makes_every_id_frequent() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 20_000, ..Default::default() });
        let top3 = topk_collapse(&ds, 3);
        let counts = global_counts(&top3);
        let n = top3.n() as f64;
        // every surviving id occurs with probability >> 1/4096
        let min_p = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| c as f64 / n)
            .fold(f64::INFINITY, f64::min);
        assert!(min_p > 1.0 / 4096.0, "min prob {min_p}");
    }

    #[test]
    fn hot_ids_keep_their_mass() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 5000, ..Default::default() });
        let before = global_counts(&ds);
        let hottest_before = *before.iter().max().unwrap();
        let top3 = topk_collapse(&ds, 3);
        let after = global_counts(&top3);
        // the per-field hottest id must keep an identical count
        assert!(after.iter().any(|&c| c == hottest_before));
    }

    #[test]
    fn reindex_preserves_structure() {
        let ds = generate(&criteo_synth(), &SynthConfig { n: 1000, ..Default::default() });
        let top3 = topk_collapse(&ds, 3);
        let re = reindex_to_schema(&top3, &criteo_synth());
        re.validate().unwrap();
        assert_eq!(re.schema.name, "criteo_synth");
        assert_eq!(re.y, ds.y);
        // collapsed field structure intact: ≤4 distinct ids per field
        let offs = re.schema.offsets();
        for f in 0..re.schema.n_cat() {
            let mut distinct: Vec<i32> = re
                .x_cat
                .chunks(re.schema.n_cat())
                .map(|r| r[f])
                .collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(distinct.len() <= 4);
            assert!(distinct.iter().all(|&g| g >= offs[f] as i32 && g < (offs[f] + 4) as i32));
        }
    }
}
