//! In-memory dataset + the `.ctr` binary on-disk format.
//!
//! Layout is struct-of-arrays for cache-friendly batch slicing:
//! `x_cat` holds **global** ids row-major `[n, n_cat]`, `x_dense` is
//! `[n, n_dense]`, labels are one byte each, and every row carries a
//! synthetic timestamp so the Criteo-seq sequential split is expressible.
//!
//! File format (little-endian):
//! ```text
//! magic "CTRD" | u32 version | u32 name_len | name bytes
//! u64 n | u32 n_cat | u32 n_dense | u32 n_vocab_sizes | u64 vocab sizes...
//! x_cat  (n * n_cat   * i32)
//! x_dense(n * n_dense * f32)
//! y      (n * u8)
//! ts     (n * u32)
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::schema::Schema;

const MAGIC: &[u8; 4] = b"CTRD";
const VERSION: u32 = 1;

/// A fully materialized CTR dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub schema: Schema,
    /// Row-major `[n, n_cat]` global ids.
    pub x_cat: Vec<i32>,
    /// Row-major `[n, n_dense]`.
    pub x_dense: Vec<f32>,
    /// Click labels (0/1).
    pub y: Vec<u8>,
    /// Monotone-ish synthetic timestamps (for the sequential split).
    pub ts: Vec<u32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Empty dataset with capacity `n`.
    pub fn with_capacity(schema: Schema, n: usize) -> Dataset {
        Dataset {
            x_cat: Vec::with_capacity(n * schema.n_cat()),
            x_dense: Vec::with_capacity(n * schema.n_dense),
            y: Vec::with_capacity(n),
            ts: Vec::with_capacity(n),
            schema,
        }
    }

    /// Positive-label rate.
    pub fn ctr(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().map(|&v| v as u64).sum::<u64>() as f64 / self.y.len() as f64
    }

    /// Borrow row `i`'s categorical ids.
    pub fn cat_row(&self, i: usize) -> &[i32] {
        let f = self.schema.n_cat();
        &self.x_cat[i * f..(i + 1) * f]
    }

    /// Borrow row `i`'s dense features.
    pub fn dense_row(&self, i: usize) -> &[f32] {
        let d = self.schema.n_dense;
        &self.x_dense[i * d..(i + 1) * d]
    }

    /// Select rows by index into a new dataset (used by splits).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::with_capacity(self.schema.clone(), idx.len());
        for &i in idx {
            out.x_cat.extend_from_slice(self.cat_row(i));
            out.x_dense.extend_from_slice(self.dense_row(i));
            out.y.push(self.y[i]);
            out.ts.push(self.ts[i]);
        }
        out
    }

    /// Validate invariants (id ranges, array lengths). Cheap enough to run
    /// after load; catches format drift immediately.
    pub fn validate(&self) -> Result<()> {
        let n = self.n();
        if self.x_cat.len() != n * self.schema.n_cat() {
            bail!("x_cat length mismatch");
        }
        if self.x_dense.len() != n * self.schema.n_dense {
            bail!("x_dense length mismatch");
        }
        if self.ts.len() != n {
            bail!("ts length mismatch");
        }
        let offsets = self.schema.offsets();
        let total = self.schema.total_vocab() as i32;
        for (i, row) in self.x_cat.chunks(self.schema.n_cat()).enumerate() {
            for (f, &id) in row.iter().enumerate() {
                let lo = offsets[f] as i32;
                let hi = lo + self.schema.vocab_sizes[f] as i32;
                if id < lo || id >= hi || id >= total {
                    bail!("row {i} field {f}: id {id} outside [{lo},{hi})");
                }
            }
        }
        Ok(())
    }

    /// Serialize to the `.ctr` binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let name = self.schema.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(self.n() as u64).to_le_bytes())?;
        w.write_all(&(self.schema.n_cat() as u32).to_le_bytes())?;
        w.write_all(&(self.schema.n_dense as u32).to_le_bytes())?;
        w.write_all(&(self.schema.vocab_sizes.len() as u32).to_le_bytes())?;
        for &v in &self.schema.vocab_sizes {
            w.write_all(&(v as u64).to_le_bytes())?;
        }
        for &v in &self.x_cat {
            w.write_all(&v.to_le_bytes())?;
        }
        for &v in &self.x_dense {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&self.y)?;
        for &v in &self.ts {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Deserialize from the `.ctr` binary format.
    pub fn load(path: &Path) -> Result<Dataset> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);

        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a .ctr file", path.display());
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported .ctr version {version}");
        }
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let n = read_u64(&mut r)? as usize;
        let n_cat = read_u32(&mut r)? as usize;
        let n_dense = read_u32(&mut r)? as usize;
        let n_vs = read_u32(&mut r)? as usize;
        let mut vocab_sizes = Vec::with_capacity(n_vs);
        for _ in 0..n_vs {
            vocab_sizes.push(read_u64(&mut r)? as usize);
        }
        if vocab_sizes.len() != n_cat {
            bail!("vocab_sizes/n_cat mismatch");
        }
        let schema = Schema {
            name: String::from_utf8(name)?,
            n_dense,
            vocab_sizes,
        };

        let mut x_cat = vec![0i32; n * n_cat];
        read_i32s(&mut r, &mut x_cat)?;
        let mut x_dense = vec![0f32; n * n_dense];
        read_f32s(&mut r, &mut x_dense)?;
        let mut y = vec![0u8; n];
        r.read_exact(&mut y)?;
        let mut ts_raw = vec![0u8; n * 4];
        r.read_exact(&mut ts_raw)?;
        let ts = ts_raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let ds = Dataset { schema, x_cat, x_dense, y, ts };
        ds.validate()?;
        Ok(ds)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_i32s(r: &mut impl Read, out: &mut [i32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (o, c) in out.iter_mut().zip(buf.chunks_exact(4)) {
        *o = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (o, c) in out.iter_mut().zip(buf.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::criteo_synth;

    fn tiny_dataset() -> Dataset {
        let schema = Schema {
            name: "t".into(),
            n_dense: 2,
            vocab_sizes: vec![3, 2],
        };
        Dataset {
            schema,
            x_cat: vec![0, 3, 2, 4, 1, 3],
            x_dense: vec![0.5, -1.0, 2.0, 0.0, 1.5, 3.25],
            y: vec![1, 0, 1],
            ts: vec![10, 20, 30],
        }
    }

    #[test]
    fn roundtrip_through_file() {
        let ds = tiny_dataset();
        let dir = std::env::temp_dir().join(format!("ctr_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.ctr");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.schema, ds.schema);
        assert_eq!(back.x_cat, ds.x_cat);
        assert_eq!(back.x_dense, ds.x_dense);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.ts, ds.ts);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_catches_out_of_range_ids() {
        let mut ds = tiny_dataset();
        ds.x_cat[0] = 4; // belongs to field 1, not field 0
        assert!(ds.validate().is_err());
    }

    #[test]
    fn select_preserves_rows() {
        let ds = tiny_dataset();
        let sub = ds.select(&[2, 0]);
        assert_eq!(sub.n(), 2);
        assert_eq!(sub.cat_row(0), ds.cat_row(2));
        assert_eq!(sub.cat_row(1), ds.cat_row(0));
        assert_eq!(sub.y, vec![1, 1]);
    }

    #[test]
    fn ctr_rate() {
        assert!((tiny_dataset().ctr() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn schema_presets_validate_empty() {
        let ds = Dataset::with_capacity(criteo_synth(), 0);
        ds.validate().unwrap();
    }
}
