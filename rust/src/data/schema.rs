//! Field layout of a CTR dataset (mirrors `python/compile/schemas.py`).
//!
//! The Rust presets are the ones data generation uses; an integration test
//! asserts byte-for-byte agreement with the schema embedded in
//! `artifacts/manifest.json` so the compile path can never drift.

/// Field layout: dense-field count plus per-categorical-field vocab sizes.
/// Categorical ids are globally offset into one concatenated table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    pub name: String,
    pub n_dense: usize,
    pub vocab_sizes: Vec<usize>,
}

impl Schema {
    pub fn n_cat(&self) -> usize {
        self.vocab_sizes.len()
    }

    pub fn total_vocab(&self) -> usize {
        self.vocab_sizes.iter().sum()
    }

    /// Iterate `(global_offset, vocab_size)` per categorical field
    /// without allocating — the clip hot loops use this instead of
    /// materializing [`Schema::offsets`] every step.
    pub fn fields(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.vocab_sizes.iter().scan(0usize, |acc, &v| {
            let off = *acc;
            *acc += v;
            Some((off, v))
        })
    }

    /// Global id offset of each categorical field.
    pub fn offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.vocab_sizes.len());
        let mut acc = 0;
        for &v in &self.vocab_sizes {
            offs.push(acc);
            acc += v;
        }
        offs
    }

    /// Which field owns a global id (panics if out of range).
    pub fn field_of(&self, global_id: usize) -> usize {
        assert!(global_id < self.total_vocab(), "id {global_id} out of range");
        let mut acc = 0;
        for (f, &v) in self.vocab_sizes.iter().enumerate() {
            acc += v;
            if global_id < acc {
                return f;
            }
        }
        unreachable!()
    }
}

/// Synthetic Criteo: 13 dense + 26 categorical fields (see DESIGN.md §4).
pub fn criteo_synth() -> Schema {
    Schema {
        name: "criteo_synth".into(),
        n_dense: 13,
        vocab_sizes: vec![
            10000, 10000, 8000, 4000, 4000, 2000, 2000, 2000, 1000, 1000, 1000, 500, 500,
            500, 500, 300, 300, 200, 100, 100, 50, 20, 10, 4, 3, 2,
        ],
    }
}

/// Synthetic Avazu: 24 categorical fields, no dense fields.
pub fn avazu_synth() -> Schema {
    Schema {
        name: "avazu_synth".into(),
        n_dense: 0,
        vocab_sizes: vec![
            8000, 8000, 4000, 2000, 2000, 1500, 1500, 1000, 500, 500, 500, 300, 300, 300,
            200, 200, 100, 100, 50, 20, 10, 5, 3, 2,
        ],
    }
}

/// Look up a preset schema by name.
pub fn by_name(name: &str) -> Option<Schema> {
    match name {
        "criteo_synth" => Some(criteo_synth()),
        "avazu_synth" => Some(avazu_synth()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fields_iterator_matches_offsets() {
        for schema in [criteo_synth(), avazu_synth()] {
            let offs = schema.offsets();
            let pairs: Vec<(usize, usize)> = schema.fields().collect();
            assert_eq!(pairs.len(), schema.n_cat());
            for (f, &(off, vs)) in pairs.iter().enumerate() {
                assert_eq!(off, offs[f]);
                assert_eq!(vs, schema.vocab_sizes[f]);
            }
        }
    }

    #[test]
    fn offsets_partition_vocab() {
        for schema in [criteo_synth(), avazu_synth()] {
            let offs = schema.offsets();
            assert_eq!(offs[0], 0);
            for i in 1..offs.len() {
                assert_eq!(offs[i], offs[i - 1] + schema.vocab_sizes[i - 1]);
            }
            assert_eq!(
                offs.last().unwrap() + schema.vocab_sizes.last().unwrap(),
                schema.total_vocab()
            );
        }
    }

    #[test]
    fn field_of_boundaries() {
        let s = criteo_synth();
        assert_eq!(s.field_of(0), 0);
        assert_eq!(s.field_of(9999), 0);
        assert_eq!(s.field_of(10000), 1);
        assert_eq!(s.field_of(s.total_vocab() - 1), s.n_cat() - 1);
    }

    #[test]
    fn presets_match_paper_field_counts() {
        assert_eq!(criteo_synth().n_cat(), 26);
        assert_eq!(criteo_synth().n_dense, 13);
        assert_eq!(avazu_synth().n_cat(), 24);
        assert_eq!(avazu_synth().n_dense, 0);
    }
}
