//! Dataset substrate: synthetic Criteo/Avazu-like CTR data.
//!
//! The paper's experiments run on Criteo (45M rows) and Avazu (32M rows),
//! which are not redistributable and far beyond this testbed; per
//! DESIGN.md §4 we substitute schema-faithful synthetic datasets whose id
//! frequencies follow the Zipf/exponential shape of the paper's Figure 4
//! and whose labels come from a hidden second-order "teacher" so AUC
//! responds to optimization quality.

pub mod batcher;
pub mod dataset;
pub mod prefetch;
pub mod schema;
pub mod split;
pub mod stats;
pub mod stream;
pub mod synth;
pub mod transform;

pub use batcher::{Batch, Batcher, EvalBatcher};
pub use prefetch::Prefetch;
pub use dataset::Dataset;
pub use schema::{Schema, avazu_synth, criteo_synth};
pub use split::{sequential_split, random_split};
pub use synth::{RowSampler, SynthConfig, generate};
