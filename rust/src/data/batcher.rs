//! Batch iteration: shuffled training epochs and padded eval batches.
//!
//! Training batches are fixed-size (the HLO artifacts are specialized per
//! microbatch shape) with drop-last semantics, reshuffled every epoch from
//! a deterministic stream. Eval batches pad the tail by repeating the last
//! row and report the valid count so metrics ignore padding.

use std::sync::OnceLock;

use anyhow::Result;

use super::dataset::Dataset;
use crate::tensor::Tensor;
use crate::util::Rng;

/// One host batch ready for literal conversion.
///
/// Construct with [`Batch::new`]; the touched-id set is computed lazily
/// (and cached) so a prefetch thread can pay for the sort while the
/// training thread is still busy with the previous step.
#[derive(Clone, Debug)]
pub struct Batch {
    /// `[b, n_cat]` global ids.
    pub x_cat: Tensor,
    /// `[b, n_dense]` (empty tensor when the schema has no dense fields).
    pub x_dense: Tensor,
    /// `[b]` labels as f32.
    pub y: Tensor,
    /// Number of non-padding rows (== b for training batches).
    pub valid: usize,
    /// Cached `touched()` result (sorted unique ids + counts).
    touched: OnceLock<(Vec<u32>, Vec<f32>)>,
}

impl Batch {
    pub fn new(x_cat: Tensor, x_dense: Tensor, y: Tensor, valid: usize) -> Batch {
        Batch { x_cat, x_dense, y, valid, touched: OnceLock::new() }
    }

    pub fn batch_size(&self) -> usize {
        self.x_cat.shape()[0]
    }

    /// Sorted unique global ids present in this batch plus per-id
    /// occurrence counts — the support set of the sparse embedding
    /// gradient and Alg. 1's full-batch `cnt(id)` in one pass.
    ///
    /// Computed once and cached; the data-pipeline prefetcher calls this
    /// on its background thread so the training thread gets a cache hit.
    pub fn touched(&self) -> Result<(Vec<u32>, Vec<f32>)> {
        let raw = self.x_cat.as_i32()?;
        Ok(self.touched.get_or_init(|| compute_touched(raw)).clone())
    }
}

/// Sorted unique ids + per-id occurrence counts of an arbitrary id
/// slice — the uncached form of [`Batch::touched`], used by the worker
/// fan-out for row-range shards of a batch (which borrow the batch's
/// storage instead of copying rows, so the batch-level cache does not
/// apply).
pub fn touched_of(raw: &[i32]) -> (Vec<u32>, Vec<f32>) {
    compute_touched(raw)
}

fn compute_touched(raw: &[i32]) -> (Vec<u32>, Vec<f32>) {
    let mut sorted: Vec<u32> = raw.iter().map(|&id| id as u32).collect();
    sorted.sort_unstable();
    let mut ids: Vec<u32> = Vec::new();
    let mut counts: Vec<f32> = Vec::new();
    for id in sorted {
        if ids.last() == Some(&id) {
            *counts.last_mut().unwrap() += 1.0;
        } else {
            ids.push(id);
            counts.push(1.0);
        }
    }
    (ids, counts)
}

fn materialize(ds: &Dataset, idx: &[usize]) -> Batch {
    let b = idx.len();
    let f = ds.schema.n_cat();
    let d = ds.schema.n_dense;
    let mut x_cat = Vec::with_capacity(b * f);
    let mut x_dense = Vec::with_capacity(b * d);
    let mut y = Vec::with_capacity(b);
    for &i in idx {
        x_cat.extend_from_slice(ds.cat_row(i));
        x_dense.extend_from_slice(ds.dense_row(i));
        y.push(ds.y[i] as f32);
    }
    Batch::new(
        Tensor::i32(vec![b, f], x_cat),
        Tensor::f32(vec![b, d], x_dense),
        Tensor::f32(vec![b], y),
        b,
    )
}

/// Shuffled fixed-size training batcher (drop-last).
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
    epoch: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> Batcher<'a> {
        assert!(batch > 0 && batch <= ds.n(), "batch {} vs n {}", batch, ds.n());
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..ds.n()).collect();
        rng.shuffle(&mut order);
        Batcher { ds, batch, order, pos: 0, rng, epoch: 0 }
    }

    /// Batches per epoch (drop-last).
    pub fn steps_per_epoch(&self) -> usize {
        self.ds.n() / self.batch
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Next fixed-size batch; reshuffles and bumps the epoch counter when
    /// the remaining tail is short.
    pub fn next_batch(&mut self) -> Batch {
        if self.pos + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        let b = materialize(self.ds, idx);
        self.pos += self.batch;
        b
    }
}

/// Sequential eval batcher with tail padding.
pub struct EvalBatcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    next_idx: usize,
}

impl<'a> EvalBatcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize) -> EvalBatcher<'a> {
        assert!(batch > 0);
        EvalBatcher { ds, batch, next_idx: 0 }
    }

    pub fn n_batches(&self) -> usize {
        self.ds.n().div_ceil(self.batch)
    }

    /// Materialize eval batch `i` (with tail padding) directly — the
    /// random-access unit the parallel evaluator hands to each thread.
    pub fn nth_batch(ds: &Dataset, batch: usize, i: usize) -> Option<Batch> {
        assert!(batch > 0);
        let pos = i * batch;
        if pos >= ds.n() {
            return None;
        }
        let end = (pos + batch).min(ds.n());
        let valid = end - pos;
        let mut idx: Vec<usize> = (pos..end).collect();
        // pad by repeating the final row to keep the artifact shape
        while idx.len() < batch {
            idx.push(end - 1);
        }
        let mut b = materialize(ds, &idx);
        b.valid = valid;
        Some(b)
    }
}

impl<'a> Iterator for EvalBatcher<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let b = EvalBatcher::nth_batch(self.ds, self.batch, self.next_idx)?;
        self.next_idx += 1;
        Some(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Schema;

    fn ds(n: usize) -> Dataset {
        let schema = Schema { name: "t".into(), n_dense: 1, vocab_sizes: vec![4, 3] };
        let mut d = Dataset::with_capacity(schema, n);
        for i in 0..n {
            d.x_cat.extend_from_slice(&[(i % 4) as i32, 4 + (i % 3) as i32]);
            d.x_dense.push(i as f32);
            d.y.push((i % 2) as u8);
            d.ts.push(i as u32);
        }
        d
    }

    #[test]
    fn training_batches_cover_epoch_without_repeats() {
        let d = ds(10);
        let mut b = Batcher::new(&d, 3, 0);
        assert_eq!(b.steps_per_epoch(), 3);
        let mut seen = Vec::new();
        for _ in 0..3 {
            let batch = b.next_batch();
            assert_eq!(batch.batch_size(), 3);
            seen.extend(batch.x_dense.as_f32().unwrap().iter().map(|&x| x as usize));
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 9, "no duplicates within an epoch");
        assert_eq!(b.epoch(), 0);
        b.next_batch(); // triggers reshuffle
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn shuffling_changes_order_between_epochs() {
        let d = ds(64);
        let mut b = Batcher::new(&d, 32, 1);
        let e0: Vec<f32> = b.next_batch().x_dense.as_f32().unwrap().to_vec();
        b.next_batch();
        let e1: Vec<f32> = b.next_batch().x_dense.as_f32().unwrap().to_vec();
        assert_ne!(e0, e1);
    }

    #[test]
    fn eval_batcher_pads_tail() {
        let d = ds(7);
        let mut it = EvalBatcher::new(&d, 4);
        assert_eq!(it.n_batches(), 2);
        let b0 = it.next().unwrap();
        assert_eq!(b0.valid, 4);
        let b1 = it.next().unwrap();
        assert_eq!(b1.valid, 3);
        assert_eq!(b1.batch_size(), 4);
        // padded row repeats the last valid row
        let cats = b1.x_cat.as_i32().unwrap();
        assert_eq!(&cats[4..6], &cats[6..8]);
        assert!(it.next().is_none());
    }

    #[test]
    fn touched_ids_sorted_unique_with_counts() {
        let batch = Batch::new(
            Tensor::i32(vec![3, 2], vec![4, 0, 4, 2, 0, 4]),
            Tensor::f32(vec![3, 0], vec![]),
            Tensor::f32(vec![3], vec![0.0; 3]),
            3,
        );
        let (ids, counts) = batch.touched().unwrap();
        assert_eq!(ids, vec![0, 2, 4]);
        assert_eq!(counts, vec![2.0, 1.0, 3.0]);
        assert_eq!(counts.iter().sum::<f32>(), 6.0);
        // second call hits the cache and must agree
        let (ids2, counts2) = batch.touched().unwrap();
        assert_eq!(ids, ids2);
        assert_eq!(counts, counts2);
    }

    #[test]
    fn nth_batch_matches_iterator() {
        let d = ds(10);
        let it: Vec<Batch> = EvalBatcher::new(&d, 4).collect();
        for (i, b) in it.iter().enumerate() {
            let nth = EvalBatcher::nth_batch(&d, 4, i).unwrap();
            assert_eq!(nth.valid, b.valid);
            assert_eq!(nth.x_cat.as_i32().unwrap(), b.x_cat.as_i32().unwrap());
        }
        assert!(EvalBatcher::nth_batch(&d, 4, it.len()).is_none());
    }

    #[test]
    fn deterministic_given_seed() {
        let d = ds(20);
        let a: Vec<i32> = Batcher::new(&d, 5, 9).next_batch().x_cat.as_i32().unwrap().to_vec();
        let b: Vec<i32> = Batcher::new(&d, 5, 9).next_batch().x_cat.as_i32().unwrap().to_vec();
        assert_eq!(a, b);
    }
}
