//! # CowClip — large-batch CTR training, reproduced end to end
//!
//! This crate is the Layer-3 coordinator of the three-layer reproduction of
//! *CowClip: Reducing CTR Prediction Model Training Time from 12 Hours to
//! 10 Minutes on 1 GPU* (Zheng et al., AAAI 2023):
//!
//! * **L1** — Pallas kernels (adaptive column-wise clipping, FM interaction)
//!   authored in `python/compile/kernels/`, correctness-gated against
//!   pure-jnp oracles.
//! * **L2** — the four CTR models (W&D, DeepFM, DCN, DCN-v2) + Adam and the
//!   clipping variants as JAX programs, AOT-lowered to HLO text under
//!   `artifacts/`.
//! * **L3** — this crate: the synthetic dataset substrate, the
//!   leader/worker data-parallel coordinator, the scaling-rule engine, the
//!   metrics stack, and the experiment harness that regenerates every table
//!   and figure of the paper. Python never runs on the training path.
//!
//! Entry points: the `cowclip` binary (see `cli`), the five `examples/`,
//! and the criterion benches. Start with [`runtime::Engine`] +
//! [`coordinator::Trainer`] if you are embedding the library.

pub mod cli;
pub mod clip;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod reference;
pub mod runtime;
pub mod scaling;
pub mod sim;
pub mod tensor;
pub mod util;

pub use anyhow::{Error, Result};
