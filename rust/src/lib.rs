//! # CowClip — large-batch CTR training, reproduced end to end
//!
//! This crate is the Layer-3 coordinator of the three-layer reproduction of
//! *CowClip: Reducing CTR Prediction Model Training Time from 12 Hours to
//! 10 Minutes on 1 GPU* (Zheng et al., AAAI 2023):
//!
//! * **L1** — Pallas kernels (adaptive column-wise clipping, FM interaction)
//!   authored in `python/compile/kernels/`, correctness-gated against
//!   pure-jnp oracles.
//! * **L2** — the four CTR models (W&D, DeepFM, DCN, DCN-v2) + Adam and the
//!   clipping variants as JAX programs, AOT-lowered to HLO text under
//!   `artifacts/`.
//! * **L3** — this crate: the synthetic dataset substrate, the
//!   leader/worker data-parallel coordinator, the scaling-rule engine, the
//!   metrics stack, and the experiment harness that regenerates every table
//!   and figure of the paper. Python never runs on the training path.
//!
//! ## The sparse gradient path
//!
//! Id frequencies in CTR data are wildly skewed, so a batch touches only
//! a small fraction of the `[V, d]` embedding table. The coordinator's
//! hot loop exploits that end to end: [`data::Batch::touched`] emits the
//! sorted unique-id list per (micro)batch, the reference backward pass
//! scatters into packed [`tensor::SparseRows`], accumulation and the
//! tree all-reduce merge `(row_ids, grads, counts)` triples as sorted-id
//! unions, all six clipping modes have sparse implementations
//! ([`clip::clip_embedding_grads_sparse`]), and [`optim::LazyAdam`]
//! applies closed-form bias-corrected moment decay on first touch — so
//! per-step embedding cost is O(touched · d), not O(V · d). Dense
//! `tensor::GradTensor` payloads (the HLO path) flow through the same
//! coordinator types and densify only at the apply-program boundary.
//!
//! ## Parallel execution and the shard-owned parameter store
//!
//! Every step runs on a parallel engine built from std threads +
//! channels (no dependencies). Parameters and optimizer state live in
//! the shard-owned [`model::store::ParamStore`] — weights behind a
//! `RwLock`, Adam moments / lazy-Adam rows / maintained per-field norms
//! behind a `Mutex` — which inverts the old leader-owned-`ParamSet`
//! model so every stage of the step can parallelize:
//!
//! * **Fan-out** — `WorkerShard::compute` jobs run on a persistent
//!   [`coordinator::StepPool`] spawned once per `train()` (no per-step
//!   thread spawn); workers take read locks on the weights and jobs
//!   carry the batch as an `Arc`.
//! * **Reduce-as-ready** — contributions stream into a
//!   [`coordinator::StreamingReducer`] that merges them **in rank
//!   order** as they land — the slowest shard's gradient overlaps the
//!   reduction of everything before it, and the fixed merge order makes
//!   any thread count bitwise-reproduce the sequential run
//!   (`rust/tests/parallel_parity.rs`).
//! * **Sharded apply** — the merged gradient is partitioned by the
//!   store's field-aligned `ShardPlan` (row ranges for the embed/wide
//!   tables, grouped whole tensors for the dense params) and CowClip's
//!   `clip → L2 → Adam` runs per shard on scoped threads, each owning
//!   disjoint `&mut` slices of weights + moments. Field alignment keeps
//!   every clip mode shard-local (`Global` gets its whole-table norm
//!   precomputed), and maintained per-field `Σw²` makes sparse AdaField
//!   O(touched) instead of re-scanning the table. Any shard count
//!   bitwise-matches the serial path (`rust/tests/shard_parity.rs`).
//!
//! A scoped [`data::Prefetch`] thread double-buffers the batch pipeline
//! (materialization + the touched-id sort for step `N+1` overlap step
//! `N`), and eval batches fan out the same way with order-preserving
//! accumulation. `threads = 1` reproduces the fully sequential seed
//! path; `0` (auto) uses one thread per core; `param_shards` sizes the
//! apply stage the same way. Checkpoints (`CCKS`) carry params, both
//! Adam moments, the lazy-Adam row clocks and the step counter, in a
//! shard-count-independent layout that still round-trips the PR-1
//! `CCKP` params format — `--resume` continues warmup and bias
//! correction exactly where a run stopped.
//!
//! ## Online serving
//!
//! The train → serve loop closes in [`serve`]: a checkpoint saved with
//! `train --save` loads into an immutable, `Arc`-shared
//! [`serve::ServeModel`] (the `CCKS`/`CCKP` artifact *is* the
//! deployment unit), and single-impression requests flow through a
//! micro-batching queue — **enqueue → coalesce → score → respond** —
//! where a micro-batch drains on a max-batch-size or latency-deadline
//! trigger and scores on a pool of threads via the reference model's
//! inference-only forward (no grad buffers, no locks on the hot path).
//! Embedding/wide tables optionally quantize to u16 codes with
//! per-field affine constants (`--quant`, ~2× less serving memory, a
//! documented dequantization error bound), request load comes from the
//! same Zipf id model the synthesizer trains on
//! ([`data::synth::RowSampler`]), and latency lands in a fixed-bucket
//! histogram ([`metrics::LatencyHistogram`], p50/p90/p99 + QPS).
//! `cowclip inspect <ckpt>` sanity-checks an artifact before rollout;
//! `rust/tests/serve_parity.rs` pins served scores to the offline
//! forward pass at any arrival order and thread count.
//!
//! ## Features
//!
//! The `pjrt` cargo feature (off by default) compiles the real
//! XLA/PJRT runtime backend; the default build substitutes a pure-Rust
//! stub so `cargo build --release && cargo test -q` needs no artifacts
//! and no XLA toolchain. See `runtime` for details.
//!
//! ## Benches
//!
//! `cargo bench` runs the plain-binary benches under `benches/`:
//! `clip_throughput` (dense vs sparse clipping arms + speedup),
//! `e2e_epoch` (sparse vs dense reference trainer, plus the HLO ladder
//! when artifacts exist), `fig1_step_time`, `data_pipeline`,
//! `metrics_auc`.
//!
//! Entry points: the `cowclip` binary (see `cli`), the five `examples/`,
//! and the benches above. Start with [`runtime::Runtime`] +
//! [`coordinator::Trainer`] if you are embedding the library.

pub mod cli;
pub mod clip;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod reference;
pub mod runtime;
pub mod scaling;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod util;

pub use anyhow::{Error, Result};
