//! # CowClip — large-batch CTR training, reproduced end to end
//!
//! This crate is the Layer-3 coordinator of the three-layer reproduction of
//! *CowClip: Reducing CTR Prediction Model Training Time from 12 Hours to
//! 10 Minutes on 1 GPU* (Zheng et al., AAAI 2023):
//!
//! * **L1** — Pallas kernels (adaptive column-wise clipping, FM interaction)
//!   authored in `python/compile/kernels/`, correctness-gated against
//!   pure-jnp oracles.
//! * **L2** — the four CTR models (W&D, DeepFM, DCN, DCN-v2) + Adam and the
//!   clipping variants as JAX programs, AOT-lowered to HLO text under
//!   `artifacts/`.
//! * **L3** — this crate: the synthetic dataset substrate, the
//!   leader/worker data-parallel coordinator, the scaling-rule engine, the
//!   metrics stack, and the experiment harness that regenerates every table
//!   and figure of the paper. Python never runs on the training path.
//!
//! ## The sparse gradient path
//!
//! Id frequencies in CTR data are wildly skewed, so a batch touches only
//! a small fraction of the `[V, d]` embedding table. The coordinator's
//! hot loop exploits that end to end: [`data::Batch::touched`] emits the
//! sorted unique-id list per (micro)batch, the reference backward pass
//! scatters into packed [`tensor::SparseRows`], accumulation and the
//! tree all-reduce merge `(row_ids, grads, counts)` triples as sorted-id
//! unions, all six clipping modes have sparse implementations
//! ([`clip::clip_embedding_grads_sparse`]), and [`optim::LazyAdam`]
//! applies closed-form bias-corrected moment decay on first touch — so
//! per-step embedding cost is O(touched · d), not O(V · d). Dense
//! `tensor::GradTensor` payloads (the HLO path) flow through the same
//! coordinator types and densify only at the apply-program boundary.
//!
//! ## Parallel execution and the shard-owned parameter store
//!
//! Every step runs on a parallel engine built from std threads +
//! channels (no dependencies). Parameters and optimizer state live in
//! the shard-owned [`model::store::ParamStore`] — weights behind a
//! `RwLock`, Adam moments / lazy-Adam rows / maintained per-field norms
//! behind a `Mutex` — which inverts the old leader-owned-`ParamSet`
//! model so every stage of the step can parallelize:
//!
//! * **Fan-out** — `WorkerShard::compute` jobs run on a persistent
//!   [`coordinator::StepPool`] spawned once per `train()` (no per-step
//!   thread spawn); workers take read locks on the weights, jobs carry
//!   the batch as an `Arc`, and each worker reads its row range **in
//!   place** (no per-step row copies).
//! * **Tree reduce-as-ready** — contributions stream into a
//!   [`coordinator::TreeReducer`] that merges them along a **fixed
//!   binary tree over contiguous rank ranges** as they land: the
//!   slowest shard's gradient overlaps the reduction of everything
//!   else, the post-arrival critical path is O(log W) merges, and the
//!   worker-count-only pairing makes any thread count and any arrival
//!   order bitwise-reproduce the same result
//!   (`rust/tests/parallel_parity.rs`).
//! * **Sharded apply, overlapped with the merge tail** — the reducer
//!   withholds the *root* merge ([`coordinator::Reduced::Halves`]); the
//!   store splits it per field-aligned `ShardPlan` row range and runs
//!   each slice inside that shard's own `clip → L2 → Adam` task on
//!   scoped threads, each owning disjoint `&mut` slices of weights +
//!   moments — apply starts on a shard's range while other ranges are
//!   still merging. Field alignment keeps every clip mode shard-local
//!   (`Global` needs the whole-table merged norm and takes the eager
//!   path), and maintained per-field `Σw²` makes sparse AdaField
//!   O(touched) instead of re-scanning the table. Any shard count
//!   bitwise-matches the serial path (`rust/tests/shard_parity.rs`).
//!
//! A scoped [`data::Prefetch`] thread double-buffers the batch pipeline
//! (materialization + the touched-id sort for step `N+1` overlap step
//! `N`), and eval batches fan out the same way with order-preserving
//! accumulation. `threads = 1` reproduces the fully sequential seed
//! path; `0` (auto) uses one thread per core; `param_shards` sizes the
//! apply stage the same way. Checkpoints (`CCKS`) carry params, both
//! Adam moments, the lazy-Adam row clocks and the step counter, in a
//! shard-count-independent layout that still round-trips the PR-1
//! `CCKP` params format — `--resume` continues warmup and bias
//! correction exactly where a run stopped.
//!
//! ## Online serving
//!
//! The train → serve loop closes in [`serve`]: a checkpoint saved with
//! `train --save` loads into an immutable, `Arc`-shared
//! [`serve::ServeModel`] (the `CCKS`/`CCKP` artifact *is* the
//! deployment unit), and single-impression requests flow through a
//! micro-batching queue — **enqueue → coalesce → score → respond** —
//! where a micro-batch drains on a max-batch-size or latency-deadline
//! trigger and scores on a pool of threads via the reference model's
//! inference-only forward (no grad buffers, no locks on the hot path).
//! Embedding/wide tables optionally quantize to u16 codes with
//! per-field affine constants (`--quant`, ~2× less serving memory, a
//! documented dequantization error bound), request load comes from the
//! same Zipf id model the synthesizer trains on
//! ([`data::synth::RowSampler`]), and latency lands in a fixed-bucket
//! histogram ([`metrics::LatencyHistogram`], p50/p90/p99 + QPS).
//! `cowclip inspect <ckpt>` sanity-checks an artifact before rollout;
//! `rust/tests/serve_parity.rs` pins served scores to the offline
//! forward pass at any arrival order and thread count.
//!
//! ## Distributed training
//!
//! The in-process tree reducer promotes to real multi-process data
//! parallelism in [`coordinator::dist`]: a coordinator binds a Unix (or
//! `tcp:`) endpoint and `N` `cowclip worker --rank R --ranks N`
//! processes connect over the [`wire`] layer — 16-byte CRC-framed
//! messages carrying a versioned sparse `(row_ids, grads, counts)`
//! contribution codec. Every process rebuilds identical replica state
//! from the seed (same init, same [`data::Batcher`] stream), so **no
//! batch or parameter data crosses the wire** — only gradients do. The
//! coordinator merges the `N` per-rank contributions along the same
//! fixed binary tree as the threaded path, broadcasts the reduced total
//! losslessly, and every process applies those identical bytes: with
//! compression off a distributed run is **bitwise identical** to the
//! sequential seed path for every clip mode and any rank count
//! (`rust/tests/dist_parity.rs`). The uplink optionally quantizes
//! sparse embedding gradients to u16/u8 codes with per-rank
//! error-feedback residuals ([`wire::Compression`], `--compress u8`),
//! cutting sparse wire bytes ≥4× at ≤1e-3 AUC cost; ids, counts and
//! dense gradients stay lossless, and shared grad/count id lists are
//! elided entirely. A deadline on every socket operation turns a killed
//! or hung rank into a clean failure signal instead of a hang — and the
//! fault-tolerance layer below turns that signal into recovery. `cargo
//! bench --bench e2e_epoch` writes the distributed arm's rows/s, wire
//! bytes/step and compression ratio to `BENCH_dist.json`.
//!
//! ## Fault tolerance
//!
//! A distributed run survives the failure modes a real fleet produces —
//! killed workers, hung workers, corrupted frames, lost coordinators —
//! without giving up determinism ([`coordinator::dist`] "Fault
//! tolerance" for the protocol, [`coordinator::chaos`] for the fault
//! injector, `rust/tests/fault_parity.rs` for the gates):
//!
//! * **Step-atomic recovery** — the coordinator applies a step only
//!   once every rank's contribution has arrived, so a mid-step rank
//!   loss never leaves partial state: already-read contributions are
//!   retained, the dead rank is parked, and a recovery window (3× the
//!   io deadline) opens for the rank to rejoin. The rejoin handshake is
//!   versioned — `Hello` carries the worker's last completed step and a
//!   [`coordinator::TrainConfig::fingerprint`] of the training
//!   configuration — and a rejoining worker catches up by **local
//!   replay** of the committed prefix from its deterministic
//!   [`data::Batcher`] stream (no parameter shipping). Requires
//!   `--compress none`; with lossy uplink compression recovery is
//!   refused by name. A run that loses a rank mid-step finishes
//!   **bitwise identical** to the fault-free sequential path for all
//!   six clip modes.
//! * **Bounded retransmission** — a CRC-corrupt frame is healed in
//!   place by the wire link's Nack/Resend exchange
//!   ([`wire::FrameLink`]) within `--retransmit-budget` tries, then
//!   fails by name; worker reconnects back off exponentially with
//!   jitter. `--max-restarts` caps rejoins per rank (`0` restores
//!   fail-fast), `--spawn-workers` respawns dead children, and
//!   `--snapshot-every` writes periodic CCKS snapshots so a killed
//!   *coordinator* restarts from the last committed step via
//!   `--resume`.
//! * **Deterministic fault injection** — `--chaos
//!   "kill:rank=1,step=4;corrupt:rank=0,step=2"` schedules seeded
//!   kill/hang/corrupt/drop/trunc/delay faults against exact ranks and
//!   steps ([`coordinator::ChaosSpec`]), which is what lets the test
//!   suite assert *bitwise* recovery rather than eventual convergence.
//!   Recovery is observable: `dist.reconnects`, `dist.retransmits`,
//!   `dist.recovered_steps`, `dist.dead_ranks` and
//!   `serve.rejected`/`dist.error_fanout_dropped` land in the metrics
//!   registry, and the serve queue sheds overload past `--max-queue`
//!   with a typed [`serve::Overloaded`] error instead of queueing
//!   unboundedly.
//!
//! ## Performance model
//!
//! The single-machine step loop is engineered so that, at steady state,
//! the compute path touches neither the allocator nor any redundant
//! memory traffic:
//!
//! * **Kernels** ([`reference::simd`]) — explicit SIMD microkernels
//!   (AVX2+FMA 4×8 tiles on x86_64, NEON 4×4 on aarch64) behind a
//!   [`reference::Kernels`] vtable resolved **once at startup** from
//!   CPU feature detection, `COWCLIP_KERNEL={auto,scalar,avx2,neon}`,
//!   or the `--kernel` CLI flag. The portable blocked kernels in
//!   [`reference::linalg`] (`i-k-j` matmuls with row-axpy inner loops,
//!   8-lane dot products) remain the scalar fallback tier, and the
//!   original scalar loops are kept verbatim in `linalg::naive` as
//!   correctness oracles — every SIMD kernel is pinned against them by
//!   `rust/tests/kernel_parity.rs` (≤1e-6, odd shapes, remainder
//!   lanes) and raced by `benches/kernels.rs`.
//! * **Fused passes** ([`reference::layers`]) — the embedding gather
//!   writes straight into the deep-stream `x0` concat layout (the
//!   first `F·d` columns *are* the embeds tensor), DeepFM's FM term and
//!   the embedding backward read it strided in place, and the serving
//!   tier gathers + dequantizes + wide-sums in one pass per request.
//! * **Scratch ownership** ([`reference::Scratch`]) — every
//!   forward/backward/infer intermediate comes from a per-thread
//!   free-list arena and returns to it; worker-pool threads, the
//!   trainer's inline fan-out, eval threads and the serving queue's
//!   scoring threads each own one for the lifetime of the run. After a
//!   one-step warmup the arena's `grow_events()` counter stays flat —
//!   tested at the model, trainer and serving levels — so the only
//!   per-step allocations are the escaping gradient payloads
//!   themselves.
//! * **Determinism story** — the tree reducer's pairing is a function
//!   of the worker count alone (left-ceiling split of contiguous rank
//!   ranges), so any arrival order, thread count or shard count
//!   produces bitwise-identical training; the deferred root merge is
//!   row-local, so executing it per shard range inside apply cannot
//!   change a single bit (`apply_sharded_pair` vs eager-merge is
//!   pinned exactly in `model::store` tests).
//!
//! Bench recipe: `cargo bench --bench kernels` (per-kernel GFLOP/s +
//! SIMD-vs-scalar speedup, written to `BENCH_kernels.json`) and
//! `cargo bench --bench e2e_epoch` (absolute full-step throughput — the
//! cross-PR comparison number, written to `BENCH_e2e.json`). No
//! `RUSTFLAGS=-C target-cpu=native` is needed anymore: the SIMD tier is
//! selected by **runtime dispatch**, so a plain release build runs the
//! widest kernels the host supports (override with `COWCLIP_KERNEL=`
//! or `--kernel` to pin a tier, e.g. `scalar` for cross-host bitwise
//! reproduction). The release profile builds with `lto = "thin"` and
//! `codegen-units = 1` so the scalar tier still inlines across module
//! boundaries.
//!
//! ## Observability
//!
//! The [`obs`] subsystem unifies telemetry across train/dist/serve with
//! zero dependencies and a hard **inertness contract**: observability
//! reads the clock and writes to obs-private atomics only, so every
//! parity suite (parallel/shard/serve/dist/kernel) passes
//! bitwise-unchanged with tracing and metrics enabled
//! (`rust/tests/obs_parity.rs`), and steady-state recording is
//! allocation-free and lock-free.
//!
//! * **Span tracing** ([`obs::span`](mod@obs::span)) — preallocated
//!   per-thread ring buffers record the step-phase taxonomy
//!   (`prefetch`, `gather`, `forward`, `backward`, `clip`, `reduce`,
//!   `wire-tx`, `wire-rx`, `apply`, `eval`, `serve-score`) with
//!   thread + rank attribution; `--trace <path>` exports a
//!   chrome://tracing-compatible JSON timeline. With tracing off a span
//!   call site costs one relaxed atomic load.
//! * **Metrics registry** ([`obs::registry`]) — fixed-slot atomic
//!   counters, gauges and histograms (the serve histogram's bucket
//!   math, generalized into [`obs::hist`]), registered once at startup;
//!   hot-path updates are single relaxed atomic operations. The trainer
//!   step loop, `StepPool`, `Prefetch`, the reducers, the dist
//!   coordinator (per-rank wire bytes, compression ratio, EF residual,
//!   deadline/stall counters) and the serve queue all publish here.
//! * **Exposition** ([`obs::snapshot`], [`obs::expose`]) — periodic
//!   JSONL snapshots (`--metrics-interval`, schema
//!   `cowclip-metrics-v1`), a Prometheus-style text dump at serve
//!   shutdown, and `cowclip metrics --connect <ep>` for a live one-shot
//!   pull over the wire `MetricsReq`/`Metrics` frames
//!   (`--metrics-bind`). The benches share the same serializer:
//!   `BENCH_kernels.json` / `BENCH_e2e.json` / `BENCH_dist.json` all
//!   carry the `cowclip-bench-v1` schema.
//!
//! ## Enforced invariants
//!
//! The promises above are policed structurally by `cowclip-lint` (the
//! `lint/` workspace member), a dependency-free static analysis pass
//! that runs blocking in CI (`cargo run -p cowclip-lint`, tests via
//! `cargo test -p cowclip-lint`). Six rule families over `rust/src`:
//!
//! 1. **hotpath-alloc** — the hot-path roots registered in
//!    `lint/hotpath.toml` (training forward/backward, clip, lazy Adam,
//!    tree-reduce merge, serve scoring) must not reach a forbidden
//!    allocation token (`Vec::new`, `vec![]`, `.clone()`, `.collect()`,
//!    `format!`, …) through the crate-local call graph.
//! 2. **determinism** — no `HashMap`/`HashSet` and no float sums over
//!    unordered iterators in `coordinator/`, `clip/`, `optim/`,
//!    `reference/` (bit-exact parity depends on ordered reduction).
//! 3. **panic** — no `unwrap`/`expect`/panicking macros/slice indexing
//!    in the serve request lifecycle (`serve/{queue,request,model}.rs`);
//!    locks there recover from poisoning via
//!    `unwrap_or_else(PoisonError::into_inner)`.
//! 4. **lock-order** — the "held while acquiring" graph over
//!    `ParamStore.weights`/`ParamStore.opt`/`StepPool.jobs` and the
//!    serve-queue locks must stay cycle-free.
//! 5. **unsafe-confinement** — the token `unsafe` may appear only under
//!    `reference/simd/` (the intrinsics microkernels); everywhere else
//!    it is a lint violation, mirroring the compiler-level policy below.
//! 6. **obs-inert** — obs calls reachable from the hot-path roots must
//!    resolve only into the alloc-free recording API
//!    (`obs::span` / `obs::span_rank` / `obs::tracing_on`); metric
//!    registration, snapshotting and export are flagged if they leak
//!    into a hot path.
//!
//! Escape hatch, per line and audited: a trailing or preceding comment
//! `lint:allow(<rule-id>): <justification>` — the justification is
//! mandatory. The crate compiles under `#![deny(unsafe_code)]` and
//! `#![deny(unused_must_use)]`; the **only** `#[allow(unsafe_code)]`
//! opt-ins live in `reference/simd/{x86,neon}.rs`, where every unsafe
//! `#[target_feature]` inner function is reachable solely through a
//! vtable installed after runtime feature detection (see
//! [`reference::simd`] for the safety argument). The concurrency-heavy
//! parity suites run under ThreadSanitizer in CI's `sanitize` job.
//!
//! ## Features
//!
//! The `pjrt` cargo feature (off by default) compiles the real
//! XLA/PJRT runtime backend; the default build substitutes a pure-Rust
//! stub so `cargo build --release && cargo test -q` needs no artifacts
//! and no XLA toolchain. See `runtime` for details.
//!
//! ## Benches
//!
//! `cargo bench` runs the plain-binary benches under `benches/`:
//! `kernels` (SIMD vs scalar vs naive kernel tiers, fused gathers),
//! `clip_throughput` (dense vs sparse clipping arms + speedup),
//! `e2e_epoch` (hot-path throughput, threaded and sharded-apply arms,
//! plus the HLO ladder when artifacts exist), `fig1_step_time`,
//! `data_pipeline`, `serve_throughput`, `metrics_auc`.
//!
//! Entry points: the `cowclip` binary (see `cli`), the five `examples/`,
//! and the benches above. Start with [`runtime::Runtime`] +
//! [`coordinator::Trainer`] if you are embedding the library.

// `deny` (not `forbid`) so `reference/simd/{x86,neon}.rs` can opt in
// with a scoped `#![allow(unsafe_code)]` — the only place the token is
// legal, enforced a second time by cowclip-lint's unsafe-confinement
// rule.
#![deny(unsafe_code)]
#![deny(unused_must_use)]

pub mod cli;
pub mod clip;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod reference;
pub mod runtime;
pub mod scaling;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod wire;

pub use anyhow::{Error, Result};
