//! A logical data-parallel worker: computes its shard's weighted gradient
//! contribution by accumulating engine-supported microbatches.
//!
//! On the reference engine the worker reads its row range **in place**
//! through [`Engine::grad_range`] — no per-step row copies — and runs on
//! a caller-owned [`Scratch`] arena, so the steady-state compute path
//! performs no heap allocation beyond the escaping gradient payloads.
//! [`slice_batch`] remains for the HLO path (its programs need owned
//! microbatch tensors) and for tests.

use anyhow::{bail, Result};

use super::accumulate::GradAccumulator;
use super::allreduce::Contribution;
use super::engine::Engine;
use crate::data::batcher::Batch;
use crate::model::params::ParamSet;
use crate::reference::Scratch;
use crate::tensor::Tensor;

/// One worker's identity + shard geometry.
#[derive(Clone, Copy, Debug)]
pub struct WorkerShard {
    pub rank: usize,
    pub world: usize,
}

impl WorkerShard {
    pub fn new(rank: usize, world: usize) -> WorkerShard {
        assert!(rank < world && world > 0);
        WorkerShard { rank, world }
    }

    /// Row range of this worker within a batch of `b` rows (even split;
    /// `b` must divide by `world`).
    pub fn range(&self, b: usize) -> (usize, usize) {
        assert_eq!(b % self.world, 0, "batch {b} not divisible by world {}", self.world);
        let per = b / self.world;
        (self.rank * per, (self.rank + 1) * per)
    }

    /// Pick the largest supported microbatch that divides `shard_rows`
    /// (reference engine supports everything → use the shard whole).
    pub fn plan_microbatch(&self, shard_rows: usize, supported: &[usize]) -> Result<usize> {
        if supported.is_empty() {
            return Ok(shard_rows);
        }
        supported
            .iter()
            .rev()
            .copied()
            .find(|mb| shard_rows % mb == 0)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no supported microbatch divides shard of {shard_rows} rows (have {supported:?})"
                )
            })
    }

    /// Compute this worker's contribution for its slice of `batch`,
    /// weighted by `shard_rows / batch_rows`. Intermediates run on the
    /// caller's `scratch` arena (one per worker thread, reused across
    /// steps).
    pub fn compute(
        &self,
        engine: &Engine,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut Scratch,
    ) -> Result<Contribution> {
        let b = batch.batch_size();
        let (lo, hi) = self.range(b);
        let rows = hi - lo;
        let mb = self.plan_microbatch(rows, &engine.grad_batch_sizes())?;
        let vocab = engine.schema().total_vocab();
        let shard_weight = rows as f64 / b as f64;
        let mb_weight = shard_weight * (mb as f64 / rows as f64);

        let mut acc = GradAccumulator::new(vocab);
        let mut start = lo;
        while start < hi {
            let out = engine.grad_range(params, batch, start, start + mb, scratch)?;
            acc.add_owned(out, mb_weight)?;
            start += mb;
        }
        // The leader-side finish() contract requires total weight 1.0;
        // a worker's partial contribution carries shard_weight instead.
        let (grads, counts, loss_weighted, w) = acc.into_parts();
        if (w - shard_weight).abs() > 1e-4 {
            bail!("worker {} accumulated weight {w}, expected {shard_weight}", self.rank);
        }
        let grads = grads.ok_or_else(|| anyhow::anyhow!("empty shard"))?;
        Ok(Contribution { grads, counts, loss_weighted, weight: shard_weight as f32 })
    }
}

/// A worker's view of its rows: borrows the whole batch when the shard
/// covers it (the 1-worker / whole-shard case — no per-step copy, and
/// the prefetcher-warmed `touched()` cache is shared), owns a copy
/// otherwise. Derefs to [`Batch`] so both cases feed `Engine::grad`
/// unchanged.
pub enum BatchSlice<'a> {
    Whole(&'a Batch),
    Owned(Batch),
}

impl std::ops::Deref for BatchSlice<'_> {
    type Target = Batch;

    fn deref(&self) -> &Batch {
        match self {
            BatchSlice::Whole(b) => b,
            BatchSlice::Owned(b) => b,
        }
    }
}

/// Rows `[lo, hi)` of a batch: a borrow when the range is the whole
/// batch, a row copy otherwise.
pub fn slice_batch(batch: &Batch, lo: usize, hi: usize) -> Result<BatchSlice<'_>> {
    let b = batch.batch_size();
    if hi > b || lo >= hi {
        bail!("slice [{lo},{hi}) out of range for batch {b}");
    }
    if lo == 0 && hi == b {
        return Ok(BatchSlice::Whole(batch));
    }
    let f = batch.x_cat.shape()[1];
    let d = batch.x_dense.shape()[1];
    let rows = hi - lo;
    let cat = batch.x_cat.as_i32()?;
    let dense = batch.x_dense.as_f32()?;
    let y = batch.y.as_f32()?;
    Ok(BatchSlice::Owned(Batch::new(
        Tensor::i32(vec![rows, f], cat[lo * f..hi * f].to_vec()),
        Tensor::f32(vec![rows, d], dense[lo * d..hi * d].to_vec()),
        Tensor::f32(vec![rows], y[lo..hi].to_vec()),
        rows,
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_batch() {
        let world = 4;
        let mut covered = vec![false; 64];
        for rank in 0..world {
            let (lo, hi) = WorkerShard::new(rank, world).range(64);
            for slot in covered[lo..hi].iter_mut() {
                assert!(!*slot);
                *slot = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn microbatch_planning() {
        let w = WorkerShard::new(0, 1);
        assert_eq!(w.plan_microbatch(512, &[64, 512]).unwrap(), 512);
        assert_eq!(w.plan_microbatch(128, &[64, 512]).unwrap(), 64);
        assert_eq!(w.plan_microbatch(320, &[64, 512]).unwrap(), 64);
        assert!(w.plan_microbatch(96, &[64, 512]).is_err());
        // reference engine: anything goes
        assert_eq!(w.plan_microbatch(96, &[]).unwrap(), 96);
    }

    #[test]
    fn slice_batch_copies_rows() {
        let batch = Batch::new(
            Tensor::i32(vec![4, 2], (0..8).collect()),
            Tensor::f32(vec![4, 1], vec![0.0, 1.0, 2.0, 3.0]),
            Tensor::f32(vec![4], vec![0.0, 1.0, 0.0, 1.0]),
            4,
        );
        let s = slice_batch(&batch, 1, 3).unwrap();
        assert!(matches!(s, BatchSlice::Owned(_)));
        assert_eq!(s.x_cat.as_i32().unwrap(), &[2, 3, 4, 5]);
        assert_eq!(s.x_dense.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(s.y.as_f32().unwrap(), &[1.0, 0.0]);
    }

    #[test]
    fn whole_batch_slice_borrows_instead_of_copying() {
        let batch = Batch::new(
            Tensor::i32(vec![2, 1], vec![3, 1]),
            Tensor::f32(vec![2, 1], vec![0.5, 0.25]),
            Tensor::f32(vec![2], vec![1.0, 0.0]),
            2,
        );
        // warm the touched cache, then check the borrow shares it
        let (ids, _) = batch.touched().unwrap();
        let s = slice_batch(&batch, 0, 2).unwrap();
        assert!(matches!(s, BatchSlice::Whole(_)));
        assert!(std::ptr::eq(&*s, &batch), "whole slice must alias the batch");
        assert_eq!(s.touched().unwrap().0, ids);
    }
}
