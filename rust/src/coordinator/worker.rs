//! A logical data-parallel worker: computes its shard's weighted gradient
//! contribution by accumulating engine-supported microbatches.

use anyhow::{bail, Result};

use super::accumulate::GradAccumulator;
use super::allreduce::Contribution;
use super::engine::Engine;
use crate::data::batcher::Batch;
use crate::model::params::ParamSet;
use crate::tensor::Tensor;

/// One worker's identity + shard geometry.
#[derive(Clone, Copy, Debug)]
pub struct WorkerShard {
    pub rank: usize,
    pub world: usize,
}

impl WorkerShard {
    pub fn new(rank: usize, world: usize) -> WorkerShard {
        assert!(rank < world && world > 0);
        WorkerShard { rank, world }
    }

    /// Row range of this worker within a batch of `b` rows (even split;
    /// `b` must divide by `world`).
    pub fn range(&self, b: usize) -> (usize, usize) {
        assert_eq!(b % self.world, 0, "batch {b} not divisible by world {}", self.world);
        let per = b / self.world;
        (self.rank * per, (self.rank + 1) * per)
    }

    /// Pick the largest supported microbatch that divides `shard_rows`
    /// (reference engine supports everything → use the shard whole).
    pub fn plan_microbatch(&self, shard_rows: usize, supported: &[usize]) -> Result<usize> {
        if supported.is_empty() {
            return Ok(shard_rows);
        }
        supported
            .iter()
            .rev()
            .copied()
            .find(|mb| shard_rows % mb == 0)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no supported microbatch divides shard of {shard_rows} rows (have {supported:?})"
                )
            })
    }

    /// Compute this worker's contribution for its slice of `batch`,
    /// weighted by `shard_rows / batch_rows`.
    pub fn compute(
        &self,
        engine: &Engine,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<Contribution> {
        let b = batch.batch_size();
        let (lo, hi) = self.range(b);
        let rows = hi - lo;
        let mb = self.plan_microbatch(rows, &engine.grad_batch_sizes())?;
        let vocab = engine.schema().total_vocab();
        let shard_weight = rows as f64 / b as f64;
        let mb_weight = shard_weight * (mb as f64 / rows as f64);

        let mut acc = GradAccumulator::new(vocab);
        let mut start = lo;
        while start < hi {
            let micro = slice_batch(batch, start, start + mb)?;
            let out = engine.grad(params, &micro)?;
            acc.add(&out, mb_weight)?;
            start += mb;
        }
        // The leader-side finish() contract requires total weight 1.0;
        // a worker's partial contribution carries shard_weight instead.
        let (grads, counts, loss_weighted, w) = acc.into_parts();
        if (w - shard_weight).abs() > 1e-4 {
            bail!("worker {} accumulated weight {w}, expected {shard_weight}", self.rank);
        }
        let grads = grads.ok_or_else(|| anyhow::anyhow!("empty shard"))?;
        Ok(Contribution { grads, counts, loss_weighted, weight: shard_weight as f32 })
    }
}

/// Copy rows `[lo, hi)` of a batch into a new owned batch.
pub fn slice_batch(batch: &Batch, lo: usize, hi: usize) -> Result<Batch> {
    let b = batch.batch_size();
    if hi > b || lo >= hi {
        bail!("slice [{lo},{hi}) out of range for batch {b}");
    }
    let f = batch.x_cat.shape()[1];
    let d = batch.x_dense.shape()[1];
    let rows = hi - lo;
    let cat = batch.x_cat.as_i32()?;
    let dense = batch.x_dense.as_f32()?;
    let y = batch.y.as_f32()?;
    Ok(Batch {
        x_cat: Tensor::i32(vec![rows, f], cat[lo * f..hi * f].to_vec()),
        x_dense: Tensor::f32(vec![rows, d], dense[lo * d..hi * d].to_vec()),
        y: Tensor::f32(vec![rows], y[lo..hi].to_vec()),
        valid: rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_batch() {
        let world = 4;
        let mut covered = vec![false; 64];
        for rank in 0..world {
            let (lo, hi) = WorkerShard::new(rank, world).range(64);
            for slot in covered[lo..hi].iter_mut() {
                assert!(!*slot);
                *slot = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn microbatch_planning() {
        let w = WorkerShard::new(0, 1);
        assert_eq!(w.plan_microbatch(512, &[64, 512]).unwrap(), 512);
        assert_eq!(w.plan_microbatch(128, &[64, 512]).unwrap(), 64);
        assert_eq!(w.plan_microbatch(320, &[64, 512]).unwrap(), 64);
        assert!(w.plan_microbatch(96, &[64, 512]).is_err());
        // reference engine: anything goes
        assert_eq!(w.plan_microbatch(96, &[]).unwrap(), 96);
    }

    #[test]
    fn slice_batch_copies_rows() {
        let batch = Batch {
            x_cat: Tensor::i32(vec![4, 2], (0..8).collect()),
            x_dense: Tensor::f32(vec![4, 1], vec![0.0, 1.0, 2.0, 3.0]),
            y: Tensor::f32(vec![4], vec![0.0, 1.0, 0.0, 1.0]),
            valid: 4,
        };
        let s = slice_batch(&batch, 1, 3).unwrap();
        assert_eq!(s.x_cat.as_i32().unwrap(), &[2, 3, 4, 5]);
        assert_eq!(s.x_dense.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(s.y.as_f32().unwrap(), &[1.0, 0.0]);
    }
}
