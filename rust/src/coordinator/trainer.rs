//! The end-to-end training loop: scaling rule → warmup → shard → grad →
//! all-reduce → sharded apply → eval, with timing broken down per phase.
//!
//! # Threading model
//!
//! Parameters and optimizer state live in the shard-owned
//! [`ParamStore`]: weights behind a `RwLock` (read by the gradient
//! fan-out, written by apply), Adam moments / lazy-Adam rows / per-field
//! norms behind a `Mutex` taken only during apply. Each step has three
//! phases:
//!
//! 1. **Fan-out** — `WorkerShard::compute` jobs run on a persistent
//!    [`StepPool`] created once in [`Trainer::train`]'s thread scope
//!    (spawn cost is paid per *run*, not per step — the old per-step
//!    `thread::scope` is gone from the hot loop). Workers take read
//!    locks on the weights, jobs carry the batch as an `Arc`, and every
//!    worker thread owns a persistent [`Scratch`] arena so the
//!    forward/backward compute path performs zero steady-state heap
//!    allocation.
//! 2. **Tree reduce-as-ready** — finished contributions stream over a
//!    per-step channel into a [`TreeReducer`] on the leader thread,
//!    merging eagerly along a **fixed binary tree over contiguous rank
//!    ranges**: reduction overlaps the slowest shard's compute, the
//!    post-arrival critical path is O(log W) merges (not a serial O(W)
//!    fold), and because the pairing depends only on the worker count,
//!    results stay bitwise identical at any thread count.
//! 3. **Sharded apply, overlapped with the merge tail** — on the
//!    reference engine (clip mode ≠ Global) the reducer withholds the
//!    *root* merge and hands back its two subtree halves
//!    ([`Reduced::Halves`]); the store splits that final merge per
//!    field-aligned [`ShardPlan`] row range and performs each slice
//!    *inside* the shard's own apply task, so CowClip's `clip → L2 →
//!    Adam` starts on a shard's range as soon as its slice of the merge
//!    completes ([`TrainConfig::param_shards`] owners, disjoint `&mut`
//!    slices of weights + moments). Neither the shard count nor the
//!    deferred merge changes the math (`rust/tests/shard_parity.rs`).
//!
//! A scoped prefetch thread ([`Prefetch`]) materializes batch `N+1` —
//! including the `Batch::touched` sort — while step `N` trains, so the
//! `data` entry of `phase_seconds` shows only the un-overlapped residual.
//! `phase_seconds` additionally reports the `grad` (fan-out + reduce)
//! and `apply` sub-phases of `step`.
//!
//! [`ParamStore`]: crate::model::store::ParamStore
//! [`ShardPlan`]: crate::model::store::ShardPlan

use std::path::Path;
use std::sync::{Arc, RwLockReadGuard};
use std::time::Instant;

use anyhow::{ensure, Result};

use super::allreduce::{Contribution, Reduced, ReduceStats, TreeReducer};
use super::engine::Engine;
use super::pool::{GradJob, StepPool};
use super::worker::WorkerShard;
use crate::clip::ClipMode;
use crate::reference::Scratch;
use crate::data::batcher::{Batch, Batcher, EvalBatcher};
use crate::data::dataset::Dataset;
use crate::data::prefetch::Prefetch;
use crate::metrics::{EvalAccumulator, LossMeter};
use crate::model::init::{init_params, InitConfig};
use crate::model::params::ParamSet;
use crate::model::store::ParamStore;
use crate::runtime::HypersVec;
use crate::scaling::rules::{HyperSet, ScalingRule};
use crate::scaling::warmup::Warmup;
use crate::util::Stopwatch;

/// Training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Effective (large) batch size.
    pub batch: usize,
    /// Base batch the hyperparameters are calibrated for.
    pub base_batch: usize,
    /// Base hypers at `base_batch`.
    pub base_hypers: HyperSet,
    /// Scaling rule mapping base hypers to `batch`.
    pub rule: ScalingRule,
    pub epochs: f64,
    /// Logical data-parallel workers.
    pub workers: usize,
    /// Compute threads for the worker fan-out, the sharded apply stage,
    /// parallel eval, and the batch prefetcher: `1` = fully sequential
    /// (the seed behavior), `0` = auto (one thread per available core,
    /// capped by the work). The thread count never changes the math —
    /// contributions merge in rank order regardless of arrival order.
    pub threads: usize,
    /// Apply-stage parameter shards: the embedding/wide tables are
    /// partitioned row-wise (field-aligned) and dense tensors grouped so
    /// `clip → L2 → Adam` runs per shard in parallel. `0` = auto (one
    /// per core, capped by the categorical field count); `1` = the
    /// serial leader path. Forced to 1 on the HLO engine (its apply
    /// program rewrites whole tensors). The shard count never changes
    /// the math (`rust/tests/shard_parity.rs`).
    pub param_shards: usize,
    /// Warmup steps on the dense LR (0 = none).
    pub warmup_steps: usize,
    /// Embedding init sigma.
    pub init_sigma: f32,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` epochs (0 = only at
    /// the end).
    pub eval_every_epochs: usize,
    /// Print progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// Batch-size scale factor `s` relative to the calibration batch.
    pub fn scale(&self) -> f64 {
        self.batch as f64 / self.base_batch as f64
    }

    /// The resolved hypers after applying the scaling rule.
    pub fn scaled_hypers(&self) -> HyperSet {
        self.rule.apply(&self.base_hypers, self.scale())
    }

    /// Order-stable 64-bit FNV-1a over every field that shapes replica
    /// state. The distributed rejoin handshake compares fingerprints so
    /// a reconnecting worker whose config drifted from the run (edited
    /// flags, different binary defaults) is refused instead of silently
    /// corrupting the reduction.
    ///
    /// Execution-shape fields (`threads`, `param_shards`,
    /// `eval_every_epochs`, `verbose`) are excluded: the repo's parity
    /// suites guarantee they never change the math, and a respawned
    /// worker may legitimately differ in them.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.batch as u64);
        eat(self.base_batch as u64);
        eat(self.base_hypers.lr_dense.to_bits() as u64);
        eat(self.base_hypers.lr_embed.to_bits() as u64);
        eat(self.base_hypers.l2_embed.to_bits() as u64);
        eat(self.base_hypers.clip_r.to_bits() as u64);
        eat(self.base_hypers.clip_zeta.to_bits() as u64);
        eat(self.base_hypers.clip_t.to_bits() as u64);
        eat(match self.rule {
            ScalingRule::NoScale => 0,
            ScalingRule::Sqrt => 1,
            ScalingRule::SqrtStar => 2,
            ScalingRule::Linear => 3,
            ScalingRule::N2Lambda => 4,
            ScalingRule::CowClip => 5,
        });
        eat(self.epochs.to_bits());
        eat(self.workers as u64);
        eat(self.warmup_steps as u64);
        eat(self.init_sigma.to_bits() as u64);
        eat(self.seed);
        h
    }

    /// Resolve the thread count for a stage with `max_units` independent
    /// units of work (worker shards for the fan-out, parameter shards
    /// for apply, batches for eval).
    pub fn threads_for(&self, max_units: usize) -> usize {
        let cap = match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            t => t,
        };
        cap.min(max_units).max(1)
    }
}

/// Per-epoch evaluation snapshot.
#[derive(Clone, Copy, Debug)]
pub struct EpochEval {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_auc: f64,
    pub test_logloss: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss_curve: Vec<f32>,
    pub epoch_evals: Vec<EpochEval>,
    pub reduce_stats: ReduceStats,
    /// (phase, seconds) totals: data / step / eval, plus the `grad`
    /// (fan-out + reduce) and `apply` sub-phases of `step`.
    pub phase_seconds: Vec<(String, f64)>,
    pub wall_seconds: f64,
    pub diverged: bool,
}

impl TrainReport {
    pub fn seconds(&self, phase: &str) -> f64 {
        self.phase_seconds
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// The leader: owns the engine and the shard-owned parameter store, and
/// drives workers.
pub struct Trainer {
    pub engine: Engine,
    pub cfg: TrainConfig,
    /// Shard-owned parameters + optimizer state (see [`ParamStore`]).
    pub store: ParamStore,
    step: usize,
    /// Loop-invariant resolved hypers (scaling rule already applied).
    hypers: HyperSet,
    /// Loop-invariant warmup schedule.
    warmup: Warmup,
    /// Per-thread scratch arenas for the inline fan-out paths (the
    /// persistent pool's workers own their own); reused across steps so
    /// the compute path stops allocating after warmup.
    scratches: Vec<Scratch>,
}

/// Resolve the apply-stage shard count: HLO applies whole tensors (so 1),
/// otherwise `param_shards` (0 = one per core) capped by the field count.
fn resolve_shards(engine: &Engine, cfg: &TrainConfig) -> usize {
    if matches!(engine, Engine::Hlo(_)) {
        return 1;
    }
    let n_fields = engine.schema().n_cat().max(1);
    let requested = match cfg.param_shards {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        s => s,
    };
    requested.min(n_fields).max(1)
}

/// Seed-deterministic store construction shared by the in-process
/// trainer and every distributed replica (`coordinator::dist`): same
/// engine + config → bitwise identical initial parameters, which is what
/// lets distributed ranks rebuild state instead of shipping it.
pub(crate) fn init_store(engine: &Engine, cfg: &TrainConfig) -> Result<ParamStore> {
    let spec = engine.spec();
    let params = init_params(&spec, &InitConfig { seed: cfg.seed, embed_sigma: cfg.init_sigma });
    let n_shards = resolve_shards(engine, cfg);
    ParamStore::new(engine.schema().clone(), params, n_shards)
}

impl Trainer {
    pub fn new(engine: Engine, cfg: TrainConfig) -> Result<Trainer> {
        ensure!(cfg.batch % cfg.workers == 0, "batch must divide by workers");
        ensure!(cfg.workers >= 1);
        let store = init_store(&engine, &cfg)?;
        let hypers = cfg.scaled_hypers();
        let warmup = Warmup::new(cfg.warmup_steps);
        let scratches = (0..cfg.threads_for(cfg.workers)).map(|_| Scratch::new()).collect();
        Ok(Trainer { engine, cfg, store, step: 0, hypers, warmup, scratches })
    }

    /// Total scratch-arena allocation events across the trainer's inline
    /// fan-out threads — flat across steps once warm (the
    /// zero-steady-state-allocation gate in `train_integration.rs`).
    pub fn scratch_grow_events(&self) -> usize {
        self.scratches.iter().map(|s| s.grow_events()).sum()
    }

    fn ensure_scratches(&mut self) {
        let need = self.cfg.threads_for(self.cfg.workers);
        while self.scratches.len() < need {
            self.scratches.push(Scratch::new());
        }
    }

    pub fn step(&self) -> usize {
        self.step
    }

    /// Shared read access to the current parameters.
    pub fn params(&self) -> RwLockReadGuard<'_, ParamSet> {
        self.store.read()
    }

    /// Save the full training state (params + Adam moments + lazy-Adam
    /// rows + step counter) as a `CCKS` checkpoint.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.store.save_checkpoint(path, self.step as u64)
    }

    /// Resume from a checkpoint written by [`Trainer::save_checkpoint`]
    /// (or a bare PR-1 `CCKP` params file): restores weights, moments and
    /// the step counter, so warmup and Adam bias correction continue
    /// exactly where the saved run stopped.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let step = self.store.load_checkpoint(path)?;
        self.step = step as usize;
        Ok(())
    }

    /// One optimizer step on a prepared batch. Returns the batch loss.
    ///
    /// This standalone entry point (benches and figure experiments call
    /// it directly) fans out inline — sequentially, or on a per-step
    /// scope when `threads > 1`. `Trainer::train` instead routes steps
    /// through its persistent [`StepPool`]; both paths produce bitwise
    /// identical results.
    pub fn train_step(&mut self, batch: &Batch) -> Result<(f32, ReduceStats)> {
        self.step += 1;
        self.ensure_scratches();
        let hv = hypers_for_step(self.hypers, self.warmup, self.step);
        let defer = wants_deferred_merge(&self.engine);
        let (total, stats) = fan_out_inline(
            &self.engine,
            &self.store,
            &self.cfg,
            batch,
            defer,
            &mut self.scratches,
        )?;
        let loss = apply_contribution(&self.engine, &self.store, &self.cfg, &hv, total)?;
        Ok((loss, stats))
    }

    /// Evaluate AUC/logloss on a dataset, fanning eval batches out over
    /// `threads_for(n_batches)` threads. Logits are pushed into the
    /// accumulator in batch order, so the result is independent of the
    /// thread count.
    pub fn evaluate(&self, ds: &Dataset) -> Result<(f64, f64)> {
        evaluate_with(&self.engine, &self.store, &self.cfg, ds)
    }

    /// Full training run.
    ///
    /// Opens one thread scope for the whole run holding the prefetch
    /// thread (batch `N+1` materializes while step `N` trains) and the
    /// persistent [`StepPool`] (when `threads != 1` and `workers > 1`).
    /// `threads == 1` keeps the fully inline sequential seed path. Batch
    /// order and all results are identical either way.
    pub fn train(&mut self, train: &Dataset, test: &Dataset) -> Result<TrainReport> {
        let t0 = Instant::now();
        let steps_per_epoch = train.n() / self.cfg.batch;
        ensure!(steps_per_epoch > 0, "batch larger than dataset");
        let total_steps = ((steps_per_epoch as f64) * self.cfg.epochs).round() as usize;
        ensure!(total_steps > 0, "no steps to run");

        let mut batcher = Batcher::new(train, self.cfg.batch, self.cfg.seed ^ 0x5eed);
        // only a single worker consumes the whole batch (and hence its
        // touched cache); shards compute their own slices' touched sets
        let warm_touched = self.cfg.workers == 1;

        self.ensure_scratches();
        // split borrows: the scope threads share the engine and the
        // store's locks while the loop advances the step counter
        let engine = &self.engine;
        let store = &self.store;
        let cfg = &self.cfg;
        let hypers = self.hypers;
        let warmup = self.warmup;
        let step = &mut self.step;
        let scratches = &mut self.scratches;

        if cfg.threads_for(2) > 1 {
            std::thread::scope(|scope| {
                let feed = Prefetch::spawn(
                    scope,
                    (0..total_steps).map(move |_| {
                        let b = batcher.next_batch();
                        if warm_touched {
                            let _ = b.touched(); // pay for the sort off the hot path
                        }
                        b
                    }),
                    2,
                );
                let pool_threads = cfg.threads_for(cfg.workers);
                let pool = (pool_threads > 1)
                    .then(|| StepPool::spawn(scope, pool_threads, engine, store.weights_lock()));
                run_loop(
                    engine,
                    store,
                    cfg,
                    hypers,
                    warmup,
                    step,
                    scratches,
                    pool.as_ref(),
                    t0,
                    total_steps,
                    steps_per_epoch,
                    test,
                    || {
                        feed.recv()
                            .ok_or_else(|| anyhow::anyhow!("prefetch producer exited early"))
                    },
                )
            })
        } else {
            run_loop(
                engine,
                store,
                cfg,
                hypers,
                warmup,
                step,
                scratches,
                None,
                t0,
                total_steps,
                steps_per_epoch,
                test,
                || Ok(batcher.next_batch()),
            )
        }
    }
}

/// Whether the reducer should withhold the root merge so the sharded
/// apply can run it split per row range: the reference engine's sparse
/// path, except `Global` clipping (whose threshold needs the
/// *whole-table* merged gradient norm before any shard may start).
fn wants_deferred_merge(engine: &Engine) -> bool {
    match engine {
        Engine::Reference(e) => {
            e.clip_mode != ClipMode::Global && !e.emits_dense_grads()
        }
        Engine::Hlo(_) => false,
    }
}

/// Finish a reducer according to the defer mode, normalizing to
/// [`Reduced`].
fn finish_reducer(reducer: TreeReducer, defer: bool) -> Result<(Reduced, ReduceStats)> {
    if defer {
        reducer.finish_halves()
    } else {
        let (total, stats) = reducer.finish()?;
        Ok((Reduced::Whole(total), stats))
    }
}

/// The per-step hypers vector: warmup factor on the dense LR at 1-based
/// `step`. Shared by `Trainer::train_step` and the pooled `run_loop` so
/// the two step paths cannot drift.
pub(crate) fn hypers_for_step(hypers: HyperSet, warmup: Warmup, step: usize) -> HypersVec {
    HypersVec::new(hypers).at_step(step).with_warmup(warmup.factor(step - 1))
}

/// Gradient fan-out through the persistent pool: one job per worker
/// rank, replies merged along the fixed tree as they land.
fn fan_out_pool(
    pool: &StepPool,
    workers: usize,
    batch: &Arc<Batch>,
    defer: bool,
) -> Result<(Reduced, ReduceStats)> {
    let (tx, rx) = std::sync::mpsc::channel();
    for rank in 0..workers {
        pool.submit(GradJob {
            rank,
            world: workers,
            batch: Arc::clone(batch),
            reply: tx.clone(),
        });
    }
    drop(tx); // the reducer's recv loop ends when the last reply lands
    let mut reducer = if defer { TreeReducer::deferred(workers) } else { TreeReducer::new(workers) };
    for (rank, c) in rx {
        reducer.push(rank, c?)?;
    }
    finish_reducer(reducer, defer)
}

/// Inline gradient fan-out (no pool): sequential when `threads <= 1`,
/// otherwise a per-step scope (the standalone `train_step` path). Each
/// thread borrows one of the trainer's persistent scratch arenas.
fn fan_out_inline(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    batch: &Batch,
    defer: bool,
    scratches: &mut [Scratch],
) -> Result<(Reduced, ReduceStats)> {
    let workers = cfg.workers;
    let threads = cfg.threads_for(workers);
    debug_assert!(scratches.len() >= threads, "trainer must pre-size its scratch arenas");
    let guard = store.read();
    let params: &ParamSet = &guard;
    if threads <= 1 {
        let scratch = &mut scratches[0];
        let mut reducer =
            if defer { TreeReducer::deferred(workers) } else { TreeReducer::new(workers) };
        for rank in 0..workers {
            let c = WorkerShard::new(rank, workers).compute(engine, params, batch, scratch)?;
            reducer.push(rank, c)?;
        }
        finish_reducer(reducer, defer)
    } else {
        std::thread::scope(|s| -> Result<(Reduced, ReduceStats)> {
            let (tx, rx) = std::sync::mpsc::channel();
            for (t, scratch) in scratches.iter_mut().take(threads).enumerate() {
                let tx = tx.clone();
                s.spawn(move || {
                    let mut rank = t;
                    while rank < workers {
                        let c = WorkerShard::new(rank, workers)
                            .compute(engine, params, batch, scratch);
                        let failed = c.is_err();
                        if tx.send((rank, c)).is_err() || failed {
                            return;
                        }
                        rank += threads;
                    }
                });
            }
            drop(tx);
            let mut reducer =
                if defer { TreeReducer::deferred(workers) } else { TreeReducer::new(workers) };
            for (rank, c) in rx {
                reducer.push(rank, c?)?;
            }
            finish_reducer(reducer, defer)
        })
    }
}

/// Apply a reduction through the store's sharded path. A whole total
/// goes through the eager apply; deferred halves route to
/// [`Engine::apply_store_halves`], whose per-shard tasks run their slice
/// of the root merge inline.
pub(crate) fn apply_contribution(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    hv: &HypersVec,
    total: Reduced,
) -> Result<f32> {
    let threads = cfg.threads_for(store.n_shards());
    let loss = total.loss_weighted();
    match total {
        Reduced::Whole(Contribution { mut grads, counts, .. }) => {
            engine.apply_store(store, &mut grads, &counts, hv, threads)?;
        }
        Reduced::Halves { mut left, right } => {
            engine.apply_store_halves(store, &mut left, right, hv, threads)?;
        }
    }
    Ok(loss)
}

/// Parallel evaluation over a read snapshot of the store's weights.
pub(crate) fn evaluate_with(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    ds: &Dataset,
) -> Result<(f64, f64)> {
    let _eval = crate::obs::span(crate::obs::Phase::Eval);
    // HLO fwd artifacts are shape-specialized: always use their exact
    // batch (EvalBatcher pads small datasets up to it); the reference
    // engine takes whatever fits.
    let eval_batch = engine.eval_batch().unwrap_or_else(|| 1024.min(ds.n().max(1)));
    let n_batches = ds.n().div_ceil(eval_batch);
    let threads = cfg.threads_for(n_batches);
    let guard = store.read();
    let params: &ParamSet = &guard;
    let mut acc = EvalAccumulator::new();
    if threads <= 1 {
        // one scratch reused across every eval batch: logits are pushed
        // then recycled, so eval stops allocating after the first batch
        let mut scratch = Scratch::new();
        for batch in EvalBatcher::new(ds, eval_batch) {
            let logits = engine.fwd_scratch(params, &batch, &mut scratch)?;
            acc.push(&logits, batch.y.as_f32()?, batch.valid);
            scratch.recycle(logits);
        }
    } else {
        type EvalOut = (usize, Vec<f32>, Vec<f32>, usize);
        let mut results = std::thread::scope(|s| -> Result<Vec<EvalOut>> {
            let mut handles = Vec::with_capacity(threads);
            for t in 0..threads {
                handles.push(s.spawn(move || -> Result<Vec<EvalOut>> {
                    let mut scratch = Scratch::new();
                    let mut out = Vec::new();
                    let mut i = t;
                    while i < n_batches {
                        let batch = EvalBatcher::nth_batch(ds, eval_batch, i)
                            .ok_or_else(|| anyhow::anyhow!("eval batch {i} out of range"))?;
                        // logits escape into the ordered result set, so
                        // they are not recycled (forward intermediates are)
                        let logits = engine.fwd_scratch(params, &batch, &mut scratch)?;
                        let y = batch.y.as_f32()?.to_vec();
                        out.push((i, logits, y, batch.valid));
                        i += threads;
                    }
                    Ok(out)
                }));
            }
            let mut all = Vec::with_capacity(n_batches);
            for h in handles {
                all.extend(h.join().expect("eval worker panicked")?);
            }
            Ok(all)
        })?;
        results.sort_unstable_by_key(|(i, ..)| *i);
        for (_, logits, y, valid) in &results {
            acc.push(logits, y, *valid);
        }
    }
    Ok((acc.auc(), acc.logloss()))
}

/// The step loop shared by the pooled and inline paths.
#[allow(clippy::too_many_arguments)]
fn run_loop(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    hypers: HyperSet,
    warmup: Warmup,
    step: &mut usize,
    scratches: &mut [Scratch],
    pool: Option<&StepPool>,
    t0: Instant,
    total_steps: usize,
    steps_per_epoch: usize,
    test: &Dataset,
    mut next_batch: impl FnMut() -> Result<Batch>,
) -> Result<TrainReport> {
    let defer = wants_deferred_merge(engine);
    let mut sw = Stopwatch::new();
    let mut loss_curve = Vec::with_capacity(total_steps);
    let mut epoch_evals = Vec::new();
    let mut reduce_total = ReduceStats::default();
    let mut epoch_loss = LossMeter::new();
    let mut diverged = false;

    // Registry handles, registered once per run: the step loop below
    // publishes grad/apply time and reduce traffic straight into the
    // metrics registry, and the end-of-run `grad`/`apply` phase totals
    // are read back as counter deltas — one source of truth instead of
    // loose local accumulators.
    let m_steps = crate::obs::counter("train.steps");
    let m_grad_ns = crate::obs::counter("train.grad_ns");
    let m_apply_ns = crate::obs::counter("train.apply_ns");
    let m_loss = crate::obs::gauge("train.loss");
    let m_rounds = crate::obs::counter("reduce.rounds");
    let m_raw = crate::obs::counter("reduce.bytes_moved");
    let m_wire = crate::obs::counter("reduce.wire_bytes");
    let grad_ns0 = m_grad_ns.get();
    let apply_ns0 = m_apply_ns.get();

    for s in 1..=total_steps {
        sw.start("data");
        let batch = Arc::new(next_batch()?);
        sw.start("step");
        *step += 1;
        let hv = hypers_for_step(hypers, warmup, *step);
        let t_grad = Instant::now();
        let (total, rstats) = match pool {
            Some(pool) => fan_out_pool(pool, cfg.workers, &batch, defer)?,
            None => fan_out_inline(engine, store, cfg, &batch, defer, scratches)?,
        };
        m_grad_ns.add(t_grad.elapsed().as_nanos() as u64);
        let t_apply = Instant::now();
        let loss = apply_contribution(engine, store, cfg, &hv, total)?;
        m_apply_ns.add(t_apply.elapsed().as_nanos() as u64);
        sw.stop();
        reduce_total.accumulate(&rstats);
        m_steps.inc();
        m_loss.set(loss as f64);
        m_rounds.add(rstats.rounds as u64);
        m_raw.add(rstats.bytes_moved);
        m_wire.add(rstats.wire_bytes);
        loss_curve.push(loss);
        epoch_loss.update(loss as f64);
        if !loss.is_finite() {
            diverged = true;
            break;
        }

        let at_epoch_end = s % steps_per_epoch == 0;
        if at_epoch_end {
            let epoch = s / steps_per_epoch;
            let do_eval =
                cfg.eval_every_epochs > 0 && epoch % cfg.eval_every_epochs == 0;
            if do_eval {
                sw.start("eval");
                let (auc, ll) = evaluate_with(engine, store, cfg, test)?;
                sw.stop();
                epoch_evals.push(EpochEval {
                    epoch,
                    train_loss: epoch_loss.mean(),
                    test_auc: auc,
                    test_logloss: ll,
                });
                if cfg.verbose {
                    println!(
                        "  epoch {epoch:>2}  train_loss {:.4}  test_auc {:.4}  test_logloss {:.4}",
                        epoch_loss.mean(),
                        auc,
                        ll
                    );
                }
            }
            epoch_loss.reset();
        }
    }
    sw.stop();

    let (final_auc, final_logloss) = if diverged {
        (f64::NAN, f64::NAN)
    } else {
        evaluate_with(engine, store, cfg, test)?
    };

    let mut phase_seconds: Vec<(String, f64)> = sw
        .summary()
        .into_iter()
        .map(|(n, d)| (n, d.as_secs_f64()))
        .collect();
    phase_seconds.push((
        "grad".to_string(),
        (m_grad_ns.get() - grad_ns0) as f64 / 1e9,
    ));
    phase_seconds.push((
        "apply".to_string(),
        (m_apply_ns.get() - apply_ns0) as f64 / 1e9,
    ));

    Ok(TrainReport {
        steps: loss_curve.len(),
        final_auc,
        final_logloss,
        train_loss_curve: loss_curve,
        epoch_evals,
        reduce_stats: reduce_total,
        phase_seconds,
        wall_seconds: t0.elapsed().as_secs_f64(),
        diverged,
    })
}

/// Convenience: slice the first `n` rows of a dataset (cheap experiment
/// subsetting).
pub fn head(ds: &Dataset, n: usize) -> Dataset {
    let idx: Vec<usize> = (0..n.min(ds.n())).collect();
    ds.select(&idx)
}
