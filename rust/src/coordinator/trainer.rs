//! The end-to-end training loop: scaling rule → warmup → shard → grad →
//! all-reduce → apply → eval, with timing broken down per phase.

use anyhow::{ensure, Result};

use super::allreduce::{tree_allreduce, ReduceStats};
use super::engine::Engine;
use super::worker::WorkerShard;
use crate::data::batcher::{Batcher, EvalBatcher};
use crate::data::dataset::Dataset;
use crate::metrics::{EvalAccumulator, LossMeter};
use crate::model::init::{init_params, InitConfig};
use crate::model::params::ParamSet;
use crate::runtime::HypersVec;
use crate::scaling::rules::{HyperSet, ScalingRule};
use crate::scaling::warmup::Warmup;
use crate::util::Stopwatch;

/// Training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Effective (large) batch size.
    pub batch: usize,
    /// Base batch the hyperparameters are calibrated for.
    pub base_batch: usize,
    /// Base hypers at `base_batch`.
    pub base_hypers: HyperSet,
    /// Scaling rule mapping base hypers to `batch`.
    pub rule: ScalingRule,
    pub epochs: f64,
    /// Logical data-parallel workers.
    pub workers: usize,
    /// Warmup steps on the dense LR (0 = none).
    pub warmup_steps: usize,
    /// Embedding init sigma.
    pub init_sigma: f32,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` epochs (0 = only at
    /// the end).
    pub eval_every_epochs: usize,
    /// Print progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// Batch-size scale factor `s` relative to the calibration batch.
    pub fn scale(&self) -> f64 {
        self.batch as f64 / self.base_batch as f64
    }

    /// The resolved hypers after applying the scaling rule.
    pub fn scaled_hypers(&self) -> HyperSet {
        self.rule.apply(&self.base_hypers, self.scale())
    }
}

/// Per-epoch evaluation snapshot.
#[derive(Clone, Copy, Debug)]
pub struct EpochEval {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_auc: f64,
    pub test_logloss: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss_curve: Vec<f32>,
    pub epoch_evals: Vec<EpochEval>,
    pub reduce_stats: ReduceStats,
    /// (phase, seconds) totals: grad / reduce / apply / data / eval.
    pub phase_seconds: Vec<(String, f64)>,
    pub wall_seconds: f64,
    pub diverged: bool,
}

impl TrainReport {
    pub fn seconds(&self, phase: &str) -> f64 {
        self.phase_seconds
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// The leader: owns parameters and drives workers.
pub struct Trainer {
    pub engine: Engine,
    pub cfg: TrainConfig,
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    step: usize,
}

impl Trainer {
    pub fn new(engine: Engine, cfg: TrainConfig) -> Result<Trainer> {
        ensure!(cfg.batch % cfg.workers == 0, "batch must divide by workers");
        ensure!(cfg.workers >= 1);
        let spec = engine.spec();
        let params = init_params(&spec, &InitConfig { seed: cfg.seed, embed_sigma: cfg.init_sigma });
        let m = params.zeros_like();
        let v = params.zeros_like();
        Ok(Trainer { engine, cfg, params, m, v, step: 0 })
    }

    pub fn step(&self) -> usize {
        self.step
    }

    /// One optimizer step on a prepared batch. Returns the batch loss.
    pub fn train_step(&mut self, batch: &crate::data::batcher::Batch) -> Result<(f32, ReduceStats)> {
        self.step += 1;
        let hypers = self.cfg.scaled_hypers();
        let warmup = Warmup::new(self.cfg.warmup_steps);
        let hv = HypersVec::new(hypers)
            .at_step(self.step)
            .with_warmup(warmup.factor(self.step - 1));

        // workers compute shard contributions
        let mut contributions = Vec::with_capacity(self.cfg.workers);
        for rank in 0..self.cfg.workers {
            let shard = WorkerShard::new(rank, self.cfg.workers);
            contributions.push(shard.compute(&self.engine, &self.params, batch)?);
        }
        let (total, stats) = tree_allreduce(contributions)?;
        let mut grads = total.grads;
        self.engine.apply(
            &mut self.params,
            &mut self.m,
            &mut self.v,
            &mut grads,
            &total.counts,
            &hv,
        )?;
        Ok((total.loss_weighted, stats))
    }

    /// Evaluate AUC/logloss on a dataset.
    pub fn evaluate(&self, ds: &Dataset) -> Result<(f64, f64)> {
        // HLO fwd artifacts are shape-specialized: always use their exact
        // batch (EvalBatcher pads small datasets up to it); the reference
        // engine takes whatever fits.
        let eval_batch = self
            .engine
            .eval_batch()
            .unwrap_or_else(|| 1024.min(ds.n().max(1)));
        let mut acc = EvalAccumulator::new();
        for batch in EvalBatcher::new(ds, eval_batch) {
            let logits = self.engine.fwd(&self.params, &batch)?;
            acc.push(&logits, batch.y.as_f32()?, batch.valid);
        }
        Ok((acc.auc(), acc.logloss()))
    }

    /// Full training run.
    pub fn train(&mut self, train: &Dataset, test: &Dataset) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut sw = Stopwatch::new();
        let steps_per_epoch = train.n() / self.cfg.batch;
        ensure!(steps_per_epoch > 0, "batch larger than dataset");
        let total_steps = ((steps_per_epoch as f64) * self.cfg.epochs).round() as usize;
        ensure!(total_steps > 0, "no steps to run");

        let mut batcher = Batcher::new(train, self.cfg.batch, self.cfg.seed ^ 0x5eed);
        let mut loss_curve = Vec::with_capacity(total_steps);
        let mut epoch_evals = Vec::new();
        let mut reduce_total = ReduceStats::default();
        let mut epoch_loss = LossMeter::new();
        let mut diverged = false;

        for s in 1..=total_steps {
            sw.start("data");
            let batch = batcher.next_batch();
            sw.start("step");
            let (loss, rstats) = self.train_step(&batch)?;
            sw.stop();
            reduce_total.rounds += rstats.rounds;
            reduce_total.bytes_moved += rstats.bytes_moved;
            reduce_total.workers = rstats.workers;
            loss_curve.push(loss);
            epoch_loss.update(loss as f64);
            if !loss.is_finite() {
                diverged = true;
                break;
            }

            let at_epoch_end = s % steps_per_epoch == 0;
            if at_epoch_end {
                let epoch = s / steps_per_epoch;
                let do_eval = self.cfg.eval_every_epochs > 0
                    && epoch % self.cfg.eval_every_epochs == 0;
                if do_eval {
                    sw.start("eval");
                    let (auc, ll) = self.evaluate(test)?;
                    sw.stop();
                    epoch_evals.push(EpochEval {
                        epoch,
                        train_loss: epoch_loss.mean(),
                        test_auc: auc,
                        test_logloss: ll,
                    });
                    if self.cfg.verbose {
                        println!(
                            "  epoch {epoch:>2}  train_loss {:.4}  test_auc {:.4}  test_logloss {:.4}",
                            epoch_loss.mean(),
                            auc,
                            ll
                        );
                    }
                }
                epoch_loss.reset();
            }
        }
        sw.stop();

        let (final_auc, final_logloss) = if diverged {
            (f64::NAN, f64::NAN)
        } else {
            let (a, l) = self.evaluate(test)?;
            (a, l)
        };

        Ok(TrainReport {
            steps: loss_curve.len(),
            final_auc,
            final_logloss,
            train_loss_curve: loss_curve,
            epoch_evals,
            reduce_stats: reduce_total,
            phase_seconds: sw
                .summary()
                .into_iter()
                .map(|(n, d)| (n, d.as_secs_f64()))
                .collect(),
            wall_seconds: t0.elapsed().as_secs_f64(),
            diverged,
        })
    }
}

/// Convenience: slice the first `n` rows of a dataset (cheap experiment
/// subsetting).
pub fn head(ds: &Dataset, n: usize) -> Dataset {
    let idx: Vec<usize> = (0..n.min(ds.n())).collect();
    ds.select(&idx)
}
