//! The end-to-end training loop: scaling rule → warmup → shard → grad →
//! all-reduce → apply → eval, with timing broken down per phase.
//!
//! # Threading model
//!
//! The leader owns `ParamSet` (params + Adam moments) exclusively. Each
//! step has three phases with different concurrency:
//!
//! 1. **Fan-out** — `WorkerShard::compute` runs on up to
//!    [`TrainConfig::threads`] scoped threads, every worker sharing one
//!    `&Engine` / `&ParamSet` / `&Batch` (all `Sync`; `Engine::grad` is
//!    `&self`).
//! 2. **Reduce-as-ready** — finished contributions stream over a channel
//!    into a [`StreamingReducer`] on the leader thread, which merges them
//!    eagerly *in rank order*: the slowest shard's gradient overlaps the
//!    reduction of everything before it, and the fixed merge order keeps
//!    results bitwise identical to a sequential run at any thread count.
//! 3. **Apply** — stays single-threaded on the leader: the optimizer
//!    mutates params and per-row lazy-Adam state in place, and a serial
//!    apply is both cheap (O(touched·d)) and trivially deterministic.
//!
//! A scoped prefetch thread ([`Prefetch`]) materializes batch `N+1` —
//! including the `Batch::touched` sort — while step `N` trains, so the
//! `data` entry of `phase_seconds` shows only the un-overlapped residual.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::allreduce::{Contribution, ReduceStats, StreamingReducer};
use super::engine::Engine;
use super::worker::WorkerShard;
use crate::data::batcher::{Batch, Batcher, EvalBatcher};
use crate::data::dataset::Dataset;
use crate::data::prefetch::Prefetch;
use crate::metrics::{EvalAccumulator, LossMeter};
use crate::model::init::{init_params, InitConfig};
use crate::model::params::ParamSet;
use crate::runtime::HypersVec;
use crate::scaling::rules::{HyperSet, ScalingRule};
use crate::scaling::warmup::Warmup;
use crate::util::Stopwatch;

/// Training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Effective (large) batch size.
    pub batch: usize,
    /// Base batch the hyperparameters are calibrated for.
    pub base_batch: usize,
    /// Base hypers at `base_batch`.
    pub base_hypers: HyperSet,
    /// Scaling rule mapping base hypers to `batch`.
    pub rule: ScalingRule,
    pub epochs: f64,
    /// Logical data-parallel workers.
    pub workers: usize,
    /// Compute threads for the worker fan-out, parallel eval, and the
    /// batch prefetcher: `1` = fully sequential (the seed behavior),
    /// `0` = auto (one thread per available core, capped by the work).
    /// The thread count never changes the math — contributions merge in
    /// rank order regardless of arrival order.
    pub threads: usize,
    /// Warmup steps on the dense LR (0 = none).
    pub warmup_steps: usize,
    /// Embedding init sigma.
    pub init_sigma: f32,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` epochs (0 = only at
    /// the end).
    pub eval_every_epochs: usize,
    /// Print progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// Batch-size scale factor `s` relative to the calibration batch.
    pub fn scale(&self) -> f64 {
        self.batch as f64 / self.base_batch as f64
    }

    /// The resolved hypers after applying the scaling rule.
    pub fn scaled_hypers(&self) -> HyperSet {
        self.rule.apply(&self.base_hypers, self.scale())
    }

    /// Resolve the thread count for a stage with `max_units` independent
    /// units of work (shards for the fan-out, batches for eval).
    pub fn threads_for(&self, max_units: usize) -> usize {
        let cap = match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            t => t,
        };
        cap.min(max_units).max(1)
    }
}

/// Per-epoch evaluation snapshot.
#[derive(Clone, Copy, Debug)]
pub struct EpochEval {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_auc: f64,
    pub test_logloss: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss_curve: Vec<f32>,
    pub epoch_evals: Vec<EpochEval>,
    pub reduce_stats: ReduceStats,
    /// (phase, seconds) totals: data / step / eval.
    pub phase_seconds: Vec<(String, f64)>,
    pub wall_seconds: f64,
    pub diverged: bool,
}

impl TrainReport {
    pub fn seconds(&self, phase: &str) -> f64 {
        self.phase_seconds
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// The leader: owns parameters and drives workers.
pub struct Trainer {
    pub engine: Engine,
    pub cfg: TrainConfig,
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    step: usize,
    /// Loop-invariant resolved hypers (scaling rule already applied).
    hypers: HyperSet,
    /// Loop-invariant warmup schedule.
    warmup: Warmup,
}

impl Trainer {
    pub fn new(engine: Engine, cfg: TrainConfig) -> Result<Trainer> {
        ensure!(cfg.batch % cfg.workers == 0, "batch must divide by workers");
        ensure!(cfg.workers >= 1);
        let spec = engine.spec();
        let params = init_params(&spec, &InitConfig { seed: cfg.seed, embed_sigma: cfg.init_sigma });
        let m = params.zeros_like();
        let v = params.zeros_like();
        let hypers = cfg.scaled_hypers();
        let warmup = Warmup::new(cfg.warmup_steps);
        Ok(Trainer { engine, cfg, params, m, v, step: 0, hypers, warmup })
    }

    pub fn step(&self) -> usize {
        self.step
    }

    /// One optimizer step on a prepared batch. Returns the batch loss.
    ///
    /// Fan-out runs on `threads_for(workers)` scoped threads (ranks are
    /// strided across threads so low ranks — merged first — finish
    /// first); the reduction happens on this thread as contributions
    /// arrive. `apply` then runs serially (see module docs).
    ///
    /// Threads are scoped per step: spawn cost is tens of µs against the
    /// multi-ms shard gradients of the large batches this engine targets.
    /// If µs-scale stepping ever matters, hoist a persistent pool to the
    /// `train()` scope (noted in ROADMAP).
    pub fn train_step(&mut self, batch: &Batch) -> Result<(f32, ReduceStats)> {
        self.step += 1;
        let hv = HypersVec::new(self.hypers)
            .at_step(self.step)
            .with_warmup(self.warmup.factor(self.step - 1));

        let workers = self.cfg.workers;
        let threads = self.cfg.threads_for(workers);
        let (total, stats) = if threads <= 1 {
            // sequential fan-out, same rank-ordered reduce
            let mut reducer = StreamingReducer::new(workers);
            for rank in 0..workers {
                let c = WorkerShard::new(rank, workers)
                    .compute(&self.engine, &self.params, batch)?;
                reducer.push(rank, c)?;
            }
            reducer.finish()?
        } else {
            let engine = &self.engine;
            let params = &self.params;
            std::thread::scope(|s| -> Result<(Contribution, ReduceStats)> {
                let (tx, rx) = std::sync::mpsc::channel();
                for t in 0..threads {
                    let tx = tx.clone();
                    s.spawn(move || {
                        let mut rank = t;
                        while rank < workers {
                            let c = WorkerShard::new(rank, workers)
                                .compute(engine, params, batch);
                            let failed = c.is_err();
                            if tx.send((rank, c)).is_err() || failed {
                                return;
                            }
                            rank += threads;
                        }
                    });
                }
                drop(tx); // reducer's recv loop ends when workers do
                let mut reducer = StreamingReducer::new(workers);
                for (rank, c) in rx {
                    reducer.push(rank, c?)?;
                }
                reducer.finish()
            })?
        };

        let mut grads = total.grads;
        self.engine.apply(
            &mut self.params,
            &mut self.m,
            &mut self.v,
            &mut grads,
            &total.counts,
            &hv,
        )?;
        Ok((total.loss_weighted, stats))
    }

    /// Evaluate AUC/logloss on a dataset, fanning eval batches out over
    /// `threads_for(n_batches)` threads. Logits are pushed into the
    /// accumulator in batch order, so the result is independent of the
    /// thread count.
    pub fn evaluate(&self, ds: &Dataset) -> Result<(f64, f64)> {
        // HLO fwd artifacts are shape-specialized: always use their exact
        // batch (EvalBatcher pads small datasets up to it); the reference
        // engine takes whatever fits.
        let eval_batch = self
            .engine
            .eval_batch()
            .unwrap_or_else(|| 1024.min(ds.n().max(1)));
        let n_batches = ds.n().div_ceil(eval_batch);
        let threads = self.cfg.threads_for(n_batches);
        let mut acc = EvalAccumulator::new();
        if threads <= 1 {
            for batch in EvalBatcher::new(ds, eval_batch) {
                let logits = self.engine.fwd(&self.params, &batch)?;
                acc.push(&logits, batch.y.as_f32()?, batch.valid);
            }
        } else {
            let engine = &self.engine;
            let params = &self.params;
            type EvalOut = (usize, Vec<f32>, Vec<f32>, usize);
            let mut results = std::thread::scope(|s| -> Result<Vec<EvalOut>> {
                let mut handles = Vec::with_capacity(threads);
                for t in 0..threads {
                    handles.push(s.spawn(move || -> Result<Vec<EvalOut>> {
                        let mut out = Vec::new();
                        let mut i = t;
                        while i < n_batches {
                            let batch = EvalBatcher::nth_batch(ds, eval_batch, i)
                                .ok_or_else(|| anyhow::anyhow!("eval batch {i} out of range"))?;
                            let logits = engine.fwd(params, &batch)?;
                            let y = batch.y.as_f32()?.to_vec();
                            out.push((i, logits, y, batch.valid));
                            i += threads;
                        }
                        Ok(out)
                    }));
                }
                let mut all = Vec::with_capacity(n_batches);
                for h in handles {
                    all.extend(h.join().expect("eval worker panicked")?);
                }
                Ok(all)
            })?;
            results.sort_unstable_by_key(|(i, ..)| *i);
            for (_, logits, y, valid) in &results {
                acc.push(logits, y, *valid);
            }
        }
        Ok((acc.auc(), acc.logloss()))
    }

    /// Full training run.
    ///
    /// With `threads != 1` the batcher runs on a scoped prefetch thread
    /// (double-buffered), overlapping batch materialization and the
    /// touched-id sort with the previous step's compute; `threads == 1`
    /// keeps the fully inline seed path. Both orders of batches are
    /// identical.
    pub fn train(&mut self, train: &Dataset, test: &Dataset) -> Result<TrainReport> {
        let t0 = Instant::now();
        let steps_per_epoch = train.n() / self.cfg.batch;
        ensure!(steps_per_epoch > 0, "batch larger than dataset");
        let total_steps = ((steps_per_epoch as f64) * self.cfg.epochs).round() as usize;
        ensure!(total_steps > 0, "no steps to run");

        let mut batcher = Batcher::new(train, self.cfg.batch, self.cfg.seed ^ 0x5eed);
        // only a single worker consumes the whole batch (and hence its
        // touched cache); shards compute their own slices' touched sets
        let warm_touched = self.cfg.workers == 1;
        if self.cfg.threads_for(2) > 1 {
            std::thread::scope(|scope| {
                let feed = Prefetch::spawn(
                    scope,
                    (0..total_steps).map(move |_| {
                        let b = batcher.next_batch();
                        if warm_touched {
                            let _ = b.touched(); // pay for the sort off the hot path
                        }
                        b
                    }),
                    2,
                );
                self.train_loop(t0, total_steps, steps_per_epoch, test, || {
                    feed.recv()
                        .ok_or_else(|| anyhow::anyhow!("prefetch producer exited early"))
                })
            })
        } else {
            self.train_loop(t0, total_steps, steps_per_epoch, test, || Ok(batcher.next_batch()))
        }
    }

    /// The step loop shared by the prefetched and inline data paths.
    fn train_loop(
        &mut self,
        t0: Instant,
        total_steps: usize,
        steps_per_epoch: usize,
        test: &Dataset,
        mut next_batch: impl FnMut() -> Result<Batch>,
    ) -> Result<TrainReport> {
        let mut sw = Stopwatch::new();
        let mut loss_curve = Vec::with_capacity(total_steps);
        let mut epoch_evals = Vec::new();
        let mut reduce_total = ReduceStats::default();
        let mut epoch_loss = LossMeter::new();
        let mut diverged = false;

        for s in 1..=total_steps {
            sw.start("data");
            let batch = next_batch()?;
            sw.start("step");
            let (loss, rstats) = self.train_step(&batch)?;
            sw.stop();
            reduce_total.rounds += rstats.rounds;
            reduce_total.bytes_moved += rstats.bytes_moved;
            reduce_total.workers = rstats.workers;
            loss_curve.push(loss);
            epoch_loss.update(loss as f64);
            if !loss.is_finite() {
                diverged = true;
                break;
            }

            let at_epoch_end = s % steps_per_epoch == 0;
            if at_epoch_end {
                let epoch = s / steps_per_epoch;
                let do_eval = self.cfg.eval_every_epochs > 0
                    && epoch % self.cfg.eval_every_epochs == 0;
                if do_eval {
                    sw.start("eval");
                    let (auc, ll) = self.evaluate(test)?;
                    sw.stop();
                    epoch_evals.push(EpochEval {
                        epoch,
                        train_loss: epoch_loss.mean(),
                        test_auc: auc,
                        test_logloss: ll,
                    });
                    if self.cfg.verbose {
                        println!(
                            "  epoch {epoch:>2}  train_loss {:.4}  test_auc {:.4}  test_logloss {:.4}",
                            epoch_loss.mean(),
                            auc,
                            ll
                        );
                    }
                }
                epoch_loss.reset();
            }
        }
        sw.stop();

        let (final_auc, final_logloss) = if diverged {
            (f64::NAN, f64::NAN)
        } else {
            let (a, l) = self.evaluate(test)?;
            (a, l)
        };

        Ok(TrainReport {
            steps: loss_curve.len(),
            final_auc,
            final_logloss,
            train_loss_curve: loss_curve,
            epoch_evals,
            reduce_stats: reduce_total,
            phase_seconds: sw
                .summary()
                .into_iter()
                .map(|(n, d)| (n, d.as_secs_f64()))
                .collect(),
            wall_seconds: t0.elapsed().as_secs_f64(),
            diverged,
        })
    }
}

/// Convenience: slice the first `n` rows of a dataset (cheap experiment
/// subsetting).
pub fn head(ds: &Dataset, n: usize) -> Dataset {
    let idx: Vec<usize> = (0..n.min(ds.n())).collect();
    ds.select(&idx)
}
