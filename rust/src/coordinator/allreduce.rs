//! Binary-tree all-reduce over worker gradient contributions.
//!
//! The paper trains on one GPU but motivates large batches partly by
//! multi-GPU embedding-gradient exchange costs; this module makes the
//! extension concrete: `W` logical workers each hold a weighted partial
//! (grads, counts, loss), and a `ceil(log2 W)`-round binary tree reduces
//! them to the full-batch gradient, with per-round traffic accounting so
//! Table 6's communication discussion can be quantified on this testbed.
//!
//! Contributions are **sparse-aware**: row-indexed gradients and counts
//! merge as sorted-id unions, and `bytes_moved` counts the actual sparse
//! payload (ids + values) — which is exactly the saving Zhao et al.'s
//! TeraByte-scale framework gets from exchanging touched rows instead of
//! whole tables.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::tensor::{GradTensor, SparseRows};

/// One worker's weighted contribution.
#[derive(Clone, Debug)]
pub struct Contribution {
    pub grads: Vec<GradTensor>,
    pub counts: SparseRows,
    /// Weighted loss (weight already folded in).
    pub loss_weighted: f32,
    pub weight: f32,
}

/// Traffic/latency accounting for one all-reduce.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReduceStats {
    pub rounds: usize,
    /// Total bytes a real network would move (sum over pairwise merges).
    pub bytes_moved: u64,
    pub workers: usize,
}

fn merge(dst: &mut Contribution, src: &Contribution) -> Result<u64> {
    ensure!(dst.grads.len() == src.grads.len(), "grad arity mismatch");
    let mut bytes = 0u64;
    for (a, b) in dst.grads.iter_mut().zip(&src.grads) {
        a.axpy(1.0, b)?;
        bytes += b.payload_bytes();
    }
    dst.counts.axpy(1.0, &src.counts)?;
    bytes += src.counts.payload_bytes();
    dst.loss_weighted += src.loss_weighted;
    dst.weight += src.weight;
    Ok(bytes)
}

/// Reduce all contributions to one (weights must sum to ~1).
pub fn tree_allreduce(
    mut contributions: Vec<Contribution>,
) -> Result<(Contribution, ReduceStats)> {
    ensure!(!contributions.is_empty(), "no contributions");
    let workers = contributions.len();
    let mut stats = ReduceStats { rounds: 0, bytes_moved: 0, workers };

    while contributions.len() > 1 {
        stats.rounds += 1;
        let half = contributions.len().div_ceil(2);
        // pair worker i with worker i+half; survivors are the first half
        let tail = contributions.split_off(half);
        for (i, src) in tail.iter().enumerate() {
            stats.bytes_moved += merge(&mut contributions[i], src)?;
        }
    }
    let total = contributions.pop().unwrap();
    ensure!(
        (total.weight - 1.0).abs() < 1e-3,
        "worker weights sum to {} != 1",
        total.weight
    );
    Ok((total, stats))
}

/// Reduce-as-ready: contributions stream in (over a channel, in whatever
/// order the worker threads finish) and merge **eagerly but always in
/// rank order**, so the slowest shard's gradient computation overlaps the
/// reduction of everything before it while the result stays bitwise
/// identical to a sequential rank-0..W-1 fold — which is what makes
/// threaded and sequential training runs agree to the last ulp (see
/// `rust/tests/parallel_parity.rs`).
///
/// Out-of-order arrivals park in a rank-keyed buffer until their
/// predecessors have merged. `rounds` counts pairwise merges (`W - 1`
/// for a full reduce) and `bytes_moved` the sparse payload traffic, same
/// accounting as [`tree_allreduce`].
pub struct StreamingReducer {
    workers: usize,
    next_rank: usize,
    pending: BTreeMap<usize, Contribution>,
    total: Option<Contribution>,
    stats: ReduceStats,
}

impl StreamingReducer {
    pub fn new(workers: usize) -> StreamingReducer {
        StreamingReducer {
            workers,
            next_rank: 0,
            pending: BTreeMap::new(),
            total: None,
            stats: ReduceStats { rounds: 0, bytes_moved: 0, workers },
        }
    }

    /// Ranks merged into the running total so far.
    pub fn merged(&self) -> usize {
        self.next_rank
    }

    /// Hand over `rank`'s contribution; merges every consecutive rank
    /// that is now available.
    pub fn push(&mut self, rank: usize, c: Contribution) -> Result<()> {
        ensure!(rank < self.workers, "rank {rank} out of range for {} workers", self.workers);
        ensure!(
            rank >= self.next_rank && !self.pending.contains_key(&rank),
            "duplicate contribution for rank {rank}"
        );
        self.pending.insert(rank, c);
        while let Some(next) = self.pending.remove(&self.next_rank) {
            match &mut self.total {
                None => self.total = Some(next),
                Some(t) => {
                    self.stats.rounds += 1;
                    self.stats.bytes_moved += merge(t, &next)?;
                }
            }
            self.next_rank += 1;
        }
        Ok(())
    }

    /// Finish: all ranks must have arrived and weights must sum to ~1.
    pub fn finish(self) -> Result<(Contribution, ReduceStats)> {
        ensure!(
            self.next_rank == self.workers,
            "only {}/{} contributions arrived",
            self.next_rank,
            self.workers
        );
        let total = self.total.ok_or_else(|| anyhow::anyhow!("no contributions"))?;
        ensure!(
            (total.weight - 1.0).abs() < 1e-3,
            "worker weights sum to {} != 1",
            total.weight
        );
        Ok((total, self.stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn contrib(v: f32, w: f32) -> Contribution {
        Contribution {
            grads: vec![GradTensor::Dense(Tensor::f32(vec![3], vec![v, v, v]))],
            counts: SparseRows::new(2, 1, vec![0, 1], vec![1.0, 2.0]),
            loss_weighted: 0.1 * w,
            weight: w,
        }
    }

    fn sparse_contrib(id: u32, v: f32, w: f32) -> Contribution {
        Contribution {
            grads: vec![GradTensor::Sparse(SparseRows::new(100, 2, vec![id], vec![v, v]))],
            counts: SparseRows::new(100, 1, vec![id], vec![1.0]),
            loss_weighted: 0.1 * w,
            weight: w,
        }
    }

    #[test]
    fn reduces_to_weighted_sum() {
        let cs = vec![contrib(0.25, 0.25); 4];
        let (total, stats) = tree_allreduce(cs).unwrap();
        assert_eq!(total.grads[0].to_tensor().as_f32().unwrap(), &[1.0, 1.0, 1.0]);
        assert_eq!(total.counts.to_dense(), vec![4.0, 8.0]);
        assert!((total.weight - 1.0).abs() < 1e-6);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.workers, 4);
        // 4 workers: 3 merges, each 3*4 grad bytes + (2+2)*4 count bytes
        assert_eq!(stats.bytes_moved, 3 * (3 * 4 + 4 * 4));
    }

    #[test]
    fn sparse_contributions_stay_sparse_and_cheap() {
        let cs = vec![
            sparse_contrib(3, 0.5, 0.5),
            sparse_contrib(90, 0.5, 0.5),
        ];
        let (total, stats) = tree_allreduce(cs).unwrap();
        match &total.grads[0] {
            GradTensor::Sparse(s) => {
                assert_eq!(s.ids(), &[3, 90]);
                assert_eq!(s.n_rows(), 100);
            }
            GradTensor::Dense(_) => panic!("all-reduce densified a sparse grad"),
        }
        assert_eq!(total.counts.ids(), &[3, 90]);
        // one merge: 1 grad row (1 id + 2 vals)*4 + counts (1 id + 1 val)*4
        assert_eq!(stats.bytes_moved, (1 + 2) * 4 + (1 + 1) * 4);
        // far below the dense payload of 100*2*4 + 100*4 bytes
        assert!(stats.bytes_moved < 1200);
    }

    #[test]
    fn odd_worker_count() {
        let cs = vec![contrib(1.0 / 3.0, 1.0 / 3.0); 3];
        let (total, stats) = tree_allreduce(cs).unwrap();
        assert!((total.grads[0].to_tensor().as_f32().unwrap()[0] - 1.0).abs() < 1e-6);
        assert_eq!(stats.rounds, 2);
    }

    #[test]
    fn single_worker_is_free() {
        let (total, stats) = tree_allreduce(vec![contrib(1.0, 1.0)]).unwrap();
        assert_eq!(stats.bytes_moved, 0);
        assert_eq!(stats.rounds, 0);
        assert_eq!(total.counts.to_dense(), vec![1.0, 2.0]);
    }

    #[test]
    fn mismatched_weights_rejected() {
        let cs = vec![contrib(1.0, 0.3), contrib(1.0, 0.3)];
        assert!(tree_allreduce(cs).is_err());
    }

    #[test]
    fn streaming_reducer_is_arrival_order_invariant() {
        // same four contributions, three different arrival orders — the
        // totals must be identical because merges happen in rank order
        let mk = |v: f32| contrib(v, 0.25);
        let vals = [0.1f32, 0.2, 0.3, 0.4];
        let mut totals = Vec::new();
        for order in [[0usize, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]] {
            let mut r = StreamingReducer::new(4);
            for rank in order {
                r.push(rank, mk(vals[rank])).unwrap();
            }
            let (total, stats) = r.finish().unwrap();
            assert_eq!(stats.rounds, 3, "W-1 merges");
            assert_eq!(stats.workers, 4);
            assert!(stats.bytes_moved > 0);
            totals.push(total.grads[0].to_tensor().as_f32().unwrap().to_vec());
        }
        assert_eq!(totals[0], totals[1]);
        assert_eq!(totals[0], totals[2]);
    }

    #[test]
    fn streaming_reducer_matches_sequential_fold() {
        let cs: Vec<Contribution> =
            (0..3).map(|r| sparse_contrib(10 * r + 1, 1.0 / 3.0, 1.0 / 3.0)).collect();
        let mut r = StreamingReducer::new(3);
        for (rank, c) in cs.clone().into_iter().enumerate() {
            r.push(rank, c).unwrap();
        }
        let (total, _) = r.finish().unwrap();
        // manual rank-ordered fold
        let mut want = cs[0].clone();
        merge(&mut want, &cs[1]).unwrap();
        merge(&mut want, &cs[2]).unwrap();
        assert_eq!(
            total.grads[0].to_tensor().as_f32().unwrap(),
            want.grads[0].to_tensor().as_f32().unwrap()
        );
        assert!(matches!(total.grads[0], GradTensor::Sparse(_)));
    }

    #[test]
    fn streaming_reducer_rejects_incomplete_and_duplicates() {
        let mut r = StreamingReducer::new(2);
        r.push(0, contrib(0.5, 0.5)).unwrap();
        assert!(r.push(0, contrib(0.5, 0.5)).is_err(), "duplicate rank");
        assert!(r.push(5, contrib(0.5, 0.5)).is_err(), "rank out of range");
        let mut r = StreamingReducer::new(2);
        r.push(1, contrib(0.5, 0.5)).unwrap();
        assert_eq!(r.merged(), 0, "rank 1 parks until rank 0 lands");
        assert!(r.finish().is_err(), "missing rank 0");
    }
}
