//! Binary-tree all-reduce over worker gradient contributions.
//!
//! The paper trains on one GPU but motivates large batches partly by
//! multi-GPU embedding-gradient exchange costs; this module makes the
//! extension concrete: `W` logical workers each hold a weighted partial
//! (grads, counts, loss), and a `ceil(log2 W)`-round binary tree reduces
//! them to the full-batch gradient, with per-round traffic accounting so
//! Table 6's communication discussion can be quantified on this testbed.
//!
//! Contributions are **sparse-aware**: row-indexed gradients and counts
//! merge as sorted-id unions, and `bytes_moved` counts the actual sparse
//! payload (ids + values) — which is exactly the saving Zhao et al.'s
//! TeraByte-scale framework gets from exchanging touched rows instead of
//! whole tables.
//!
//! Two reducers live here:
//!
//! * [`tree_allreduce`] — the offline round-structured reduce kept for
//!   the traffic-model studies and tests.
//! * [`TreeReducer`] — the streaming reducer on the training hot path:
//!   contributions arrive in any order (over a channel, as worker
//!   threads finish) and merge eagerly along a **fixed binary tree over
//!   contiguous rank ranges**. The pairing depends only on the worker
//!   count — never on arrival order or thread count — so the reduction
//!   is bitwise deterministic, and the critical path after the last
//!   arrival is O(log W) merges instead of the O(W) tail the old serial
//!   rank-ordered fold paid. With [`TreeReducer::deferred`], the *root*
//!   merge (the largest one) is withheld and handed back as
//!   [`Reduced::Halves`], so the sharded apply stage can run it split by
//!   parameter-shard row range — each shard merges its slice and
//!   immediately applies it, overlapping the merge tail with the
//!   optimizer (`model::store::ParamStore::apply_sharded_pair`).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::tensor::{GradTensor, SparseRows};
use crate::wire::codec::contribution_wire_len;
use crate::wire::frame::FRAME_HEADER_LEN;

/// One worker's weighted contribution.
#[derive(Clone, Debug)]
pub struct Contribution {
    pub grads: Vec<GradTensor>,
    pub counts: SparseRows,
    /// Weighted loss (weight already folded in).
    pub loss_weighted: f32,
    pub weight: f32,
}

/// Traffic/latency accounting for one all-reduce.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReduceStats {
    pub rounds: usize,
    /// Raw sparse payload bytes (ids + values as f32) summed over the
    /// pairwise merges — the traffic-model quantity of Table 6.
    pub bytes_moved: u64,
    /// What the same merges would occupy **on the wire** under the
    /// `wire` layer's uncompressed framing: frame header + versioned
    /// `Contribution` encoding (shared-id elision included). This is
    /// what `coordinator::dist` actually moves per uplink frame with
    /// compression off; quantization only shrinks it further.
    pub wire_bytes: u64,
    pub workers: usize,
}

impl ReduceStats {
    /// Fold one step's stats into a running total (the trainer's
    /// end-of-run summary; `workers` is a property, not a sum).
    pub fn accumulate(&mut self, step: &ReduceStats) {
        self.rounds += step.rounds;
        self.bytes_moved += step.bytes_moved;
        self.wire_bytes += step.wire_bytes;
        self.workers = step.workers;
    }
}

/// A finished reduction: either the full total, or the root's two
/// subtree totals with their merge deferred into the apply stage.
pub enum Reduced {
    Whole(Contribution),
    /// `left` covers ranks `[0, mid)`, `right` covers `[mid, W)`; the
    /// root merge `left + right` has been *accounted* in the stats but
    /// executes inside the sharded apply, split per row range.
    Halves { left: Contribution, right: Contribution },
}

impl Reduced {
    /// Total weighted loss (the root merge's loss sum is associative-free).
    pub fn loss_weighted(&self) -> f32 {
        match self {
            Reduced::Whole(c) => c.loss_weighted,
            Reduced::Halves { left, right } => left.loss_weighted + right.loss_weighted,
        }
    }

    /// Force the full merge (fallback consumers: HLO apply, tests).
    pub fn into_whole(self) -> Result<Contribution> {
        match self {
            Reduced::Whole(c) => Ok(c),
            Reduced::Halves { mut left, right } => {
                merge(&mut left, &right)?;
                Ok(left)
            }
        }
    }
}

/// Merge `src` into `dst`, returning `(raw, wire)` traffic for the
/// transfer of `src`: raw sparse payload bytes vs the framed
/// uncompressed wire encoding ([`contribution_wire_len`]).
fn merge(dst: &mut Contribution, src: &Contribution) -> Result<(u64, u64)> {
    let _span = crate::obs::span(crate::obs::Phase::Reduce);
    ensure!(dst.grads.len() == src.grads.len(), "grad arity mismatch");
    let wire = FRAME_HEADER_LEN as u64 + contribution_wire_len(src);
    let mut bytes = 0u64;
    for (a, b) in dst.grads.iter_mut().zip(&src.grads) {
        a.axpy(1.0, b)?;
        bytes += b.payload_bytes();
    }
    dst.counts.axpy(1.0, &src.counts)?;
    bytes += src.counts.payload_bytes();
    dst.loss_weighted += src.loss_weighted;
    dst.weight += src.weight;
    Ok((bytes, wire))
}

fn payload_bytes(c: &Contribution) -> u64 {
    c.grads.iter().map(|g| g.payload_bytes()).sum::<u64>() + c.counts.payload_bytes()
}

/// Reduce all contributions to one (weights must sum to ~1).
pub fn tree_allreduce(
    mut contributions: Vec<Contribution>,
) -> Result<(Contribution, ReduceStats)> {
    ensure!(!contributions.is_empty(), "no contributions");
    let workers = contributions.len();
    let mut stats = ReduceStats { rounds: 0, bytes_moved: 0, wire_bytes: 0, workers };

    while contributions.len() > 1 {
        stats.rounds += 1;
        let half = contributions.len().div_ceil(2);
        // pair worker i with worker i+half; survivors are the first half
        let tail = contributions.split_off(half);
        for (i, src) in tail.iter().enumerate() {
            let (raw, wire) = merge(&mut contributions[i], src)?;
            stats.bytes_moved += raw;
            stats.wire_bytes += wire;
        }
    }
    let total = contributions.pop().unwrap();
    ensure!(
        (total.weight - 1.0).abs() < 1e-3,
        "worker weights sum to {} != 1",
        total.weight
    );
    Ok((total, stats))
}

/// The canonical tree split of a rank range `[lo, hi)`: the left child
/// takes the ceiling half. Every node of the merge tree is a contiguous
/// range produced by recursively applying this split from the root
/// `[0, W)` — fixed by `W` alone.
fn split_point(lo: usize, hi: usize) -> usize {
    lo + (hi - lo).div_ceil(2)
}

/// Locate the sibling + parent of canonical segment `[lo, hi)` by
/// descending the fixed tree from the root. Returns `None` for the root
/// itself.
fn sibling_of(
    workers: usize,
    lo: usize,
    hi: usize,
) -> Option<((usize, usize), (usize, usize), bool)> {
    let (mut a, mut b) = (0usize, workers);
    while b - a > 1 {
        let mid = split_point(a, b);
        if (lo, hi) == (a, mid) {
            return Some(((mid, b), (a, b), true));
        }
        if (lo, hi) == (mid, b) {
            return Some(((a, mid), (a, b), false));
        }
        if hi <= mid {
            b = mid;
        } else if lo >= mid {
            a = mid;
        } else {
            unreachable!("segment [{lo}, {hi}) straddles the canonical split {mid}");
        }
    }
    None
}

/// Reduce-as-ready over a **deterministic merge tree** (see module
/// docs): contributions stream in (over a channel, in whatever order the
/// worker threads finish) and merge eagerly with their tree sibling as
/// soon as both sides are ready. The pairing is fixed by the worker
/// count, so the result — and the per-merge traffic accounting — is
/// identical at any thread count and any arrival order; the work
/// *remaining* after the slowest shard lands is only its O(log W) spine
/// to the root, not a serial O(W) fold.
///
/// `rounds` counts pairwise merges (`W - 1` for a full reduce) and
/// `bytes_moved` the sparse payload traffic, same accounting as
/// [`tree_allreduce`].
pub struct TreeReducer {
    workers: usize,
    arrived: Vec<bool>,
    /// Ready-but-unmerged canonical segments: `lo -> (hi, contribution)`.
    ready: BTreeMap<usize, (usize, Contribution)>,
    stats: ReduceStats,
    /// Withhold the root merge for the apply stage (see
    /// [`TreeReducer::finish_halves`]).
    defer_root: bool,
}

impl TreeReducer {
    pub fn new(workers: usize) -> TreeReducer {
        TreeReducer {
            workers,
            arrived: vec![false; workers],
            ready: BTreeMap::new(),
            stats: ReduceStats { rounds: 0, bytes_moved: 0, wire_bytes: 0, workers },
            defer_root: false,
        }
    }

    /// A reducer that stops one merge short of the root: `finish_halves`
    /// hands back the two subtree totals so the final (largest) merge
    /// can run inside the sharded apply, split per row range.
    pub fn deferred(workers: usize) -> TreeReducer {
        let mut r = TreeReducer::new(workers);
        r.defer_root = true;
        r
    }

    /// Ranks whose contributions have arrived so far.
    pub fn arrived(&self) -> usize {
        self.arrived.iter().filter(|&&a| a).count()
    }

    /// Hand over `rank`'s contribution; eagerly merges every tree node
    /// whose two children are now both ready.
    pub fn push(&mut self, rank: usize, c: Contribution) -> Result<()> {
        ensure!(rank < self.workers, "rank {rank} out of range for {} workers", self.workers);
        ensure!(!self.arrived[rank], "duplicate contribution for rank {rank}");
        self.arrived[rank] = true;
        self.ready.insert(rank, (rank + 1, c));

        let (mut lo, mut hi) = (rank, rank + 1);
        while let Some((sib, parent, is_left)) = sibling_of(self.workers, lo, hi) {
            if self.defer_root && parent == (0, self.workers) {
                break;
            }
            let sib_ready = self.ready.get(&sib.0).is_some_and(|(h, _)| *h == sib.1);
            if !sib_ready {
                break;
            }
            let (_, other) = self.ready.remove(&sib.0).unwrap();
            let (_, mine) = self.ready.remove(&lo).unwrap();
            // merge left += right regardless of arrival order
            let (mut left, right) = if is_left { (mine, other) } else { (other, mine) };
            self.stats.rounds += 1;
            let (raw, wire) = merge(&mut left, &right)?;
            self.stats.bytes_moved += raw;
            self.stats.wire_bytes += wire;
            self.ready.insert(parent.0, (parent.1, left));
            (lo, hi) = parent;
        }
        Ok(())
    }

    fn ensure_complete(&self) -> Result<()> {
        let n = self.arrived();
        ensure!(
            n == self.workers,
            "only {n}/{} contributions arrived",
            self.workers
        );
        Ok(())
    }

    /// Finish with the full total: all ranks must have arrived and
    /// weights must sum to ~1. (A deferred reducer performs the root
    /// merge here — the fallback for consumers that need the whole
    /// gradient, e.g. the HLO apply program.)
    pub fn finish(mut self) -> Result<(Contribution, ReduceStats)> {
        self.ensure_complete()?;
        if self.ready.len() == 2 {
            let (_, (_, right)) = self.ready.pop_last().unwrap();
            let (_, (_, mut left)) = self.ready.pop_last().unwrap();
            self.stats.rounds += 1;
            let (raw, wire) = merge(&mut left, &right)?;
            self.stats.bytes_moved += raw;
            self.stats.wire_bytes += wire;
            self.ready.insert(0, (self.workers, left));
        }
        ensure!(self.ready.len() == 1, "reduction did not converge to a single segment");
        let (_, (_, total)) = self.ready.pop_last().unwrap();
        ensure!(
            (total.weight - 1.0).abs() < 1e-3,
            "worker weights sum to {} != 1",
            total.weight
        );
        Ok((total, self.stats))
    }

    /// Finish with the root merge deferred: returns
    /// [`Reduced::Halves`] (or `Whole` for a single worker). The
    /// withheld merge is *accounted* here — its pairing, payload bytes
    /// and round are fixed already — so the stats are identical to
    /// [`TreeReducer::finish`]'s at any thread count.
    pub fn finish_halves(mut self) -> Result<(Reduced, ReduceStats)> {
        ensure!(self.defer_root, "finish_halves requires TreeReducer::deferred");
        self.ensure_complete()?;
        if self.workers == 1 {
            let (_, (_, total)) = self.ready.pop_last().unwrap();
            ensure!((total.weight - 1.0).abs() < 1e-3, "weight {} != 1", total.weight);
            return Ok((Reduced::Whole(total), self.stats));
        }
        ensure!(self.ready.len() == 2, "deferred reduction must end with two subtrees");
        let (_, (_, right)) = self.ready.pop_last().unwrap();
        let (_, (_, left)) = self.ready.pop_last().unwrap();
        ensure!(
            (left.weight + right.weight - 1.0).abs() < 1e-3,
            "worker weights sum to {} != 1",
            left.weight + right.weight
        );
        self.stats.rounds += 1;
        self.stats.bytes_moved += payload_bytes(&right);
        self.stats.wire_bytes += FRAME_HEADER_LEN as u64 + contribution_wire_len(&right);
        Ok((Reduced::Halves { left, right }, self.stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn contrib(v: f32, w: f32) -> Contribution {
        Contribution {
            grads: vec![GradTensor::Dense(Tensor::f32(vec![3], vec![v, v, v]))],
            counts: SparseRows::new(2, 1, vec![0, 1], vec![1.0, 2.0]),
            loss_weighted: 0.1 * w,
            weight: w,
        }
    }

    fn sparse_contrib(id: u32, v: f32, w: f32) -> Contribution {
        Contribution {
            grads: vec![GradTensor::Sparse(SparseRows::new(100, 2, vec![id], vec![v, v]))],
            counts: SparseRows::new(100, 1, vec![id], vec![1.0]),
            loss_weighted: 0.1 * w,
            weight: w,
        }
    }

    /// The reference serial execution of the same fixed tree: recursive
    /// left-ceiling split, children reduced first, then left += right.
    fn serial_tree_fold(cs: &[Contribution], lo: usize, hi: usize) -> Contribution {
        if hi - lo == 1 {
            return cs[lo].clone();
        }
        let mid = super::split_point(lo, hi);
        let mut left = serial_tree_fold(cs, lo, mid);
        let right = serial_tree_fold(cs, mid, hi);
        merge(&mut left, &right).unwrap();
        left
    }

    #[test]
    fn reduces_to_weighted_sum() {
        let cs = vec![contrib(0.25, 0.25); 4];
        let (total, stats) = tree_allreduce(cs).unwrap();
        assert_eq!(total.grads[0].to_tensor().as_f32().unwrap(), &[1.0, 1.0, 1.0]);
        assert_eq!(total.counts.to_dense(), vec![4.0, 8.0]);
        assert!((total.weight - 1.0).abs() < 1e-6);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.workers, 4);
        // 4 workers: 3 merges, each 3*4 grad bytes + (2+2)*4 count bytes
        assert_eq!(stats.bytes_moved, 3 * (3 * 4 + 4 * 4));
        // on-wire accounting: every merge moves one framed, versioned
        // contribution — and all three transferred sides are identical
        // in shape, so the exact length is 3x one encoding
        let per_merge = FRAME_HEADER_LEN as u64 + contribution_wire_len(&contrib(0.25, 0.25));
        assert_eq!(stats.wire_bytes, 3 * per_merge);
    }

    #[test]
    fn sparse_contributions_stay_sparse_and_cheap() {
        let cs = vec![
            sparse_contrib(3, 0.5, 0.5),
            sparse_contrib(90, 0.5, 0.5),
        ];
        let (total, stats) = tree_allreduce(cs).unwrap();
        match &total.grads[0] {
            GradTensor::Sparse(s) => {
                assert_eq!(s.ids(), &[3, 90]);
                assert_eq!(s.n_rows(), 100);
            }
            GradTensor::Dense(_) => panic!("all-reduce densified a sparse grad"),
        }
        assert_eq!(total.counts.ids(), &[3, 90]);
        // one merge: 1 grad row (1 id + 2 vals)*4 + counts (1 id + 1 val)*4
        assert_eq!(stats.bytes_moved, (1 + 2) * 4 + (1 + 1) * 4);
        // far below the dense payload of 100*2*4 + 100*4 bytes
        assert!(stats.bytes_moved < 1200);
        // the single transferred side is the rank-1 leaf
        assert_eq!(
            stats.wire_bytes,
            FRAME_HEADER_LEN as u64 + contribution_wire_len(&sparse_contrib(90, 0.5, 0.5))
        );
    }

    #[test]
    fn odd_worker_count() {
        let cs = vec![contrib(1.0 / 3.0, 1.0 / 3.0); 3];
        let (total, stats) = tree_allreduce(cs).unwrap();
        assert!((total.grads[0].to_tensor().as_f32().unwrap()[0] - 1.0).abs() < 1e-6);
        assert_eq!(stats.rounds, 2);
    }

    #[test]
    fn single_worker_is_free() {
        let (total, stats) = tree_allreduce(vec![contrib(1.0, 1.0)]).unwrap();
        assert_eq!(stats.bytes_moved, 0);
        assert_eq!(stats.rounds, 0);
        assert_eq!(total.counts.to_dense(), vec![1.0, 2.0]);
    }

    #[test]
    fn mismatched_weights_rejected() {
        let cs = vec![contrib(1.0, 0.3), contrib(1.0, 0.3)];
        assert!(tree_allreduce(cs).is_err());
    }

    /// Acceptance (satellite): for 1–9 workers and scrambled arrival
    /// orders, the streaming tree reducer is **bitwise** equal to the
    /// serial execution of the same fold — the fixed pairing, not the
    /// arrival schedule, defines the result.
    #[test]
    fn tree_reducer_bitwise_matches_serial_fold_1_to_9_workers() {
        for workers in 1usize..=9 {
            // overlapping + disjoint sparse ids, uneven values
            let cs: Vec<Contribution> = (0..workers)
                .map(|r| {
                    let mut c = sparse_contrib(
                        (7 * r % 10) as u32,
                        0.1 + r as f32 * 0.371,
                        1.0 / workers as f32,
                    );
                    c.loss_weighted = 0.01 * r as f32;
                    c
                })
                .collect();
            let want = serial_tree_fold(&cs, 0, workers);

            // a few deterministic scrambles of the arrival order
            for scramble in 0..3usize {
                let mut order: Vec<usize> = (0..workers).collect();
                match scramble {
                    1 => order.reverse(),
                    2 => order.rotate_left(workers / 2),
                    _ => {}
                }
                let mut r = TreeReducer::new(workers);
                for rank in order {
                    r.push(rank, cs[rank].clone()).unwrap();
                }
                let (total, stats) = r.finish().unwrap();
                assert_eq!(stats.rounds, workers - 1, "W-1 merges");
                assert_eq!(
                    total.grads[0].to_tensor().as_f32().unwrap(),
                    want.grads[0].to_tensor().as_f32().unwrap(),
                    "workers={workers} scramble={scramble}: grads"
                );
                assert_eq!(total.counts, want.counts, "workers={workers}: counts");
                assert_eq!(total.loss_weighted, want.loss_weighted, "workers={workers}: loss");
            }
        }
    }

    /// Deferred mode: halves merge to exactly the full finish() total,
    /// and the accounted stats agree with the eager path.
    #[test]
    fn deferred_root_merge_equals_eager_finish() {
        for workers in 1usize..=7 {
            let cs: Vec<Contribution> = (0..workers)
                .map(|r| sparse_contrib((3 * r % 8) as u32, 0.2 + r as f32, 1.0 / workers as f32))
                .collect();
            let mut eager = TreeReducer::new(workers);
            let mut deferred = TreeReducer::deferred(workers);
            for (rank, c) in cs.iter().enumerate() {
                eager.push(rank, c.clone()).unwrap();
                deferred.push(rank, c.clone()).unwrap();
            }
            let (want, want_stats) = eager.finish().unwrap();
            let (halves, stats) = deferred.finish_halves().unwrap();
            assert_eq!(stats, want_stats, "workers={workers}: stats must match");
            let got = halves.into_whole().unwrap();
            assert_eq!(
                got.grads[0].to_tensor().as_f32().unwrap(),
                want.grads[0].to_tensor().as_f32().unwrap(),
                "workers={workers}"
            );
            assert_eq!(got.counts, want.counts);
        }
    }

    #[test]
    fn arrival_order_and_critical_path() {
        // same four contributions, three arrival orders — identical
        // totals; and after the last arrival only the spine merges run
        let mk = |v: f32| contrib(v, 0.25);
        let vals = [0.1f32, 0.2, 0.3, 0.4];
        let mut totals = Vec::new();
        for order in [[0usize, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]] {
            let mut r = TreeReducer::new(4);
            for rank in order {
                r.push(rank, mk(vals[rank])).unwrap();
            }
            let (total, stats) = r.finish().unwrap();
            assert_eq!(stats.rounds, 3, "W-1 merges");
            assert_eq!(stats.workers, 4);
            assert!(stats.bytes_moved > 0);
            // framing + versioned encoding overhead dominates these tiny
            // contributions, so wire > raw here; at scale they converge
            assert!(stats.wire_bytes > stats.bytes_moved);
            totals.push(total.grads[0].to_tensor().as_f32().unwrap().to_vec());
        }
        assert_eq!(totals[0], totals[1]);
        assert_eq!(totals[0], totals[2]);

        // critical path: with ranks 0,1,3 already in, rank 2's arrival
        // triggers exactly the ceil(log2 4) = 2 spine merges
        let mut r = TreeReducer::new(4);
        r.push(0, mk(0.1)).unwrap();
        r.push(1, mk(0.2)).unwrap(); // merges (0,1) immediately
        r.push(3, mk(0.4)).unwrap(); // parks: sibling 2 missing
        assert_eq!(r.stats.rounds, 1);
        r.push(2, mk(0.3)).unwrap(); // (2,3) then root — the log-depth spine
        assert_eq!(r.stats.rounds, 3);
    }

    #[test]
    fn tree_reducer_rejects_incomplete_and_duplicates() {
        let mut r = TreeReducer::new(2);
        r.push(0, contrib(0.5, 0.5)).unwrap();
        assert!(r.push(0, contrib(0.5, 0.5)).is_err(), "duplicate rank");
        assert!(r.push(5, contrib(0.5, 0.5)).is_err(), "rank out of range");
        let mut r = TreeReducer::new(2);
        r.push(1, contrib(0.5, 0.5)).unwrap();
        assert_eq!(r.arrived(), 1);
        assert!(r.finish().is_err(), "missing rank 0");
        let r = TreeReducer::new(3);
        assert!(r.finish().is_err(), "nothing arrived");
    }
}
