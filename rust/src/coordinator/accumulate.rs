//! Gradient accumulation across microbatches.
//!
//! A logical batch of size `B` is computed as `k` microbatches of equal
//! size `b` (`B = k·b`). Because every microbatch gradient is a *mean*
//! over its rows, the big-batch gradient is the weight-`b/B` sum of the
//! microbatch gradients; occurrence counts add. The clip threshold of
//! Alg. 1 then sees exactly the full-batch `cnt(id)`, which is the
//! invariant `python/tests/test_train_step.py::
//! test_microbatch_accumulation_equals_big_batch` pins down on the JAX
//! side and `rust/tests` re-checks end to end.
//!
//! Accumulation is **sparse-aware**: row-indexed gradients and counts
//! merge as sorted-id unions (cost O(touched · d) per add), never
//! densifying over `total_vocab()` — the batch's union of touched ids
//! stays tiny relative to V on CTR data.

use anyhow::{ensure, Result};

use crate::reference::GradOutput;
use crate::tensor::{GradTensor, SparseRows};

/// Weighted accumulator for microbatch gradient outputs.
pub struct GradAccumulator {
    grads: Option<Vec<GradTensor>>,
    counts: SparseRows,
    loss_weighted: f64,
    weight: f64,
}

impl GradAccumulator {
    pub fn new(vocab: usize) -> GradAccumulator {
        GradAccumulator {
            grads: None,
            counts: SparseRows::empty(vocab, 1),
            loss_weighted: 0.0,
            weight: 0.0,
        }
    }

    /// Add one microbatch's output with the given weight (its share of
    /// the effective batch, e.g. `b/B`). Borrows the output and clones
    /// only to seed the first microbatch (later adds merge in place) —
    /// see [`GradAccumulator::add_owned`] for the fully move-in path.
    pub fn add(&mut self, out: &GradOutput, weight: f64) -> Result<()> {
        ensure!(out.counts.n_rows() == self.counts.n_rows(), "vocab mismatch");
        match &mut self.grads {
            None => {
                let mut scaled = out.grads.clone();
                for t in &mut scaled {
                    t.scale(weight as f32)?;
                }
                self.grads = Some(scaled);
            }
            Some(acc) => {
                ensure!(acc.len() == out.grads.len(), "grad arity mismatch");
                for (a, g) in acc.iter_mut().zip(&out.grads) {
                    a.axpy(weight as f32, g)?;
                }
            }
        }
        // counts add unweighted: Alg. 1 wants the full-batch cnt(id)
        self.counts.axpy(1.0, &out.counts)?;
        self.loss_weighted += out.loss as f64 * weight;
        self.weight += weight;
        Ok(())
    }

    /// Move-in twin of [`GradAccumulator::add`]: the first microbatch's
    /// gradients and counts are scaled in place and kept (no clone), so
    /// a worker whose shard is a single microbatch — the reference
    /// engine's common case — accumulates with zero payload copies.
    pub fn add_owned(&mut self, out: GradOutput, weight: f64) -> Result<()> {
        ensure!(out.counts.n_rows() == self.counts.n_rows(), "vocab mismatch");
        match &mut self.grads {
            None => {
                let mut scaled = out.grads;
                for t in &mut scaled {
                    t.scale(weight as f32)?;
                }
                self.grads = Some(scaled);
            }
            Some(acc) => {
                ensure!(acc.len() == out.grads.len(), "grad arity mismatch");
                for (a, g) in acc.iter_mut().zip(&out.grads) {
                    a.axpy(weight as f32, g)?;
                }
            }
        }
        // counts add unweighted: Alg. 1 wants the full-batch cnt(id).
        // `axpy(1.0, x)` into an empty table equals `x` bitwise, so the
        // first microbatch may simply move its counts in.
        if self.counts.is_empty() {
            self.counts = out.counts;
        } else {
            self.counts.axpy(1.0, &out.counts)?;
        }
        self.loss_weighted += out.loss as f64 * weight;
        self.weight += weight;
        Ok(())
    }

    /// Total weight added so far (should reach 1.0 for a full batch).
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Decompose into raw parts: (grads, counts, weighted loss, weight).
    /// Used by workers whose partial weight is deliberately < 1.
    pub fn into_parts(self) -> (Option<Vec<GradTensor>>, SparseRows, f32, f64) {
        (self.grads, self.counts, self.loss_weighted as f32, self.weight)
    }

    /// Finish: returns (grads, counts, weighted mean loss).
    pub fn finish(self) -> Result<(Vec<GradTensor>, SparseRows, f32)> {
        ensure!(self.grads.is_some(), "no microbatches accumulated");
        ensure!(
            (self.weight - 1.0).abs() < 1e-4,
            "accumulated weight {} != 1.0 (incomplete batch?)",
            self.weight
        );
        Ok((
            self.grads.unwrap(),
            self.counts,
            self.loss_weighted as f32,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn out(val: f32, count: f32, loss: f32) -> GradOutput {
        GradOutput {
            grads: vec![GradTensor::Dense(Tensor::f32(vec![2], vec![val, -val]))],
            counts: SparseRows::new(2, 1, vec![0], vec![count]),
            loss,
        }
    }

    fn sparse_out(id: u32, val: f32, count: f32, loss: f32) -> GradOutput {
        GradOutput {
            grads: vec![GradTensor::Sparse(SparseRows::new(
                4,
                2,
                vec![id],
                vec![val, -val],
            ))],
            counts: SparseRows::new(4, 1, vec![id], vec![count]),
            loss,
        }
    }

    #[test]
    fn weighted_mean_of_grads_and_sum_of_counts() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&out(1.0, 3.0, 0.5), 0.5).unwrap();
        acc.add(&out(3.0, 1.0, 0.7), 0.5).unwrap();
        let (grads, counts, loss) = acc.finish().unwrap();
        assert_eq!(grads[0].to_tensor().as_f32().unwrap(), &[2.0, -2.0]);
        assert_eq!(counts.to_dense(), vec![4.0, 0.0]);
        assert!((loss - 0.6).abs() < 1e-6);
    }

    #[test]
    fn sparse_microbatches_merge_without_densifying() {
        let mut acc = GradAccumulator::new(4);
        acc.add(&sparse_out(1, 2.0, 1.0, 0.4), 0.5).unwrap();
        acc.add(&sparse_out(3, 4.0, 2.0, 0.6), 0.5).unwrap();
        let (grads, counts, loss) = acc.finish().unwrap();
        match &grads[0] {
            GradTensor::Sparse(s) => {
                assert_eq!(s.ids(), &[1, 3]);
                assert_eq!(s.vals(), &[1.0, -1.0, 2.0, -2.0]);
            }
            GradTensor::Dense(_) => panic!("accumulation densified a sparse grad"),
        }
        assert_eq!(counts.ids(), &[1, 3]);
        assert_eq!(counts.vals(), &[1.0, 2.0]);
        assert!((loss - 0.5).abs() < 1e-6);
    }

    #[test]
    fn overlapping_sparse_ids_sum() {
        let mut acc = GradAccumulator::new(4);
        acc.add(&sparse_out(2, 2.0, 1.0, 0.0), 0.5).unwrap();
        acc.add(&sparse_out(2, 6.0, 3.0, 0.0), 0.5).unwrap();
        let (grads, counts, _) = acc.finish().unwrap();
        assert_eq!(grads[0].to_tensor().as_f32().unwrap()[4..6], [4.0, -4.0]);
        assert_eq!(counts.value_at(2), 4.0);
    }

    #[test]
    fn incomplete_weight_rejected() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&out(1.0, 1.0, 0.5), 0.25).unwrap();
        assert!(acc.finish().is_err());
    }

    #[test]
    fn empty_rejected() {
        let acc = GradAccumulator::new(2);
        assert!(acc.finish().is_err());
    }
}
