//! Gradient accumulation across microbatches.
//!
//! A logical batch of size `B` is computed as `k` microbatches of equal
//! size `b` (`B = k·b`). Because every microbatch gradient is a *mean*
//! over its rows, the big-batch gradient is the weight-`b/B` sum of the
//! microbatch gradients; occurrence counts add. The clip threshold of
//! Alg. 1 then sees exactly the full-batch `cnt(id)`, which is the
//! invariant `python/tests/test_train_step.py::
//! test_microbatch_accumulation_equals_big_batch` pins down on the JAX
//! side and `rust/tests` re-checks end to end.

use anyhow::{ensure, Result};

use crate::reference::GradOutput;
use crate::tensor::Tensor;

/// Weighted accumulator for microbatch gradient outputs.
pub struct GradAccumulator {
    grads: Option<Vec<Tensor>>,
    counts: Vec<f32>,
    loss_weighted: f64,
    weight: f64,
}

impl GradAccumulator {
    pub fn new(vocab: usize) -> GradAccumulator {
        GradAccumulator {
            grads: None,
            counts: vec![0.0; vocab],
            loss_weighted: 0.0,
            weight: 0.0,
        }
    }

    /// Add one microbatch's output with the given weight (its share of
    /// the effective batch, e.g. `b/B`).
    pub fn add(&mut self, out: &GradOutput, weight: f64) -> Result<()> {
        ensure!(out.counts.len() == self.counts.len(), "vocab mismatch");
        match &mut self.grads {
            None => {
                let mut scaled = out.grads.clone();
                for t in &mut scaled {
                    t.scale(weight as f32)?;
                }
                self.grads = Some(scaled);
            }
            Some(acc) => {
                ensure!(acc.len() == out.grads.len(), "grad arity mismatch");
                for (a, g) in acc.iter_mut().zip(&out.grads) {
                    a.axpy(weight as f32, g)?;
                }
            }
        }
        for (c, &x) in self.counts.iter_mut().zip(&out.counts) {
            *c += x;
        }
        self.loss_weighted += out.loss as f64 * weight;
        self.weight += weight;
        Ok(())
    }

    /// Total weight added so far (should reach 1.0 for a full batch).
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Decompose into raw parts: (grads, counts, weighted loss, weight).
    /// Used by workers whose partial weight is deliberately < 1.
    pub fn into_parts(self) -> (Option<Vec<Tensor>>, Vec<f32>, f32, f64) {
        (self.grads, self.counts, self.loss_weighted as f32, self.weight)
    }

    /// Finish: returns (grads, counts, weighted mean loss).
    pub fn finish(self) -> Result<(Vec<Tensor>, Vec<f32>, f32)> {
        ensure!(self.grads.is_some(), "no microbatches accumulated");
        ensure!(
            (self.weight - 1.0).abs() < 1e-4,
            "accumulated weight {} != 1.0 (incomplete batch?)",
            self.weight
        );
        Ok((
            self.grads.unwrap(),
            self.counts,
            self.loss_weighted as f32,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out(val: f32, count: f32, loss: f32) -> GradOutput {
        GradOutput {
            grads: vec![Tensor::f32(vec![2], vec![val, -val])],
            counts: vec![count, 0.0],
            loss,
        }
    }

    #[test]
    fn weighted_mean_of_grads_and_sum_of_counts() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&out(1.0, 3.0, 0.5), 0.5).unwrap();
        acc.add(&out(3.0, 1.0, 0.7), 0.5).unwrap();
        let (grads, counts, loss) = acc.finish().unwrap();
        assert_eq!(grads[0].as_f32().unwrap(), &[2.0, -2.0]);
        assert_eq!(counts, vec![4.0, 0.0]);
        assert!((loss - 0.6).abs() < 1e-6);
    }

    #[test]
    fn incomplete_weight_rejected() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&out(1.0, 1.0, 0.5), 0.25).unwrap();
        assert!(acc.finish().is_err());
    }

    #[test]
    fn empty_rejected() {
        let acc = GradAccumulator::new(2);
        assert!(acc.finish().is_err());
    }
}
