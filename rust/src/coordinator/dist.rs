//! Multi-process distributed training over the socket transport.
//!
//! # Process model
//!
//! One **coordinator** process binds the endpoint and `N` **worker**
//! processes (`cowclip worker --rank R --ranks N`) connect to it. Every
//! process builds the *same* replica state — identical parameter init
//! (same seed), identical [`Batcher`] stream — so no batch data ever
//! crosses the wire. Per step:
//!
//! 1. each rank computes its [`WorkerShard`] contribution for the step's
//!    (locally materialized) batch and sends it as a `Contrib` frame;
//! 2. the coordinator reduces the `N` contributions along the **fixed
//!    binary tree over contiguous rank ranges** ([`TreeReducer`]) — the
//!    same pairing the in-process trainer uses, so the reduced total is
//!    bitwise identical to the sequential path at any rank count;
//! 3. the coordinator broadcasts the reduced total **losslessly**
//!    ([`Compression::None`], bitwise round-trip) before applying, and
//!    every process applies those identical bytes through the same
//!    sharded optimizer — the replicas cannot drift.
//!
//! # Determinism contract
//!
//! With compression off the `Contrib` payload is raw little-endian f32
//! (bitwise round-trip), the tree pairing is fixed by the rank count,
//! and the broadcast total is always lossless: a distributed run is
//! **bitwise identical** to the sequential seed path for every clip
//! mode and any rank count (`rust/tests/dist_parity.rs`).
//!
//! # Compression + error feedback
//!
//! With `u16`/`u8` compression, workers quantize sparse gradient values
//! on the wire and keep a per-rank **error-feedback residual**
//! ([`ErrorFeedback`]): the rounding error of step `t` (computed with
//! the exact [`quant_code`]/[`dequant`] arithmetic the encoder used) is
//! added to the next gradient for the same rows before step `t + 1`
//! encodes, so quantization noise averages out instead of accumulating —
//! the Baidu CTR result this module reproduces. Ids, counts, and dense
//! MLP gradients are never quantized; the broadcast total stays
//! lossless either way.
//!
//! # Fault tolerance
//!
//! Liveness is deadline-based: every socket read/write is armed with
//! [`DistOptions::deadline`]. On top of that, PR 10 makes a rank
//! failure *recoverable* instead of run-fatal:
//!
//! - **Step-atomic commit.** The coordinator applies a step only after
//!   all `N` contributions arrived and the lossless total was reduced.
//!   If a rank dies mid-step, the contributions already read are
//!   *retained* (parameters have not changed, so they stay valid), the
//!   rank is marked dead, and the run enters a bounded **recovery
//!   window** (3× the io deadline) instead of aborting.
//! - **Versioned rejoin.** A reconnecting worker's `Hello` names the
//!   last step it applied plus its [`TrainConfig::fingerprint`]; the
//!   coordinator refuses mismatched configs and replies with its
//!   `committed` step. The worker replays `last+1..=committed` by
//!   local reduction ([`replay_step`] computes *all* ranks' shards from
//!   its own batch stream) — bitwise identical to the socket path
//!   because the broadcast total is a lossless round-trip of exactly
//!   that reduction. Recovery therefore **requires
//!   [`Compression::None`]**: quantized uplinks carry per-rank
//!   error-feedback state that a fresh process cannot rebuild, and both
//!   sides refuse recovery rather than silently fork the replicas.
//! - **Bounded retransmission.** CRC-corrupt frames are healed by the
//!   [`FrameLink`] Nack/Resend exchange within
//!   [`DistOptions::retransmit_budget`]; only then is the peer lost.
//! - **Fault injection.** [`DistOptions::chaos`] arms a deterministic,
//!   seeded [`ChaosSpec`] schedule on the worker side (kill / hang a
//!   rank at step N, corrupt / drop / truncate / delay a frame), so
//!   every recovery path above is exercised by tests
//!   (`rust/tests/fault_parity.rs`) and CI rather than by production
//!   incidents.
//! - **Coordinator snapshots.** [`DistOptions::snapshot_every`] writes
//!   a CCKS checkpoint every K committed steps so a coordinator crash
//!   can restart the whole run from the last committed step.
//!
//! Observable counters: `dist.reconnects`, `dist.retransmits`,
//! `dist.recovered_steps`, `dist.dead_ranks`, `dist.error_fanout_dropped`.

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::allreduce::{Contribution, Reduced, TreeReducer};
use super::chaos::{ChaosConn, ChaosKill, ChaosKind, ChaosListener, ChaosSchedule, ChaosSpec};
use super::engine::Engine;
use super::trainer::{
    apply_contribution, evaluate_with, hypers_for_step, init_store, TrainConfig,
};
use super::transport::{Conn, Endpoint};
use super::worker::WorkerShard;
use crate::data::batcher::{Batch, Batcher};
use crate::data::dataset::Dataset;
use crate::model::params::ParamSet;
use crate::model::store::ParamStore;
use crate::obs::Counter;
use crate::reference::Scratch;
use crate::scaling::rules::HyperSet;
use crate::scaling::warmup::Warmup;
use crate::tensor::GradTensor;
use crate::wire::codec::{
    decode_contribution, decode_error, decode_hello, decode_welcome, dequant,
    encode_contribution, encode_error, encode_hello, encode_welcome, quant_code, quant_scale,
    Compression, ContribStats, Hello, Welcome,
};
use crate::wire::frame::{write_frame, FrameKind, FRAME_HEADER_LEN};
use crate::wire::link::FrameLink;

/// Everything a distributed run needs besides the [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Data-parallel rank count (must equal `TrainConfig::workers`).
    pub ranks: usize,
    /// Where the coordinator listens and workers connect.
    pub endpoint: Endpoint,
    /// Wire compression for worker → coordinator sparse gradients.
    pub compress: Compression,
    /// Accept + per-I/O deadline: a peer silent for longer is lost.
    pub deadline: Duration,
    /// Corrupt receptions healed per logical frame before the peer is
    /// treated as lost (the [`FrameLink`] Nack/Resend budget).
    pub retransmit_budget: u32,
    /// Rejoins tolerated per rank (and worker-side reconnect attempts)
    /// before the run fails. `0` disables recovery entirely: the first
    /// lost rank aborts the run, as before PR 10.
    pub max_restarts: u32,
    /// Deterministic fault-injection schedule, armed on the worker side
    /// (`--chaos`). `None` in production.
    pub chaos: Option<ChaosSpec>,
    /// Write a CCKS snapshot of the coordinator store every K committed
    /// steps (`0` = off). Requires [`DistOptions::snapshot`].
    pub snapshot_every: u64,
    /// Snapshot destination path.
    pub snapshot: Option<PathBuf>,
}

impl DistOptions {
    /// Options with the fault-tolerance knobs at their defaults:
    /// retransmit budget 3, two restarts per rank, no chaos, no
    /// snapshots.
    pub fn new(
        ranks: usize,
        endpoint: Endpoint,
        compress: Compression,
        deadline: Duration,
    ) -> DistOptions {
        DistOptions {
            ranks,
            endpoint,
            compress,
            deadline,
            retransmit_budget: 3,
            max_restarts: 2,
            chaos: None,
            snapshot_every: 0,
            snapshot: None,
        }
    }
}

/// Wire-traffic accounting for one distributed run (coordinator side).
#[derive(Clone, Copy, Debug, Default)]
pub struct DistStats {
    /// Optimizer steps completed.
    pub steps: usize,
    /// Worker → coordinator `Contrib` frames received.
    pub rounds: usize,
    /// Framed bytes the same contributions would occupy uncompressed.
    pub raw_bytes: u64,
    /// Framed bytes actually received (header + encoded payload).
    pub wire_bytes: u64,
    /// Framed bytes broadcast back (`Total` frames, always lossless).
    pub bcast_bytes: u64,
    /// Raw f32 bytes of the sparse sections (ids + counts + grads).
    pub sparse_raw_bytes: u64,
    /// On-wire bytes of the same sparse sections.
    pub sparse_wire_bytes: u64,
    /// Successful rank rejoins accepted by the coordinator.
    pub reconnects: u64,
    /// CRC-corrupt frames healed by Nack/Resend on coordinator links.
    pub retransmits: u64,
    /// Steps that committed despite losing (and recovering) a rank.
    pub recovered_steps: u64,
    /// Rank-loss events (a rank can die, rejoin, and die again).
    pub dead_ranks: u64,
}

impl DistStats {
    /// Compression ratio over the sparse sections — the ≥4× gate of the
    /// wire-compression acceptance criterion (dense MLP gradients are
    /// never quantized, so they are excluded from the ratio).
    pub fn compression_ratio(&self) -> f64 {
        if self.sparse_wire_bytes == 0 {
            1.0
        } else {
            self.sparse_raw_bytes as f64 / self.sparse_wire_bytes as f64
        }
    }
}

/// Result of a coordinated distributed run.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub steps: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss_curve: Vec<f32>,
    pub stats: DistStats,
    pub wall_seconds: f64,
}

/// Hook used by `--spawn-workers`: relaunch the worker process for a
/// dead rank so it can rejoin within the recovery window. Reconnects
/// from still-alive ranks (hung, not crashed) need no hook — they reuse
/// the in-library retry path.
pub trait Respawn {
    fn respawn(&self, rank: usize) -> Result<()>;
}

/// Terminal coordinator verdict carried by an `Error` frame: the worker
/// must *not* reconnect after one of these — the run itself is over.
#[derive(Debug)]
struct CoordinatorAbort(String);

impl fmt::Display for CoordinatorAbort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CoordinatorAbort {}

fn validate(cfg: &TrainConfig, opts: &DistOptions) -> Result<()> {
    ensure!(opts.ranks >= 1, "dist: ranks must be >= 1");
    ensure!(
        cfg.workers == opts.ranks,
        "dist: cfg.workers ({}) must equal the rank count ({})",
        cfg.workers,
        opts.ranks
    );
    ensure!(
        cfg.batch % opts.ranks == 0,
        "dist: batch {} must divide by the rank count {}",
        cfg.batch,
        opts.ranks
    );
    ensure!(
        opts.snapshot_every == 0 || opts.snapshot.is_some(),
        "dist: --snapshot-every needs a snapshot path (--save)"
    );
    Ok(())
}

/// Total optimizer steps of the run — identical arithmetic on every
/// process, cross-checked in the handshake.
fn plan_steps(cfg: &TrainConfig, train: &Dataset) -> Result<u64> {
    let steps_per_epoch = train.n() / cfg.batch;
    ensure!(steps_per_epoch > 0, "dist: batch larger than dataset");
    let total_steps = ((steps_per_epoch as f64) * cfg.epochs).round() as usize;
    ensure!(total_steps > 0, "dist: no steps to run");
    Ok(total_steps as u64)
}

/// Per-rank connection state on the coordinator.
struct RankLinks {
    /// One slot per rank; `None` while the rank is dead.
    links: Vec<Option<FrameLink<ChaosConn>>>,
    /// Connections of lost ranks, parked *open*: a hung-but-alive peer
    /// can still be handed the terminal `Error` fan-out through its old
    /// socket even though the coordinator will never read from it again.
    parked: Vec<Conn>,
    /// Rejoins consumed per rank (bounded by `max_restarts`).
    restarts: Vec<u32>,
}

impl RankLinks {
    fn new(ranks: usize) -> RankLinks {
        RankLinks {
            links: (0..ranks).map(|_| None).collect(),
            parked: Vec::new(),
            restarts: vec![0; ranks],
        }
    }

    fn any_dead(&self) -> bool {
        self.links.iter().any(|slot| slot.is_none())
    }

    fn dead_ranks(&self) -> Vec<usize> {
        self.links
            .iter()
            .enumerate()
            .filter(|(_, slot)| slot.is_none())
            .map(|(rank, _)| rank)
            .collect()
    }
}

/// Run the coordinator: bind, handshake all ranks, drive the step loop
/// (with recovery), then evaluate the final replica. Returns the report
/// and the trained store (bitwise identical to every worker's replica).
pub fn coordinate(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
    opts: &DistOptions,
) -> Result<(DistReport, ParamStore)> {
    coordinate_with(engine, cfg, train, test, opts, None)
}

/// [`coordinate`] with an optional [`Respawn`] hook for dead ranks.
pub fn coordinate_with(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
    opts: &DistOptions,
    respawn: Option<&dyn Respawn>,
) -> Result<(DistReport, ParamStore)> {
    let t0 = Instant::now();
    validate(cfg, opts)?;
    let total_steps = plan_steps(cfg, train)?;
    let fingerprint = cfg.fingerprint();
    let store = init_store(engine, cfg)?;
    let hypers = cfg.scaled_hypers();
    let warmup = Warmup::new(cfg.warmup_steps);

    let listener = ChaosListener::bind(&opts.endpoint)?;
    let mut links = RankLinks::new(opts.ranks);
    for _ in 0..opts.ranks {
        accept_rank(&listener, cfg, opts, total_steps, fingerprint, 0, &mut links, opts.deadline)
            .context("dist: initial handshake")?;
    }

    let mut loss_curve = Vec::with_capacity(total_steps as usize);
    let mut stats = DistStats::default();
    let run = run_steps(
        engine,
        &store,
        cfg,
        hypers,
        warmup,
        total_steps,
        &listener,
        &mut links,
        opts,
        respawn,
        fingerprint,
        &mut loss_curve,
        &mut stats,
    );
    if let Err(err) = run {
        // Push the failure to the surviving ranks (live and parked) so
        // they exit with the cause instead of timing out, then surface
        // it locally.
        broadcast_error(&mut links, opts, &format!("{err:#}"));
        return Err(err);
    }
    for slot in links.links.iter_mut().flatten() {
        let _ = slot.send(FrameKind::Shutdown, &[]);
    }
    for slot in links.links.iter().flatten() {
        slot.stream().conn().shutdown();
    }
    for conn in &links.parked {
        conn.shutdown();
    }

    let (final_auc, final_logloss) = evaluate_with(engine, &store, cfg, test)?;
    let report = DistReport {
        steps: loss_curve.len(),
        final_auc,
        final_logloss,
        train_loss_curve: loss_curve,
        stats,
        wall_seconds: t0.elapsed().as_secs_f64(),
    };
    Ok((report, store))
}

/// Accept one connection and run the versioned (re)join handshake:
/// validate the `Hello` against the run (rank count, batch, seed, step
/// plan, config fingerprint, claimed progress ≤ committed), reply with
/// `Welcome { committed }`, and fill the rank's slot. Returns the rank.
#[allow(clippy::too_many_arguments)]
fn accept_rank(
    listener: &ChaosListener,
    cfg: &TrainConfig,
    opts: &DistOptions,
    total_steps: u64,
    fingerprint: u64,
    committed: u64,
    links: &mut RankLinks,
    window: Duration,
) -> Result<usize> {
    let conn = listener.accept_deadline(window)?;
    conn.conn().set_io_deadline(Some(opts.deadline))?;
    let mut link = FrameLink::new(conn, opts.retransmit_budget);
    let (kind, payload) = link.recv().context("dist: handshake read (io deadline)")?;
    match kind {
        FrameKind::Hello => {}
        FrameKind::Error => bail!("dist: worker failed: {}", decode_error(&payload)?),
        other => bail!("dist: expected Hello, got {other:?}"),
    }
    let hello = decode_hello(&payload)?;
    ensure!(
        hello.ranks as usize == opts.ranks,
        "dist: worker expects {} ranks, coordinator has {}",
        hello.ranks,
        opts.ranks
    );
    ensure!(
        hello.batch == cfg.batch as u64,
        "dist: worker batch {} != coordinator batch {}",
        hello.batch,
        cfg.batch
    );
    ensure!(
        hello.seed == cfg.seed,
        "dist: worker seed {} != coordinator seed {}",
        hello.seed,
        cfg.seed
    );
    ensure!(
        hello.total_steps == total_steps,
        "dist: worker plans {} steps, coordinator {total_steps}",
        hello.total_steps
    );
    ensure!(
        hello.fingerprint == fingerprint,
        "dist: worker config fingerprint {:#018x} != coordinator {fingerprint:#018x} \
         (mismatched training configuration)",
        hello.fingerprint
    );
    ensure!(
        hello.last_step <= committed,
        "dist: rank {} claims step {} but the coordinator committed only {committed}",
        hello.rank,
        hello.last_step
    );
    let rank = hello.rank as usize;
    ensure!(rank < opts.ranks, "dist: rank {rank} out of range for {} ranks", opts.ranks);
    let slot = links.links.get_mut(rank).context("dist: rank slot")?;
    ensure!(slot.is_none(), "dist: duplicate handshake for rank {rank}");
    let welcome = encode_welcome(&Welcome { compress: opts.compress, total_steps, committed });
    link.send(FrameKind::Welcome, &welcome)
        .with_context(|| format!("dist: welcome rank {rank}"))?;
    *slot = Some(link);
    Ok(rank)
}

/// How a rank's turn in the collection loop failed.
enum RankFailure {
    /// The run must abort (the rank reported an application error).
    Fatal(anyhow::Error),
    /// The rank is gone or desynced; recovery may replace it.
    Lost(anyhow::Error),
}

/// Read one `Contrib` frame from a rank. Returns the decoded
/// contribution, its wire stats, and the retransmissions healed while
/// reading it.
fn read_contrib(
    link: &mut FrameLink<ChaosConn>,
    rank: usize,
    step: u64,
    opts: &DistOptions,
) -> std::result::Result<(Contribution, ContribStats, u64), RankFailure> {
    let read = {
        let _rx = crate::obs::span_rank(crate::obs::Phase::WireRx, rank);
        link.recv()
    };
    let healed = link.drain_retransmits();
    let (kind, payload) = match read {
        Ok(frame) => frame,
        Err(err) => {
            return Err(RankFailure::Lost(err.context(format!(
                "dist: rank {rank} missed the io deadline ({:?}) at step {step}",
                opts.deadline
            ))))
        }
    };
    match kind {
        FrameKind::Contrib => {}
        FrameKind::Error => {
            let msg = decode_error(&payload)
                .unwrap_or_else(|_| "malformed error payload".to_string());
            return Err(RankFailure::Fatal(anyhow!(
                "dist: rank {rank} failed at step {step}: {msg}"
            )));
        }
        other => {
            return Err(RankFailure::Lost(anyhow!(
                "dist: rank {rank} sent {other:?} at step {step}, expected Contrib"
            )))
        }
    }
    match decode_contribution(&payload) {
        Ok((c, cstats)) => Ok((c, cstats, healed)),
        Err(err) => Err(RankFailure::Lost(
            err.context(format!("dist: rank {rank} contribution at step {step}")),
        )),
    }
}

/// Mark a rank dead: park its connection (open — see [`RankLinks`]) and
/// decide whether recovery is allowed. Errors when recovery is off,
/// impossible (lossy compression), or exhausted for this rank.
fn mark_lost(
    links: &mut RankLinks,
    rank: usize,
    step: u64,
    opts: &DistOptions,
    cause: anyhow::Error,
    stats: &mut DistStats,
    m_dead: &Counter,
) -> Result<()> {
    stats.dead_ranks += 1;
    m_dead.inc();
    if let Some(link) = links.links.get_mut(rank).and_then(|slot| slot.take()) {
        let (conn, _sched) = link.into_stream().into_parts();
        links.parked.push(conn);
    }
    if opts.max_restarts == 0 {
        return Err(cause.context(format!(
            "dist: rank {rank} lost at step {step}; recovery is disabled (--max-restarts 0)"
        )));
    }
    if opts.compress != Compression::None {
        return Err(cause.context(format!(
            "dist: rank {rank} lost at step {step}; recovery requires --compress none \
             (a rejoining rank cannot rebuild quantized error-feedback residuals bitwise)"
        )));
    }
    let used = links.restarts.get_mut(rank).context("dist: restart slot")?;
    if *used >= opts.max_restarts {
        return Err(cause.context(format!(
            "dist: rank {rank} lost at step {step} after exhausting --max-restarts {}",
            opts.max_restarts
        )));
    }
    *used += 1;
    Ok(())
}

/// Re-admit every dead rank within the recovery window (3× the io
/// deadline: one for the peer to notice the break, one to reconnect and
/// handshake, one slack). Respawns dead ranks first when a hook is
/// present.
#[allow(clippy::too_many_arguments)]
fn recover_dead(
    listener: &ChaosListener,
    cfg: &TrainConfig,
    opts: &DistOptions,
    total_steps: u64,
    fingerprint: u64,
    committed: u64,
    step: u64,
    links: &mut RankLinks,
    respawn: Option<&dyn Respawn>,
    stats: &mut DistStats,
    m_reconnects: &Counter,
) -> Result<()> {
    if let Some(hook) = respawn {
        for rank in links.dead_ranks() {
            hook.respawn(rank)
                .with_context(|| format!("dist: respawning rank {rank} at step {step}"))?;
        }
    }
    let window = opts.deadline.saturating_mul(3);
    let t0 = Instant::now();
    while links.any_dead() {
        let remaining = window.checked_sub(t0.elapsed()).with_context(|| {
            format!(
                "dist: recovery window ({window:?} = 3x the io deadline) expired at step \
                 {step} with ranks {:?} still dead",
                links.dead_ranks()
            )
        })?;
        let rank = accept_rank(
            listener,
            cfg,
            opts,
            total_steps,
            fingerprint,
            committed,
            links,
            remaining,
        )
        .with_context(|| {
            format!("dist: recovering ranks {:?} at step {step}", links.dead_ranks())
        })?;
        stats.reconnects += 1;
        m_reconnects.inc();
        if cfg.verbose {
            println!("dist: rank {rank} rejoined at step {step} (committed {committed})");
        }
    }
    Ok(())
}

/// The coordinator's step loop: collect one `Contrib` per rank (rank
/// order; the tree pairing makes arrival order irrelevant anyway),
/// reduce, broadcast the lossless total, apply — recovering lost ranks
/// between collection passes so a step only ever commits whole.
#[allow(clippy::too_many_arguments)]
fn run_steps(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    hypers: HyperSet,
    warmup: Warmup,
    total_steps: u64,
    listener: &ChaosListener,
    links: &mut RankLinks,
    opts: &DistOptions,
    respawn: Option<&dyn Respawn>,
    fingerprint: u64,
    loss_curve: &mut Vec<f32>,
    stats: &mut DistStats,
) -> Result<()> {
    let header = FRAME_HEADER_LEN as u64;
    let ranks = opts.ranks;
    // Registered once per run, before the step loop: per-rank wire-byte
    // counters are bumped with the exact same quantities as the
    // `DistStats` fields below, so the per-rank totals always sum to the
    // run summary's byte accounting.
    let m_rx: Vec<_> =
        (0..ranks).map(|r| crate::obs::counter(&format!("dist.rank{r}.rx_bytes"))).collect();
    let m_tx: Vec<_> =
        (0..ranks).map(|r| crate::obs::counter(&format!("dist.rank{r}.tx_bytes"))).collect();
    let m_steps = crate::obs::counter("dist.steps");
    let m_raw = crate::obs::counter("dist.raw_bytes");
    let m_wire = crate::obs::counter("dist.wire_bytes");
    let m_bcast = crate::obs::counter("dist.bcast_bytes");
    let m_deadline = crate::obs::counter("dist.deadline_errors");
    let m_ratio = crate::obs::gauge("dist.compression_ratio");
    let m_reconnects = crate::obs::counter("dist.reconnects");
    let m_retrans = crate::obs::counter("dist.retransmits");
    let m_recovered = crate::obs::counter("dist.recovered_steps");
    let m_dead = crate::obs::counter("dist.dead_ranks");
    for step in 1..=total_steps {
        let committed = step - 1;
        let hv = hypers_for_step(hypers, warmup, step as usize);
        let mut reducer = TreeReducer::new(ranks);
        let mut have = vec![false; ranks];
        let mut recovered = false;
        // Collection passes: read every missing contribution; on rank
        // loss, recover and re-read only the ranks that never landed
        // (already-read contributions stay valid — no state changed).
        loop {
            if links.any_dead() {
                recover_dead(
                    listener,
                    cfg,
                    opts,
                    total_steps,
                    fingerprint,
                    committed,
                    step,
                    links,
                    respawn,
                    stats,
                    &m_reconnects,
                )?;
                recovered = true;
            }
            let mut lost = false;
            for rank in 0..ranks {
                if have.get(rank).copied().unwrap_or(true) {
                    continue;
                }
                let outcome = match links.links.get_mut(rank).and_then(|slot| slot.as_mut()) {
                    Some(link) => read_contrib(link, rank, step, opts),
                    None => {
                        lost = true;
                        continue;
                    }
                };
                match outcome {
                    Ok((c, cstats, healed)) => {
                        stats.rounds += 1;
                        stats.raw_bytes += header + cstats.raw_bytes;
                        stats.wire_bytes += header + cstats.wire_bytes;
                        stats.sparse_raw_bytes += cstats.sparse_raw;
                        stats.sparse_wire_bytes += cstats.sparse_wire;
                        stats.retransmits += healed;
                        m_raw.add(header + cstats.raw_bytes);
                        m_wire.add(header + cstats.wire_bytes);
                        if healed > 0 {
                            m_retrans.add(healed);
                        }
                        if let Some(ctr) = m_rx.get(rank) {
                            ctr.add(header + cstats.wire_bytes);
                        }
                        reducer.push(rank, c)?;
                        if let Some(flag) = have.get_mut(rank) {
                            *flag = true;
                        }
                    }
                    Err(RankFailure::Fatal(err)) => return Err(err),
                    Err(RankFailure::Lost(cause)) => {
                        m_deadline.inc();
                        mark_lost(links, rank, step, opts, cause, stats, &m_dead)?;
                        lost = true;
                    }
                }
            }
            if !lost && !links.any_dead() {
                break;
            }
        }
        let (total, _) = reducer.finish()?;
        // Broadcast the reduced total losslessly *before* applying:
        // every replica then applies identical bytes, so the stores
        // stay bitwise in sync even with lossy uplink compression.
        let (payload, _) = encode_contribution(&total, Compression::None)?;
        let mut sent: u64 = 0;
        for rank in 0..ranks {
            let pushed = match links.links.get_mut(rank).and_then(|slot| slot.as_mut()) {
                Some(link) => {
                    let _tx = crate::obs::span_rank(crate::obs::Phase::WireTx, rank);
                    link.send(FrameKind::Total, &payload)
                }
                None => continue,
            };
            match pushed {
                Ok(()) => {
                    if let Some(ctr) = m_tx.get(rank) {
                        ctr.add(header + payload.len() as u64);
                    }
                    sent += 1;
                }
                Err(cause) => {
                    // A rank lost on broadcast is not re-awaited this
                    // step: the commit proceeds (all contributions are
                    // in) and the rank replays the step itself when it
                    // rejoins.
                    mark_lost(
                        links,
                        rank,
                        step,
                        opts,
                        cause.context(format!(
                            "dist: broadcast total to rank {rank} at step {step}"
                        )),
                        stats,
                        &m_dead,
                    )?;
                    recovered = true;
                }
            }
        }
        stats.bcast_bytes += (header + payload.len() as u64) * sent;
        m_bcast.add((header + payload.len() as u64) * sent);
        let loss = apply_contribution(engine, store, cfg, &hv, Reduced::Whole(total))?;
        loss_curve.push(loss);
        stats.steps = step as usize;
        m_steps.inc();
        m_ratio.set(stats.compression_ratio());
        if recovered {
            stats.recovered_steps += 1;
            m_recovered.inc();
        }
        if opts.snapshot_every > 0 && step % opts.snapshot_every == 0 {
            if let Some(path) = &opts.snapshot {
                store
                    .save_checkpoint(path, step)
                    .with_context(|| format!("dist: snapshot at step {step}"))?;
            }
        }
    }
    // A rank lost on the final broadcast still deserves a clean exit:
    // let it rejoin, replay to the end locally, and take the Shutdown.
    if links.any_dead() {
        recover_dead(
            listener,
            cfg,
            opts,
            total_steps,
            fingerprint,
            total_steps,
            total_steps,
            links,
            respawn,
            stats,
            &m_reconnects,
        )?;
    }
    Ok(())
}

/// Best-effort `Error` fan-out on coordinator failure — to live links
/// *and* parked connections of lost ranks — with a per-rank write
/// deadline derived from the run's io deadline. Writes that fail are
/// counted on `dist.error_fanout_dropped`.
fn broadcast_error(links: &mut RankLinks, opts: &DistOptions, msg: &str) {
    let payload = encode_error(msg);
    let per_rank =
        (opts.deadline / 8).clamp(Duration::from_millis(50), Duration::from_secs(1));
    let m_dropped = crate::obs::counter("dist.error_fanout_dropped");
    for slot in links.links.iter_mut().flatten() {
        let _ = slot.stream().conn().set_io_deadline(Some(per_rank));
        if slot.send(FrameKind::Error, &payload).is_err() {
            m_dropped.inc();
        }
        slot.stream().conn().shutdown();
    }
    for conn in links.parked.iter_mut() {
        let _ = conn.set_io_deadline(Some(per_rank));
        if write_frame(conn, FrameKind::Error, &payload).is_err() {
            m_dropped.inc();
        }
        conn.shutdown();
    }
}

/// One worker's full replica state, built once and carried across
/// reconnects: the same init and the same forward-only batch stream as
/// every peer.
struct WorkerState<'a> {
    store: ParamStore,
    hypers: HyperSet,
    warmup: Warmup,
    batcher: Batcher<'a>,
    scratch: Scratch,
    ef: ErrorFeedback,
    /// Last step whose total this replica applied.
    last_completed: u64,
    /// Highest step a batch has been drawn for (the batcher is
    /// forward-only, so a batch is drawn at most once per step).
    produced: u64,
    /// The batch for step `produced`, kept until that step commits so a
    /// failed step can be retried on a fresh connection.
    cur: Option<Batch>,
}

impl WorkerState<'_> {
    /// Materialize the batch for `step`, drawing from the batcher only
    /// if this step never had one (a retry reuses the kept batch).
    fn draw(&mut self, step: u64) {
        if self.produced < step {
            self.cur = Some(self.batcher.next_batch());
            self.produced = step;
        }
    }
}

/// Run one worker rank end to end: connect (with retry, covering the
/// coordinator-bind race), handshake, and drive the step loop —
/// reconnecting and replaying through up to `max_restarts` connection
/// failures.
pub fn worker(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    rank: usize,
    opts: &DistOptions,
) -> Result<()> {
    validate(cfg, opts)?;
    ensure!(rank < opts.ranks, "dist: rank {rank} out of range for {} ranks", opts.ranks);
    let total_steps = plan_steps(cfg, train)?;
    let fingerprint = cfg.fingerprint();
    let mut st = WorkerState {
        store: init_store(engine, cfg)?,
        hypers: cfg.scaled_hypers(),
        warmup: Warmup::new(cfg.warmup_steps),
        batcher: Batcher::new(train, cfg.batch, cfg.seed ^ 0x5eed),
        scratch: Scratch::new(),
        ef: ErrorFeedback::default(),
        last_completed: 0,
        produced: 0,
        cur: None,
    };
    // The chaos schedule outlives any one connection: events not yet
    // fired survive a reconnect (a respawned *process* starts clean —
    // the supervisor strips `--chaos` when relaunching).
    let mut chaos = ChaosSchedule::for_rank(opts.chaos.as_ref(), rank);
    let mut reconnects: u32 = 0;
    let m_reconnects = crate::obs::counter("dist.reconnects");
    loop {
        let conn = opts.endpoint.connect_retry(opts.deadline)?;
        conn.set_io_deadline(Some(opts.deadline))?;
        let sched = std::mem::replace(&mut chaos, ChaosSchedule::inert());
        let mut link = FrameLink::new(ChaosConn::new(conn, sched), opts.retransmit_budget);
        let res = worker_session(engine, cfg, total_steps, fingerprint, rank, opts, &mut link, &mut st);
        let (conn, sched) = link.into_stream().into_parts();
        conn.shutdown();
        chaos = sched;
        match res {
            Ok(()) => return Ok(()),
            Err(err) => {
                // Injected kills and terminal coordinator verdicts are
                // final; everything else is a connection-level failure
                // the rejoin handshake can heal.
                if err.downcast_ref::<ChaosKill>().is_some()
                    || err.downcast_ref::<CoordinatorAbort>().is_some()
                {
                    return Err(err);
                }
                if reconnects >= opts.max_restarts {
                    return Err(err.context(format!(
                        "dist: rank {rank} gave up after {reconnects} reconnect attempts \
                         (--max-restarts {})",
                        opts.max_restarts
                    )));
                }
                reconnects += 1;
                m_reconnects.inc();
                if cfg.verbose {
                    println!("dist: rank {rank} reconnecting after: {err:#}");
                }
            }
        }
    }
}

/// One connection's worth of worker protocol: rejoin handshake, local
/// catch-up replay, then the compute/send/apply step loop until the
/// final Shutdown.
#[allow(clippy::too_many_arguments)]
fn worker_session(
    engine: &Engine,
    cfg: &TrainConfig,
    total_steps: u64,
    fingerprint: u64,
    rank: usize,
    opts: &DistOptions,
    link: &mut FrameLink<ChaosConn>,
    st: &mut WorkerState<'_>,
) -> Result<()> {
    let hello = Hello {
        rank: rank as u32,
        ranks: opts.ranks as u32,
        batch: cfg.batch as u64,
        seed: cfg.seed,
        total_steps,
        last_step: st.last_completed,
        fingerprint,
    };
    link.send(FrameKind::Hello, &encode_hello(&hello))
        .with_context(|| format!("dist: rank {rank} hello"))?;
    let (kind, payload) = link
        .recv()
        .with_context(|| format!("dist: rank {rank} waiting for Welcome (io deadline)"))?;
    let welcome = match kind {
        FrameKind::Welcome => decode_welcome(&payload)?,
        FrameKind::Error => {
            let msg = decode_error(&payload)
                .unwrap_or_else(|_| "malformed error payload".to_string());
            return Err(anyhow::Error::new(CoordinatorAbort(format!(
                "dist: coordinator rejected rank {rank}: {msg}"
            ))));
        }
        other => bail!("dist: expected Welcome, got {other:?}"),
    };
    if welcome.total_steps != total_steps {
        return Err(anyhow::Error::new(CoordinatorAbort(format!(
            "dist: coordinator plans {} steps, rank {rank} {total_steps}",
            welcome.total_steps
        ))));
    }
    ensure!(
        welcome.committed <= total_steps,
        "dist: coordinator claims committed step {} of {total_steps}",
        welcome.committed
    );
    ensure!(
        st.last_completed <= welcome.committed,
        "dist: rank {rank} is ahead of the coordinator ({} > {})",
        st.last_completed,
        welcome.committed
    );
    if welcome.committed > st.last_completed {
        ensure!(
            welcome.compress == Compression::None,
            "dist: rank {rank} cannot replay steps {}..={} under {:?} compression; \
             recovery requires --compress none",
            st.last_completed + 1,
            welcome.committed,
            welcome.compress
        );
    }
    let compress = welcome.compress;

    // Catch up to the coordinator by local replay: compute *all* ranks'
    // shards from our own batch stream and reduce them through the same
    // fixed tree. With lossless totals (enforced above) this is bitwise
    // the same arithmetic the socket path would have fed us.
    while st.last_completed < welcome.committed {
        let step = st.last_completed + 1;
        st.draw(step);
        replay_step(engine, cfg, st, step)
            .with_context(|| format!("dist: rank {rank} replaying step {step}"))?;
        st.last_completed = step;
        st.cur = None;
    }

    let m_stalls = crate::obs::counter("dist.stalls");
    let m_ef = crate::obs::gauge("dist.ef_residual");
    let m_retrans = crate::obs::counter("dist.retransmits");
    while st.last_completed < total_steps {
        let step = st.last_completed + 1;
        for ev in link.stream_mut().schedule_mut().take_process(step) {
            match ev.kind {
                ChaosKind::Kill => {
                    return Err(anyhow::Error::new(ChaosKill { rank, step }))
                }
                ChaosKind::Hang => std::thread::sleep(Duration::from_millis(ev.ms)),
                _ => {}
            }
        }
        link.stream_mut().set_step(step);
        st.draw(step);
        let hv = hypers_for_step(st.hypers, st.warmup, step as usize);
        let mut c = {
            let WorkerState { store, cur, scratch, .. } = &mut *st;
            let batch = cur.as_ref().context("dist: step batch missing")?;
            let guard = store.read();
            let params: &ParamSet = &guard;
            WorkerShard::new(rank, opts.ranks).compute(engine, params, batch, scratch)?
        };
        // Fold last step's rounding error into the touched rows, encode,
        // then remember this step's rounding error for the next fold.
        st.ef.fold_in(&mut c.grads);
        let (payload, _) = encode_contribution(&c, compress)?;
        st.ef.absorb(&c.grads, compress);
        m_ef.set(st.ef.residual_l1());
        {
            let _tx = crate::obs::span_rank(crate::obs::Phase::WireTx, rank);
            link.send(FrameKind::Contrib, &payload)
                .with_context(|| format!("dist: rank {rank} send contribution at step {step}"))?;
        }

        let read = {
            let _rx = crate::obs::span_rank(crate::obs::Phase::WireRx, rank);
            link.recv()
        };
        let healed = link.drain_retransmits();
        if healed > 0 {
            m_retrans.add(healed);
        }
        if read.is_err() {
            m_stalls.inc();
        }
        let (kind, payload) = read.with_context(|| {
            format!(
                "dist: rank {rank} waiting for the reduced total at step {step} \
                 (io deadline {:?})",
                opts.deadline
            )
        })?;
        let total = match kind {
            FrameKind::Total => {
                decode_contribution(&payload)
                    .with_context(|| format!("dist: total at step {step}"))?
                    .0
            }
            FrameKind::Error => {
                let msg = decode_error(&payload)
                    .unwrap_or_else(|_| "malformed error payload".to_string());
                return Err(anyhow::Error::new(CoordinatorAbort(format!(
                    "dist: coordinator aborted at step {step}: {msg}"
                ))));
            }
            other => bail!("dist: expected Total, got {other:?}"),
        };
        apply_contribution(engine, &st.store, cfg, &hv, Reduced::Whole(total))?;
        st.last_completed = step;
        st.cur = None;
    }

    let (kind, payload) = link
        .recv()
        .with_context(|| format!("dist: rank {rank} waiting for Shutdown (io deadline)"))?;
    match kind {
        FrameKind::Shutdown => Ok(()),
        FrameKind::Error => {
            let msg = decode_error(&payload)
                .unwrap_or_else(|_| "malformed error payload".to_string());
            Err(anyhow::Error::new(CoordinatorAbort(format!(
                "dist: coordinator failed after the last step: {msg}"
            ))))
        }
        other => bail!("dist: expected Shutdown, got {other:?}"),
    }
}

/// Replay one committed step entirely locally: compute every rank's
/// shard from this replica's batch, reduce through the fixed tree, and
/// apply the whole total — the exact arithmetic whose lossless
/// broadcast the socket path would have delivered.
fn replay_step(engine: &Engine, cfg: &TrainConfig, st: &mut WorkerState<'_>, step: u64) -> Result<()> {
    let hv = hypers_for_step(st.hypers, st.warmup, step as usize);
    let WorkerState { store, cur, scratch, .. } = &mut *st;
    let batch = cur.as_ref().context("dist: replay batch missing")?;
    let mut reducer = TreeReducer::new(cfg.workers);
    {
        let guard = store.read();
        let params: &ParamSet = &guard;
        for r in 0..cfg.workers {
            let c = WorkerShard::new(r, cfg.workers).compute(engine, params, batch, scratch)?;
            reducer.push(r, c)?;
        }
    }
    let (total, _) = reducer.finish()?;
    apply_contribution(engine, store, cfg, &hv, Reduced::Whole(total))?;
    Ok(())
}

/// Per-rank error-feedback residuals: the quantization rounding error of
/// each sparse gradient row sent, keyed by row id, folded into the next
/// gradient that touches the row.
///
/// The residual is computed with the exact [`quant_scale`] /
/// [`quant_code`] / [`dequant`] arithmetic the encoder used on the same
/// values, so what the map holds is bit-for-bit `sent - received` — the
/// compensation term of Baidu's low-precision CTR training scheme.
/// Rows untouched by a later step keep their residual pending until the
/// row is touched again. With [`Compression::None`] the residual is
/// identically zero and the maps stay empty.
#[derive(Default)]
struct ErrorFeedback {
    /// One map per gradient slot (same order as `Contribution::grads`).
    residuals: Vec<BTreeMap<u32, Vec<f32>>>,
}

impl ErrorFeedback {
    fn ensure_slots(&mut self, n: usize) {
        while self.residuals.len() < n {
            self.residuals.push(BTreeMap::new());
        }
    }

    /// Add pending residuals into the rows this gradient touches. Only
    /// stored rows change, so the gradient's id structure (and the
    /// shared-ids wire optimization) is preserved.
    fn fold_in(&mut self, grads: &mut [GradTensor]) {
        self.ensure_slots(grads.len());
        for (g, map) in grads.iter_mut().zip(self.residuals.iter_mut()) {
            if map.is_empty() {
                continue;
            }
            if let GradTensor::Sparse(s) = g {
                let d = s.d();
                let (ids, vals) = s.ids_vals_mut();
                for (k, id) in ids.iter().enumerate() {
                    if let Some(row) = map.remove(id) {
                        for (v, r) in vals.iter_mut().skip(k * d).take(d).zip(&row) {
                            *v += *r;
                        }
                    }
                }
            }
        }
    }

    /// Total pending-residual L1 mass — the `dist.ef_residual` gauge.
    /// Maps are `BTreeMap`s, so the accumulation order is deterministic.
    fn residual_l1(&self) -> f64 {
        let mut total = 0.0f64;
        for map in &self.residuals {
            for row in map.values() {
                for &v in row {
                    total += v.abs() as f64;
                }
            }
        }
        total
    }

    /// Record the rounding error the wire just introduced for every
    /// sparse row of `grads` (which must be the exact values that were
    /// encoded). No-op for [`Compression::None`].
    fn absorb(&mut self, grads: &[GradTensor], compress: Compression) {
        let Some(q) = compress.levels() else {
            return;
        };
        self.ensure_slots(grads.len());
        for (g, map) in grads.iter().zip(self.residuals.iter_mut()) {
            if let GradTensor::Sparse(s) = g {
                let d = s.d();
                let scale = quant_scale(s.vals(), q);
                for (k, &id) in s.ids().iter().enumerate() {
                    let row = &s.vals()[k * d..(k + 1) * d];
                    let mut res = Vec::with_capacity(d);
                    let mut nonzero = false;
                    for &v in row {
                        let e = v - dequant(quant_code(v, scale, q), scale);
                        nonzero |= e != 0.0;
                        res.push(e);
                    }
                    if nonzero {
                        map.insert(id, res);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SparseRows;

    fn sparse_grad(ids: &[u32], vals: &[f32], d: usize) -> GradTensor {
        GradTensor::Sparse(SparseRows::new(100, d, ids.to_vec(), vals.to_vec()))
    }

    #[test]
    fn error_feedback_compensates_quantization_exactly() {
        let compress = Compression::U8;
        let q = compress.levels().unwrap();
        let vals = [0.5f32, -0.31, 0.007, 0.2, -0.9, 0.113];
        let mut grads = vec![sparse_grad(&[2, 7, 11], &vals, 2)];
        let mut ef = ErrorFeedback::default();

        // Step 1: nothing pending; absorb records the rounding error.
        ef.fold_in(&mut grads);
        ef.absorb(&grads, compress);
        let scale = quant_scale(&vals, q);
        let wire: Vec<f32> =
            vals.iter().map(|&v| dequant(quant_code(v, scale, q), scale)).collect();

        // Step 2 touches the same rows: the folded gradient must be the
        // new values plus exactly (sent - received) from step 1.
        let vals2 = [0.1f32, 0.1, 0.1, 0.1, 0.1, 0.1];
        let mut grads2 = vec![sparse_grad(&[2, 7, 11], &vals2, 2)];
        ef.fold_in(&mut grads2);
        let GradTensor::Sparse(s) = &grads2[0] else { panic!("sparse expected") };
        for ((&got, &v2), (&v1, &w)) in
            s.vals().iter().zip(&vals2).zip(vals.iter().zip(&wire))
        {
            let want = v2 + (v1 - w);
            assert_eq!(got.to_bits(), want.to_bits(), "residual must be bit-exact");
        }
    }

    #[test]
    fn error_feedback_keeps_untouched_rows_pending() {
        let compress = Compression::U8;
        let mut grads = vec![sparse_grad(&[2, 7], &[0.5, -0.31], 1)];
        let mut ef = ErrorFeedback::default();
        ef.fold_in(&mut grads);
        ef.absorb(&grads, compress);

        // Next step touches only row 7: row 2's residual stays pending.
        let mut grads2 = vec![sparse_grad(&[7], &[0.25], 1)];
        ef.fold_in(&mut grads2);
        assert!(ef.residuals[0].contains_key(&2), "row 2 residual must stay pending");
        assert!(!ef.residuals[0].contains_key(&7), "row 7 residual was consumed");

        // And a later step touching row 2 consumes it.
        let mut grads3 = vec![sparse_grad(&[2], &[0.0], 1)];
        ef.fold_in(&mut grads3);
        assert!(ef.residuals[0].is_empty());
        let GradTensor::Sparse(s) = &grads3[0] else { panic!("sparse expected") };
        assert!(s.vals()[0] != 0.0, "pending residual folded into a zero gradient");
    }

    #[test]
    fn error_feedback_is_inert_without_compression() {
        let mut grads = vec![sparse_grad(&[1, 2], &[0.5, -0.5], 1)];
        let mut ef = ErrorFeedback::default();
        ef.fold_in(&mut grads);
        ef.absorb(&grads, Compression::None);
        assert!(ef.residuals[0].is_empty());
        let GradTensor::Sparse(s) = &grads[0] else { panic!("sparse expected") };
        assert_eq!(s.vals(), &[0.5, -0.5]);
    }

    #[test]
    fn dist_options_validate_rejects_mismatches() {
        use crate::scaling::rules::{HyperSet, ScalingRule};
        let cfg = TrainConfig {
            batch: 128,
            base_batch: 128,
            base_hypers: HyperSet {
                lr_dense: 1e-3,
                lr_embed: 1e-3,
                l2_embed: 0.0,
                clip_r: 1.0,
                clip_zeta: 1e-4,
                clip_t: 0.5,
            },
            rule: ScalingRule::NoScale,
            epochs: 1.0,
            workers: 2,
            threads: 1,
            param_shards: 1,
            warmup_steps: 0,
            init_sigma: 0.01,
            seed: 1,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mk = |ranks| {
            DistOptions::new(
                ranks,
                Endpoint::Unix(std::path::PathBuf::from("/tmp/x.sock")),
                Compression::None,
                Duration::from_secs(1),
            )
        };
        assert!(validate(&cfg, &mk(2)).is_ok());
        assert!(validate(&cfg, &mk(0)).is_err(), "zero ranks");
        assert!(validate(&cfg, &mk(3)).is_err(), "workers != ranks");
        let mut snap = mk(2);
        snap.snapshot_every = 4;
        assert!(validate(&cfg, &snap).is_err(), "snapshot-every without a path");
        snap.snapshot = Some(std::path::PathBuf::from("/tmp/x.ckpt"));
        assert!(validate(&cfg, &snap).is_ok());
    }

    #[test]
    fn fingerprint_distinguishes_configs_and_ignores_shape() {
        let base = TrainConfig {
            batch: 128,
            base_batch: 128,
            base_hypers: HyperSet {
                lr_dense: 1e-3,
                lr_embed: 1e-3,
                l2_embed: 0.0,
                clip_r: 1.0,
                clip_zeta: 1e-4,
                clip_t: 0.5,
            },
            rule: crate::scaling::rules::ScalingRule::CowClip,
            epochs: 1.0,
            workers: 2,
            threads: 1,
            param_shards: 1,
            warmup_steps: 0,
            init_sigma: 0.01,
            seed: 1,
            eval_every_epochs: 0,
            verbose: false,
        };
        let fp = base.fingerprint();
        let mut other = base.clone();
        other.seed = 2;
        assert_ne!(fp, other.fingerprint(), "seed must change the fingerprint");
        let mut lr = base.clone();
        lr.base_hypers.lr_embed = 2e-3;
        assert_ne!(fp, lr.fingerprint(), "hypers must change the fingerprint");
        // Execution-shape knobs are parity-inert and excluded.
        let mut shape = base.clone();
        shape.threads = 8;
        shape.param_shards = 4;
        shape.verbose = true;
        assert_eq!(fp, shape.fingerprint(), "shape knobs must not change the fingerprint");
    }
}
