//! Multi-process distributed training over the socket transport.
//!
//! # Process model
//!
//! One **coordinator** process binds the endpoint and `N` **worker**
//! processes (`cowclip worker --rank R --ranks N`) connect to it. Every
//! process builds the *same* replica state — identical parameter init
//! (same seed), identical [`Batcher`] stream — so no batch data ever
//! crosses the wire. Per step:
//!
//! 1. each rank computes its [`WorkerShard`] contribution for the step's
//!    (locally materialized) batch and sends it as a `Contrib` frame;
//! 2. the coordinator reduces the `N` contributions along the **fixed
//!    binary tree over contiguous rank ranges** ([`TreeReducer`]) — the
//!    same pairing the in-process trainer uses, so the reduced total is
//!    bitwise identical to the sequential path at any rank count;
//! 3. the coordinator broadcasts the reduced total **losslessly**
//!    ([`Compression::None`], bitwise round-trip) before applying, and
//!    every process applies those identical bytes through the same
//!    sharded optimizer — the replicas cannot drift.
//!
//! # Determinism contract
//!
//! With compression off the `Contrib` payload is raw little-endian f32
//! (bitwise round-trip), the tree pairing is fixed by the rank count,
//! and the broadcast total is always lossless: a distributed run is
//! **bitwise identical** to the sequential seed path for every clip
//! mode and any rank count (`rust/tests/dist_parity.rs`).
//!
//! # Compression + error feedback
//!
//! With `u16`/`u8` compression, workers quantize sparse gradient values
//! on the wire and keep a per-rank **error-feedback residual**
//! ([`ErrorFeedback`]): the rounding error of step `t` (computed with
//! the exact [`quant_code`]/[`dequant`] arithmetic the encoder used) is
//! added to the next gradient for the same rows before step `t + 1`
//! encodes, so quantization noise averages out instead of accumulating —
//! the Baidu CTR result this module reproduces. Ids, counts, and dense
//! MLP gradients are never quantized; the broadcast total stays
//! lossless either way.
//!
//! Liveness is deadline-based: every socket read/write is armed with
//! [`DistOptions::deadline`], so a killed or hung rank surfaces as an
//! error naming the deadline and the coordinator pushes an `Error`
//! frame to the surviving ranks before shutting down.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::allreduce::{Reduced, TreeReducer};
use super::engine::Engine;
use super::trainer::{
    apply_contribution, evaluate_with, hypers_for_step, init_store, TrainConfig,
};
use super::transport::{Conn, Endpoint};
use super::worker::WorkerShard;
use crate::data::batcher::Batcher;
use crate::data::dataset::Dataset;
use crate::model::params::ParamSet;
use crate::model::store::ParamStore;
use crate::reference::Scratch;
use crate::scaling::rules::HyperSet;
use crate::scaling::warmup::Warmup;
use crate::tensor::GradTensor;
use crate::wire::codec::{
    decode_contribution, decode_error, decode_hello, decode_welcome, dequant,
    encode_contribution, encode_error, encode_hello, encode_welcome, quant_code, quant_scale,
    Compression, Hello, Welcome,
};
use crate::wire::frame::{read_frame, write_frame, FrameKind, FRAME_HEADER_LEN};

/// Everything a distributed run needs besides the [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Data-parallel rank count (must equal `TrainConfig::workers`).
    pub ranks: usize,
    /// Where the coordinator listens and workers connect.
    pub endpoint: Endpoint,
    /// Wire compression for worker → coordinator sparse gradients.
    pub compress: Compression,
    /// Accept + per-I/O deadline: a peer silent for longer errors out.
    pub deadline: Duration,
}

/// Wire-traffic accounting for one distributed run (coordinator side).
#[derive(Clone, Copy, Debug, Default)]
pub struct DistStats {
    /// Optimizer steps completed.
    pub steps: usize,
    /// Worker → coordinator `Contrib` frames received.
    pub rounds: usize,
    /// Framed bytes the same contributions would occupy uncompressed.
    pub raw_bytes: u64,
    /// Framed bytes actually received (header + encoded payload).
    pub wire_bytes: u64,
    /// Framed bytes broadcast back (`Total` frames, always lossless).
    pub bcast_bytes: u64,
    /// Raw f32 bytes of the sparse sections (ids + counts + grads).
    pub sparse_raw_bytes: u64,
    /// On-wire bytes of the same sparse sections.
    pub sparse_wire_bytes: u64,
}

impl DistStats {
    /// Compression ratio over the sparse sections — the ≥4× gate of the
    /// wire-compression acceptance criterion (dense MLP gradients are
    /// never quantized, so they are excluded from the ratio).
    pub fn compression_ratio(&self) -> f64 {
        if self.sparse_wire_bytes == 0 {
            1.0
        } else {
            self.sparse_raw_bytes as f64 / self.sparse_wire_bytes as f64
        }
    }
}

/// Result of a coordinated distributed run.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub steps: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss_curve: Vec<f32>,
    pub stats: DistStats,
    pub wall_seconds: f64,
}

fn validate(cfg: &TrainConfig, opts: &DistOptions) -> Result<()> {
    ensure!(opts.ranks >= 1, "dist: ranks must be >= 1");
    ensure!(
        cfg.workers == opts.ranks,
        "dist: cfg.workers ({}) must equal the rank count ({})",
        cfg.workers,
        opts.ranks
    );
    ensure!(
        cfg.batch % opts.ranks == 0,
        "dist: batch {} must divide by the rank count {}",
        cfg.batch,
        opts.ranks
    );
    Ok(())
}

/// Total optimizer steps of the run — identical arithmetic on every
/// process, cross-checked in the handshake.
fn plan_steps(cfg: &TrainConfig, train: &Dataset) -> Result<u64> {
    let steps_per_epoch = train.n() / cfg.batch;
    ensure!(steps_per_epoch > 0, "dist: batch larger than dataset");
    let total_steps = ((steps_per_epoch as f64) * cfg.epochs).round() as usize;
    ensure!(total_steps > 0, "dist: no steps to run");
    Ok(total_steps as u64)
}

/// Run the coordinator: bind, handshake all ranks, drive the step loop,
/// then evaluate the final replica. Returns the report and the trained
/// store (bitwise identical to every worker's replica).
pub fn coordinate(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
    opts: &DistOptions,
) -> Result<(DistReport, ParamStore)> {
    let t0 = Instant::now();
    validate(cfg, opts)?;
    let total_steps = plan_steps(cfg, train)?;
    let store = init_store(engine, cfg)?;
    let hypers = cfg.scaled_hypers();
    let warmup = Warmup::new(cfg.warmup_steps);

    let listener = opts.endpoint.bind()?;
    let mut slots: Vec<Option<Conn>> = (0..opts.ranks).map(|_| None).collect();
    for _ in 0..opts.ranks {
        let mut conn = listener.accept_deadline(opts.deadline)?;
        conn.set_io_deadline(Some(opts.deadline))?;
        let (kind, payload) =
            read_frame(&mut conn).context("dist: handshake read (io deadline)")?;
        match kind {
            FrameKind::Hello => {}
            FrameKind::Error => bail!("dist: worker failed: {}", decode_error(&payload)?),
            other => bail!("dist: expected Hello, got {other:?}"),
        }
        let hello = decode_hello(&payload)?;
        ensure!(
            hello.ranks as usize == opts.ranks,
            "dist: worker expects {} ranks, coordinator has {}",
            hello.ranks,
            opts.ranks
        );
        ensure!(
            hello.batch == cfg.batch as u64,
            "dist: worker batch {} != coordinator batch {}",
            hello.batch,
            cfg.batch
        );
        ensure!(
            hello.seed == cfg.seed,
            "dist: worker seed {} != coordinator seed {}",
            hello.seed,
            cfg.seed
        );
        ensure!(
            hello.total_steps == total_steps,
            "dist: worker plans {} steps, coordinator {total_steps}",
            hello.total_steps
        );
        let rank = hello.rank as usize;
        ensure!(rank < opts.ranks, "dist: rank {rank} out of range for {} ranks", opts.ranks);
        let slot = slots.get_mut(rank).context("dist: rank slot")?;
        ensure!(slot.is_none(), "dist: duplicate handshake for rank {rank}");
        let welcome = encode_welcome(&Welcome { compress: opts.compress, total_steps });
        write_frame(&mut conn, FrameKind::Welcome, &welcome)
            .with_context(|| format!("dist: welcome rank {rank}"))?;
        *slot = Some(conn);
    }
    let mut conns: Vec<Conn> = Vec::with_capacity(opts.ranks);
    for (rank, slot) in slots.into_iter().enumerate() {
        conns.push(slot.with_context(|| format!("dist: missing handshake for rank {rank}"))?);
    }

    let mut loss_curve = Vec::with_capacity(total_steps as usize);
    let mut stats = DistStats::default();
    let run = run_steps(
        engine,
        &store,
        cfg,
        hypers,
        warmup,
        total_steps,
        &mut conns,
        opts,
        &mut loss_curve,
        &mut stats,
    );
    if let Err(err) = run {
        // Push the failure to the surviving ranks so they exit with the
        // cause instead of timing out, then surface it locally.
        broadcast_error(&mut conns, &format!("{err:#}"));
        return Err(err);
    }
    for conn in conns.iter_mut() {
        let _ = write_frame(conn, FrameKind::Shutdown, &[]);
    }
    for conn in &conns {
        conn.shutdown();
    }

    let (final_auc, final_logloss) = evaluate_with(engine, &store, cfg, test)?;
    let report = DistReport {
        steps: loss_curve.len(),
        final_auc,
        final_logloss,
        train_loss_curve: loss_curve,
        stats,
        wall_seconds: t0.elapsed().as_secs_f64(),
    };
    Ok((report, store))
}

/// The coordinator's step loop: collect one `Contrib` per rank (rank
/// order; the tree pairing makes arrival order irrelevant anyway),
/// reduce, broadcast the lossless total, apply.
#[allow(clippy::too_many_arguments)]
fn run_steps(
    engine: &Engine,
    store: &ParamStore,
    cfg: &TrainConfig,
    hypers: HyperSet,
    warmup: Warmup,
    total_steps: u64,
    conns: &mut [Conn],
    opts: &DistOptions,
    loss_curve: &mut Vec<f32>,
    stats: &mut DistStats,
) -> Result<()> {
    let header = FRAME_HEADER_LEN as u64;
    // Registered once per run, before the step loop: per-rank wire-byte
    // counters are bumped with the exact same quantities as the
    // `DistStats` fields below, so the per-rank totals always sum to the
    // run summary's byte accounting.
    let m_rx: Vec<_> = (0..conns.len())
        .map(|r| crate::obs::counter(&format!("dist.rank{r}.rx_bytes")))
        .collect();
    let m_tx: Vec<_> = (0..conns.len())
        .map(|r| crate::obs::counter(&format!("dist.rank{r}.tx_bytes")))
        .collect();
    let m_steps = crate::obs::counter("dist.steps");
    let m_raw = crate::obs::counter("dist.raw_bytes");
    let m_wire = crate::obs::counter("dist.wire_bytes");
    let m_bcast = crate::obs::counter("dist.bcast_bytes");
    let m_deadline = crate::obs::counter("dist.deadline_errors");
    let m_ratio = crate::obs::gauge("dist.compression_ratio");
    for step in 1..=total_steps {
        let hv = hypers_for_step(hypers, warmup, step as usize);
        let mut reducer = TreeReducer::new(conns.len());
        for (rank, conn) in conns.iter_mut().enumerate() {
            let read = {
                let _rx = crate::obs::span_rank(crate::obs::Phase::WireRx, rank);
                read_frame(conn)
            };
            if read.is_err() {
                m_deadline.inc();
            }
            let (kind, payload) = read.with_context(|| {
                format!(
                    "dist: rank {rank} missed the io deadline ({:?}) at step {step}",
                    opts.deadline
                )
            })?;
            match kind {
                FrameKind::Contrib => {}
                FrameKind::Error => {
                    bail!("dist: rank {rank} failed at step {step}: {}", decode_error(&payload)?)
                }
                other => bail!("dist: rank {rank} sent {other:?}, expected Contrib"),
            }
            let (c, cstats) = decode_contribution(&payload)
                .with_context(|| format!("dist: rank {rank} contribution at step {step}"))?;
            stats.rounds += 1;
            stats.raw_bytes += header + cstats.raw_bytes;
            stats.wire_bytes += header + cstats.wire_bytes;
            stats.sparse_raw_bytes += cstats.sparse_raw;
            stats.sparse_wire_bytes += cstats.sparse_wire;
            m_raw.add(header + cstats.raw_bytes);
            m_wire.add(header + cstats.wire_bytes);
            if let Some(ctr) = m_rx.get(rank) {
                ctr.add(header + cstats.wire_bytes);
            }
            reducer.push(rank, c)?;
        }
        let (total, _) = reducer.finish()?;
        // Broadcast the reduced total losslessly *before* applying:
        // every replica then applies identical bytes, so the stores
        // stay bitwise in sync even with lossy uplink compression.
        let (payload, _) = encode_contribution(&total, Compression::None)?;
        for (rank, conn) in conns.iter_mut().enumerate() {
            let _tx = crate::obs::span_rank(crate::obs::Phase::WireTx, rank);
            write_frame(conn, FrameKind::Total, &payload)
                .with_context(|| format!("dist: broadcast total at step {step}"))?;
            if let Some(ctr) = m_tx.get(rank) {
                ctr.add(header + payload.len() as u64);
            }
        }
        stats.bcast_bytes += (header + payload.len() as u64) * conns.len() as u64;
        m_bcast.add((header + payload.len() as u64) * conns.len() as u64);
        let loss = apply_contribution(engine, store, cfg, &hv, Reduced::Whole(total))?;
        loss_curve.push(loss);
        stats.steps = step as usize;
        m_steps.inc();
        m_ratio.set(stats.compression_ratio());
    }
    Ok(())
}

/// Best-effort `Error` fan-out on coordinator failure; never blocks
/// longer than a short bounded write per rank.
fn broadcast_error(conns: &mut [Conn], msg: &str) {
    let payload = encode_error(msg);
    for conn in conns.iter_mut() {
        let _ = conn.set_io_deadline(Some(Duration::from_millis(200)));
        let _ = write_frame(conn, FrameKind::Error, &payload);
        conn.shutdown();
    }
}

/// Run one worker rank end to end: connect (with retry, covering the
/// coordinator-bind race), handshake, then the step loop.
pub fn worker(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    rank: usize,
    opts: &DistOptions,
) -> Result<()> {
    validate(cfg, opts)?;
    ensure!(rank < opts.ranks, "dist: rank {rank} out of range for {} ranks", opts.ranks);
    let conn = opts.endpoint.connect_retry(opts.deadline)?;
    worker_loop(engine, cfg, train, rank, opts, conn)
}

/// The worker step loop over an established connection.
fn worker_loop(
    engine: &Engine,
    cfg: &TrainConfig,
    train: &Dataset,
    rank: usize,
    opts: &DistOptions,
    mut conn: Conn,
) -> Result<()> {
    let total_steps = plan_steps(cfg, train)?;
    conn.set_io_deadline(Some(opts.deadline))?;
    let hello = Hello {
        rank: rank as u32,
        ranks: opts.ranks as u32,
        batch: cfg.batch as u64,
        seed: cfg.seed,
        total_steps,
    };
    write_frame(&mut conn, FrameKind::Hello, &encode_hello(&hello))
        .with_context(|| format!("dist: rank {rank} hello"))?;
    let (kind, payload) = read_frame(&mut conn)
        .with_context(|| format!("dist: rank {rank} waiting for Welcome (io deadline)"))?;
    let welcome = match kind {
        FrameKind::Welcome => decode_welcome(&payload)?,
        FrameKind::Error => {
            bail!("dist: coordinator rejected rank {rank}: {}", decode_error(&payload)?)
        }
        other => bail!("dist: expected Welcome, got {other:?}"),
    };
    ensure!(
        welcome.total_steps == total_steps,
        "dist: coordinator plans {} steps, rank {rank} {total_steps}",
        welcome.total_steps
    );
    let compress = welcome.compress;

    // Full replica state: same init, same batch stream as every peer.
    let store = init_store(engine, cfg)?;
    let hypers = cfg.scaled_hypers();
    let warmup = Warmup::new(cfg.warmup_steps);
    let mut batcher = Batcher::new(train, cfg.batch, cfg.seed ^ 0x5eed);
    let mut scratch = Scratch::new();
    let mut ef = ErrorFeedback::default();
    let m_stalls = crate::obs::counter("dist.stalls");
    let m_ef = crate::obs::gauge("dist.ef_residual");

    for step in 1..=total_steps {
        let batch = batcher.next_batch();
        let hv = hypers_for_step(hypers, warmup, step as usize);
        let mut c = {
            let guard = store.read();
            let params: &ParamSet = &guard;
            WorkerShard::new(rank, opts.ranks).compute(engine, params, &batch, &mut scratch)?
        };
        // Fold last step's rounding error into the touched rows, encode,
        // then remember this step's rounding error for the next fold.
        ef.fold_in(&mut c.grads);
        let (payload, _) = encode_contribution(&c, compress)?;
        ef.absorb(&c.grads, compress);
        m_ef.set(ef.residual_l1());
        {
            let _tx = crate::obs::span_rank(crate::obs::Phase::WireTx, rank);
            write_frame(&mut conn, FrameKind::Contrib, &payload)
                .with_context(|| format!("dist: rank {rank} send contribution at step {step}"))?;
        }

        let read = {
            let _rx = crate::obs::span_rank(crate::obs::Phase::WireRx, rank);
            read_frame(&mut conn)
        };
        if read.is_err() {
            m_stalls.inc();
        }
        let (kind, payload) = read.with_context(|| {
            format!(
                "dist: rank {rank} waiting for the reduced total at step {step} \
                 (io deadline {:?})",
                opts.deadline
            )
        })?;
        let total = match kind {
            FrameKind::Total => {
                decode_contribution(&payload)
                    .with_context(|| format!("dist: total at step {step}"))?
                    .0
            }
            FrameKind::Error => {
                bail!("dist: coordinator aborted at step {step}: {}", decode_error(&payload)?)
            }
            other => bail!("dist: expected Total, got {other:?}"),
        };
        apply_contribution(engine, &store, cfg, &hv, Reduced::Whole(total))?;
    }

    let (kind, payload) = read_frame(&mut conn)
        .with_context(|| format!("dist: rank {rank} waiting for Shutdown (io deadline)"))?;
    match kind {
        FrameKind::Shutdown => {}
        FrameKind::Error => {
            bail!("dist: coordinator failed after the last step: {}", decode_error(&payload)?)
        }
        other => bail!("dist: expected Shutdown, got {other:?}"),
    }
    conn.shutdown();
    Ok(())
}

/// Per-rank error-feedback residuals: the quantization rounding error of
/// each sparse gradient row sent, keyed by row id, folded into the next
/// gradient that touches the row.
///
/// The residual is computed with the exact [`quant_scale`] /
/// [`quant_code`] / [`dequant`] arithmetic the encoder used on the same
/// values, so what the map holds is bit-for-bit `sent - received` — the
/// compensation term of Baidu's low-precision CTR training scheme.
/// Rows untouched by a later step keep their residual pending until the
/// row is touched again. With [`Compression::None`] the residual is
/// identically zero and the maps stay empty.
#[derive(Default)]
struct ErrorFeedback {
    /// One map per gradient slot (same order as `Contribution::grads`).
    residuals: Vec<BTreeMap<u32, Vec<f32>>>,
}

impl ErrorFeedback {
    fn ensure_slots(&mut self, n: usize) {
        while self.residuals.len() < n {
            self.residuals.push(BTreeMap::new());
        }
    }

    /// Add pending residuals into the rows this gradient touches. Only
    /// stored rows change, so the gradient's id structure (and the
    /// shared-ids wire optimization) is preserved.
    fn fold_in(&mut self, grads: &mut [GradTensor]) {
        self.ensure_slots(grads.len());
        for (g, map) in grads.iter_mut().zip(self.residuals.iter_mut()) {
            if map.is_empty() {
                continue;
            }
            if let GradTensor::Sparse(s) = g {
                let d = s.d();
                let (ids, vals) = s.ids_vals_mut();
                for (k, id) in ids.iter().enumerate() {
                    if let Some(row) = map.remove(id) {
                        for (v, r) in vals.iter_mut().skip(k * d).take(d).zip(&row) {
                            *v += *r;
                        }
                    }
                }
            }
        }
    }

    /// Total pending-residual L1 mass — the `dist.ef_residual` gauge.
    /// Maps are `BTreeMap`s, so the accumulation order is deterministic.
    fn residual_l1(&self) -> f64 {
        let mut total = 0.0f64;
        for map in &self.residuals {
            for row in map.values() {
                for &v in row {
                    total += v.abs() as f64;
                }
            }
        }
        total
    }

    /// Record the rounding error the wire just introduced for every
    /// sparse row of `grads` (which must be the exact values that were
    /// encoded). No-op for [`Compression::None`].
    fn absorb(&mut self, grads: &[GradTensor], compress: Compression) {
        let Some(q) = compress.levels() else {
            return;
        };
        self.ensure_slots(grads.len());
        for (g, map) in grads.iter().zip(self.residuals.iter_mut()) {
            if let GradTensor::Sparse(s) = g {
                let d = s.d();
                let scale = quant_scale(s.vals(), q);
                for (k, &id) in s.ids().iter().enumerate() {
                    let row = &s.vals()[k * d..(k + 1) * d];
                    let mut res = Vec::with_capacity(d);
                    let mut nonzero = false;
                    for &v in row {
                        let e = v - dequant(quant_code(v, scale, q), scale);
                        nonzero |= e != 0.0;
                        res.push(e);
                    }
                    if nonzero {
                        map.insert(id, res);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SparseRows;

    fn sparse_grad(ids: &[u32], vals: &[f32], d: usize) -> GradTensor {
        GradTensor::Sparse(SparseRows::new(100, d, ids.to_vec(), vals.to_vec()))
    }

    #[test]
    fn error_feedback_compensates_quantization_exactly() {
        let compress = Compression::U8;
        let q = compress.levels().unwrap();
        let vals = [0.5f32, -0.31, 0.007, 0.2, -0.9, 0.113];
        let mut grads = vec![sparse_grad(&[2, 7, 11], &vals, 2)];
        let mut ef = ErrorFeedback::default();

        // Step 1: nothing pending; absorb records the rounding error.
        ef.fold_in(&mut grads);
        ef.absorb(&grads, compress);
        let scale = quant_scale(&vals, q);
        let wire: Vec<f32> =
            vals.iter().map(|&v| dequant(quant_code(v, scale, q), scale)).collect();

        // Step 2 touches the same rows: the folded gradient must be the
        // new values plus exactly (sent - received) from step 1.
        let vals2 = [0.1f32, 0.1, 0.1, 0.1, 0.1, 0.1];
        let mut grads2 = vec![sparse_grad(&[2, 7, 11], &vals2, 2)];
        ef.fold_in(&mut grads2);
        let GradTensor::Sparse(s) = &grads2[0] else { panic!("sparse expected") };
        for ((&got, &v2), (&v1, &w)) in
            s.vals().iter().zip(&vals2).zip(vals.iter().zip(&wire))
        {
            let want = v2 + (v1 - w);
            assert_eq!(got.to_bits(), want.to_bits(), "residual must be bit-exact");
        }
    }

    #[test]
    fn error_feedback_keeps_untouched_rows_pending() {
        let compress = Compression::U8;
        let mut grads = vec![sparse_grad(&[2, 7], &[0.5, -0.31], 1)];
        let mut ef = ErrorFeedback::default();
        ef.fold_in(&mut grads);
        ef.absorb(&grads, compress);

        // Next step touches only row 7: row 2's residual stays pending.
        let mut grads2 = vec![sparse_grad(&[7], &[0.25], 1)];
        ef.fold_in(&mut grads2);
        assert!(ef.residuals[0].contains_key(&2), "row 2 residual must stay pending");
        assert!(!ef.residuals[0].contains_key(&7), "row 7 residual was consumed");

        // And a later step touching row 2 consumes it.
        let mut grads3 = vec![sparse_grad(&[2], &[0.0], 1)];
        ef.fold_in(&mut grads3);
        assert!(ef.residuals[0].is_empty());
        let GradTensor::Sparse(s) = &grads3[0] else { panic!("sparse expected") };
        assert!(s.vals()[0] != 0.0, "pending residual folded into a zero gradient");
    }

    #[test]
    fn error_feedback_is_inert_without_compression() {
        let mut grads = vec![sparse_grad(&[1, 2], &[0.5, -0.5], 1)];
        let mut ef = ErrorFeedback::default();
        ef.fold_in(&mut grads);
        ef.absorb(&grads, Compression::None);
        assert!(ef.residuals[0].is_empty());
        let GradTensor::Sparse(s) = &grads[0] else { panic!("sparse expected") };
        assert_eq!(s.vals(), &[0.5, -0.5]);
    }

    #[test]
    fn dist_options_validate_rejects_mismatches() {
        use crate::scaling::rules::{HyperSet, ScalingRule};
        let cfg = TrainConfig {
            batch: 128,
            base_batch: 128,
            base_hypers: HyperSet {
                lr_dense: 1e-3,
                lr_embed: 1e-3,
                l2_embed: 0.0,
                clip_r: 1.0,
                clip_zeta: 1e-4,
                clip_t: 0.5,
            },
            rule: ScalingRule::NoScale,
            epochs: 1.0,
            workers: 2,
            threads: 1,
            param_shards: 1,
            warmup_steps: 0,
            init_sigma: 0.01,
            seed: 1,
            eval_every_epochs: 0,
            verbose: false,
        };
        let mk = |ranks| DistOptions {
            ranks,
            endpoint: Endpoint::Unix(std::path::PathBuf::from("/tmp/x.sock")),
            compress: Compression::None,
            deadline: Duration::from_secs(1),
        };
        assert!(validate(&cfg, &mk(2)).is_ok());
        assert!(validate(&cfg, &mk(0)).is_err(), "zero ranks");
        assert!(validate(&cfg, &mk(3)).is_err(), "workers != ranks");
    }
}
