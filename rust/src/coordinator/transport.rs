//! Socket transport for distributed training.
//!
//! Unix domain sockets are the default (coordinator and workers share a
//! host); TCP is opt-in via a `tcp:host:port` endpoint for multi-machine
//! runs. Both sides speak the `wire::frame` protocol over a [`Conn`].
//!
//! Liveness is deadline-based everywhere: [`Listener::accept_deadline`]
//! polls a non-blocking listener, and [`Conn::set_io_deadline`] arms OS
//! read/write timeouts, so a killed or hung peer surfaces as an `Err`
//! naming the deadline instead of wedging the run. The transport holds
//! no locks and never panics on peer input.

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

/// Where the coordinator listens and workers connect.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// Unix domain socket path (default; `unix:` prefix optional).
    Unix(PathBuf),
    /// TCP address as `tcp:host:port`.
    Tcp(String),
}

impl FromStr for Endpoint {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            ensure!(!addr.is_empty(), "transport: empty tcp address");
            Ok(Endpoint::Tcp(addr.to_string()))
        } else {
            let path = s.strip_prefix("unix:").unwrap_or(s);
            ensure!(!path.is_empty(), "transport: empty socket path");
            Ok(Endpoint::Unix(PathBuf::from(path)))
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
            Endpoint::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

impl Endpoint {
    /// Bind a non-blocking listener at this endpoint.
    pub fn bind(&self) -> Result<Listener> {
        match self {
            Endpoint::Unix(path) => bind_unix(path),
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("transport: bind tcp:{addr}"))?;
                l.set_nonblocking(true).context("transport: set_nonblocking")?;
                Ok(Listener::Tcp(l))
            }
        }
    }

    /// Connect, retrying until `timeout` — covers the startup race where
    /// a worker launches before the coordinator has bound its socket,
    /// and reconnection storms during fault recovery.
    ///
    /// Retries back off exponentially (5 ms doubling to a 500 ms cap)
    /// with a small deterministic jitter derived from the attempt index
    /// — no RNG, so two runs of the same schedule retry at the same
    /// instants, but concurrent ranks (different attempt phases) do not
    /// thundering-herd a recovering coordinator.
    pub fn connect_retry(&self, timeout: Duration) -> Result<Conn> {
        let start = Instant::now();
        let mut attempts: u64 = 0;
        let mut backoff = Duration::from_millis(5);
        loop {
            match self.connect_once() {
                Ok(conn) => return Ok(conn),
                Err(err) => {
                    attempts += 1;
                    if start.elapsed() >= timeout {
                        return Err(err).with_context(|| {
                            format!(
                                "transport: connect to {self} timed out after {timeout:?} \
                                 ({attempts} attempts)"
                            )
                        });
                    }
                    // Top 3 bits of a Weyl-sequence hash: 0..8 ms jitter.
                    let jitter = Duration::from_millis(
                        attempts.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 61,
                    );
                    let remaining = timeout.saturating_sub(start.elapsed());
                    thread::sleep((backoff + jitter).min(remaining.max(Duration::from_millis(1))));
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        }
    }

    fn connect_once(&self) -> Result<Conn> {
        match self {
            Endpoint::Unix(path) => connect_unix(path),
            Endpoint::Tcp(addr) => {
                let s = TcpStream::connect(addr)
                    .with_context(|| format!("transport: connect tcp:{addr}"))?;
                let _ = s.set_nodelay(true);
                Ok(Conn::Tcp(s))
            }
        }
    }
}

#[cfg(unix)]
fn bind_unix(path: &Path) -> Result<Listener> {
    use std::os::unix::fs::FileTypeExt;
    // Remove a stale socket left by a previous run — but only if it
    // really is a socket; never delete an arbitrary file.
    if let Ok(meta) = std::fs::symlink_metadata(path) {
        ensure!(
            meta.file_type().is_socket(),
            "transport: {} exists and is not a socket",
            path.display()
        );
        std::fs::remove_file(path)
            .with_context(|| format!("transport: remove stale socket {}", path.display()))?;
    }
    let l = UnixListener::bind(path)
        .with_context(|| format!("transport: bind unix:{}", path.display()))?;
    l.set_nonblocking(true).context("transport: set_nonblocking")?;
    Ok(Listener::Unix(l))
}

#[cfg(not(unix))]
fn bind_unix(path: &Path) -> Result<Listener> {
    anyhow::bail!(
        "transport: unix sockets are unsupported on this platform ({})",
        path.display()
    )
}

#[cfg(unix)]
fn connect_unix(path: &Path) -> Result<Conn> {
    let s = UnixStream::connect(path)
        .with_context(|| format!("transport: connect unix:{}", path.display()))?;
    Ok(Conn::Unix(s))
}

#[cfg(not(unix))]
fn connect_unix(path: &Path) -> Result<Conn> {
    anyhow::bail!(
        "transport: unix sockets are unsupported on this platform ({})",
        path.display()
    )
}

/// A bound, non-blocking listener.
pub enum Listener {
    #[cfg(unix)]
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    /// Accept one connection before `deadline` elapses; the returned
    /// connection is switched back to blocking I/O.
    pub fn accept_deadline(&self, deadline: Duration) -> Result<Conn> {
        let start = Instant::now();
        loop {
            let accepted = match self {
                #[cfg(unix)]
                Listener::Unix(l) => match l.accept() {
                    Ok((s, _)) => Some(Conn::Unix(s)),
                    Err(err) if err.kind() == io::ErrorKind::WouldBlock => None,
                    Err(err) => return Err(err).context("transport: accept"),
                },
                Listener::Tcp(l) => match l.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nodelay(true);
                        Some(Conn::Tcp(s))
                    }
                    Err(err) if err.kind() == io::ErrorKind::WouldBlock => None,
                    Err(err) => return Err(err).context("transport: accept"),
                },
            };
            if let Some(conn) = accepted {
                conn.set_blocking()?;
                return Ok(conn);
            }
            ensure!(
                start.elapsed() < deadline,
                "transport: accept deadline ({deadline:?}) expired waiting for a worker"
            );
            thread::sleep(Duration::from_millis(2));
        }
    }
}

/// One established connection; implements `Read` + `Write` so
/// `wire::frame` works over it directly.
pub enum Conn {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Conn {
    fn set_blocking(&self) -> Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(false).context("transport: set_blocking"),
            Conn::Tcp(s) => s.set_nonblocking(false).context("transport: set_blocking"),
        }
    }

    /// Bound every subsequent read and write: a peer that stalls past
    /// the deadline turns into an `Err` instead of a hang. `None`
    /// restores unbounded blocking I/O.
    pub fn set_io_deadline(&self, deadline: Option<Duration>) -> Result<()> {
        // A zero duration means "no timeout" to the std API (which
        // rejects it); clamp to something strictly positive.
        let t = deadline.map(|d| d.max(Duration::from_millis(1)));
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => {
                s.set_read_timeout(t).context("transport: set read timeout")?;
                s.set_write_timeout(t).context("transport: set write timeout")
            }
            Conn::Tcp(s) => {
                s.set_read_timeout(t).context("transport: set read timeout")?;
                s.set_write_timeout(t).context("transport: set write timeout")
            }
        }
    }

    /// Best-effort close of both directions.
    pub fn shutdown(&self) {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Conn::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::wire::{read_frame, write_frame, FrameKind};
    use std::sync::atomic::{AtomicUsize, Ordering};

    static SOCK_SEQ: AtomicUsize = AtomicUsize::new(0);

    fn temp_endpoint() -> Endpoint {
        let seq = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "cowclip-transport-test-{}-{seq}.sock",
            std::process::id()
        ));
        Endpoint::Unix(path)
    }

    #[test]
    fn endpoint_parse_and_display() {
        let ep: Endpoint = "unix:/tmp/x.sock".parse().unwrap();
        assert_eq!(ep, Endpoint::Unix(PathBuf::from("/tmp/x.sock")));
        assert_eq!(ep.to_string(), "unix:/tmp/x.sock");
        let ep: Endpoint = "/tmp/y.sock".parse().unwrap();
        assert_eq!(ep, Endpoint::Unix(PathBuf::from("/tmp/y.sock")));
        let ep: Endpoint = "tcp:127.0.0.1:9000".parse().unwrap();
        assert_eq!(ep, Endpoint::Tcp("127.0.0.1:9000".to_string()));
        assert_eq!(ep.to_string(), "tcp:127.0.0.1:9000");
        assert!("".parse::<Endpoint>().is_err());
        assert!("tcp:".parse::<Endpoint>().is_err());
    }

    #[test]
    fn unix_frame_roundtrip_both_directions() {
        let ep = temp_endpoint();
        let listener = ep.bind().unwrap();
        let ep2 = ep.clone();
        let client = std::thread::spawn(move || {
            let mut conn = ep2.connect_retry(Duration::from_secs(5)).unwrap();
            write_frame(&mut conn, FrameKind::Hello, b"worker 0").unwrap();
            let (kind, payload) = read_frame(&mut conn).unwrap();
            assert_eq!(kind, FrameKind::Welcome);
            payload
        });
        let mut conn = listener.accept_deadline(Duration::from_secs(5)).unwrap();
        let (kind, payload) = read_frame(&mut conn).unwrap();
        assert_eq!(kind, FrameKind::Hello);
        assert_eq!(payload, b"worker 0");
        write_frame(&mut conn, FrameKind::Welcome, b"ok").unwrap();
        assert_eq!(client.join().unwrap(), b"ok");
    }

    #[test]
    fn connect_timeout_names_attempt_count() {
        let ep = temp_endpoint(); // never bound
        let err = ep.connect_retry(Duration::from_millis(60)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("attempts"), "{msg}");
    }

    #[test]
    fn accept_deadline_expires_with_named_error() {
        let ep = temp_endpoint();
        let listener = ep.bind().unwrap();
        let err = listener
            .accept_deadline(Duration::from_millis(40))
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    #[test]
    fn io_deadline_turns_a_silent_peer_into_an_error() {
        let ep = temp_endpoint();
        let listener = ep.bind().unwrap();
        let ep2 = ep.clone();
        let client = std::thread::spawn(move || {
            let conn = ep2.connect_retry(Duration::from_secs(5)).unwrap();
            // Connect and then go silent for longer than the deadline.
            std::thread::sleep(Duration::from_millis(300));
            drop(conn);
        });
        let mut conn = listener.accept_deadline(Duration::from_secs(5)).unwrap();
        conn.set_io_deadline(Some(Duration::from_millis(50))).unwrap();
        assert!(read_frame(&mut conn).is_err());
        client.join().unwrap();
    }

    #[test]
    fn stale_socket_is_replaced_but_regular_files_are_not() {
        let ep = temp_endpoint();
        // First bind creates the socket file; a rebind must replace it.
        drop(ep.bind().unwrap());
        drop(ep.bind().unwrap());
        if let Endpoint::Unix(path) = &ep {
            let _ = std::fs::remove_file(path);
            std::fs::write(path, b"not a socket").unwrap();
            assert!(ep.bind().is_err());
            let _ = std::fs::remove_file(path);
        }
    }
}
