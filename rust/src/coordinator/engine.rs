//! Training engine abstraction: AOT/PJRT programs or the Rust reference.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::clip::{ClipMode, ClipParams};
use crate::data::batcher::Batch;
use crate::data::schema::Schema;
use crate::model::manifest::ParamEntry;
use crate::model::params::ParamSet;
use crate::model::store::{ApplyCtx, ParamStore};
use crate::reference::step::build_spec;
use crate::reference::{GradOutput, ModelKind, ReferenceEngine, ReferenceModel};
use crate::runtime::{HypersVec, Program, Runtime};
use crate::tensor::{GradTensor, SparseRows, Tensor};

/// A training engine: grad / apply / fwd over positional parameters.
///
/// `grad` and `fwd` take `&self` and every variant is `Sync` (asserted
/// below), so the trainer's fan-out shares one `&Engine` across worker
/// threads; only `apply` needs `&mut self` (optimizer state) and runs on
/// the leader thread.
pub enum Engine {
    /// AOT HLO programs through PJRT (the production path).
    Hlo(HloEngine),
    /// Pure-Rust reference (no artifacts needed; slower).
    Reference(ReferenceEngine),
}

impl Engine {
    /// Build the HLO engine.
    pub fn hlo(
        runtime: Arc<Runtime>,
        model: ModelKind,
        schema_name: &str,
        clip: ClipMode,
    ) -> Result<Engine> {
        Ok(Engine::Hlo(HloEngine::new(runtime, model, schema_name, clip)?))
    }

    /// Build the reference engine from manifest-equivalent constants.
    pub fn reference(
        model: ModelKind,
        schema: Schema,
        embed_dim: usize,
        hidden: Vec<usize>,
        n_cross: usize,
        clip: ClipMode,
    ) -> Engine {
        Engine::Reference(ReferenceEngine::new(
            ReferenceModel::new(model, schema, embed_dim, hidden, n_cross),
            clip,
        ))
    }

    pub fn spec(&self) -> Vec<ParamEntry> {
        match self {
            Engine::Hlo(e) => e.spec.clone(),
            Engine::Reference(e) => e.spec(),
        }
    }

    pub fn schema(&self) -> &Schema {
        match self {
            Engine::Hlo(e) => &e.schema,
            Engine::Reference(e) => &e.model.schema,
        }
    }

    pub fn clip_mode(&self) -> ClipMode {
        match self {
            Engine::Hlo(e) => e.clip,
            Engine::Reference(e) => e.clip_mode,
        }
    }

    /// Microbatch sizes this engine can compute gradients at directly.
    pub fn grad_batch_sizes(&self) -> Vec<usize> {
        match self {
            Engine::Hlo(e) => e.microbatches.clone(),
            Engine::Reference(_) => vec![], // any size
        }
    }

    /// Gradient + counts + loss for one batch whose size must be directly
    /// supported (HLO: one of `grad_batch_sizes`; reference: any).
    pub fn grad(&self, params: &ParamSet, batch: &Batch) -> Result<GradOutput> {
        match self {
            Engine::Hlo(e) => e.grad(params, batch),
            Engine::Reference(e) => e.grad(params, batch),
        }
    }

    /// Gradient of rows `[lo, hi)` of `batch` — the worker fan-out's hot
    /// path. The reference engine reads the batch storage in place and
    /// runs its intermediates on `scratch` (zero copies, zero
    /// steady-state allocation); the HLO engine needs owned microbatch
    /// tensors for its program inputs, so it materializes the slice.
    pub fn grad_range(
        &self,
        params: &ParamSet,
        batch: &Batch,
        lo: usize,
        hi: usize,
        scratch: &mut crate::reference::Scratch,
    ) -> Result<GradOutput> {
        match self {
            Engine::Hlo(e) => {
                let micro = super::worker::slice_batch(batch, lo, hi)?;
                e.grad(params, &micro)
            }
            Engine::Reference(e) => e.grad_range_scratch(params, batch, lo, hi, scratch),
        }
    }

    /// Optimizer update in place over caller-owned `ParamSet`s — the
    /// **leader-serial oracle** path. The trainer itself applies through
    /// [`Engine::apply_store`]; this entry point remains for the parity
    /// suites (`hlo_parity`, `shard_parity`) that pin the sharded store
    /// against the original serial math.
    pub fn apply(
        &mut self,
        params: &mut ParamSet,
        m: &mut ParamSet,
        v: &mut ParamSet,
        grads: &mut [GradTensor],
        counts: &SparseRows,
        hv: &HypersVec,
    ) -> Result<()> {
        match self {
            Engine::Hlo(e) => {
                let dense_counts = counts.to_dense();
                e.apply(params, m, v, grads, &dense_counts, hv)
            }
            Engine::Reference(e) => {
                let mut h = hv.hypers;
                h.lr_dense *= hv.dense_lr_factor;
                e.apply(params, m, v, grads, counts, &h, hv.step)
            }
        }
    }

    /// Optimizer update through the shard-owned [`ParamStore`] — the
    /// trainer's apply path. Takes `&self`: all optimizer state lives in
    /// the store, so the engine stays shareable with the gradient
    /// fan-out's persistent worker pool.
    ///
    /// The reference engine runs `clip → L2 → Adam` per parameter shard
    /// (on up to `threads` scoped threads); the HLO apply program
    /// rewrites whole tensors, so it goes through the store's exclusive
    /// whole-set access and sparse payloads densify at that boundary.
    pub fn apply_store(
        &self,
        store: &ParamStore,
        grads: &mut [GradTensor],
        counts: &SparseRows,
        hv: &HypersVec,
        threads: usize,
    ) -> Result<()> {
        match self {
            Engine::Hlo(e) => {
                let dense_counts = counts.to_dense();
                store.with_all_mut(|params, m, v| e.apply(params, m, v, grads, &dense_counts, hv))
            }
            Engine::Reference(e) => {
                let ctx = reference_apply_ctx(e, hv);
                store.apply_sharded(&ctx, grads, counts, threads)
            }
        }
    }

    /// Optimizer update for a reduction finished as two subtree halves
    /// ([`crate::coordinator::Reduced::Halves`]): the root merge runs
    /// *inside* the sharded apply, split per parameter-shard row range,
    /// so apply work starts on each shard's range as soon as its slice
    /// merges instead of waiting for the whole-table merge tail.
    ///
    /// Reference engine only (the trainer routes the HLO engine — and
    /// the diagnostic dense-grads / Global-clip configurations — through
    /// the eager [`Engine::apply_store`] path); as a defensive fallback
    /// a non-reference engine merges eagerly here and delegates.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_store_halves(
        &self,
        store: &ParamStore,
        left: &mut crate::coordinator::allreduce::Contribution,
        right: crate::coordinator::allreduce::Contribution,
        hv: &HypersVec,
        threads: usize,
    ) -> Result<()> {
        match self {
            Engine::Reference(e) => {
                let ctx = reference_apply_ctx(e, hv);
                store.apply_sharded_pair(
                    &ctx,
                    &mut left.grads,
                    right.grads,
                    &left.counts,
                    &right.counts,
                    threads,
                )
            }
            Engine::Hlo(_) => {
                // eager fallback: merge, then the whole-tensor apply
                for (a, b) in left.grads.iter_mut().zip(&right.grads) {
                    a.axpy(1.0, b)?;
                }
                left.counts.axpy(1.0, &right.counts)?;
                self.apply_store(store, &mut left.grads, &left.counts, hv, threads)
            }
        }
    }

    /// Eval logits (batch size must match the fwd artifact for HLO).
    pub fn fwd(&self, params: &ParamSet, batch: &Batch) -> Result<Vec<f32>> {
        match self {
            Engine::Hlo(e) => e.fwd(params, batch),
            Engine::Reference(e) => e.fwd(params, batch),
        }
    }

    /// Eval logits on a caller-owned scratch arena (the returned buffer
    /// was taken from it — recycle after use on the reference engine).
    pub fn fwd_scratch(
        &self,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut crate::reference::Scratch,
    ) -> Result<Vec<f32>> {
        match self {
            Engine::Hlo(e) => e.fwd(params, batch),
            Engine::Reference(e) => e.fwd_scratch(params, batch, scratch),
        }
    }

    /// Eval batch size (fixed for HLO; caller's choice for reference).
    pub fn eval_batch(&self) -> Option<usize> {
        match self {
            Engine::Hlo(e) => Some(e.eval_batch),
            Engine::Reference(_) => None,
        }
    }
}

/// The AOT/PJRT engine: one `grad` program per microbatch size, one
/// `apply` program per clip mode, one `fwd` program for eval.
pub struct HloEngine {
    runtime: Arc<Runtime>,
    pub model: ModelKind,
    pub schema: Schema,
    pub clip: ClipMode,
    pub spec: Vec<ParamEntry>,
    pub microbatches: Vec<usize>,
    pub eval_batch: usize,
    grad_programs: Vec<(usize, Arc<Program>)>,
    apply_program: Arc<Program>,
    fwd_program: Arc<Program>,
    has_dense: bool,
}

impl HloEngine {
    pub fn new(
        runtime: Arc<Runtime>,
        model: ModelKind,
        schema_name: &str,
        clip: ClipMode,
    ) -> Result<HloEngine> {
        let manifest = runtime.manifest();
        let schema = manifest.schema(schema_name)?;
        let spec = manifest.param_spec(schema_name, model.as_str())?.to_vec();

        // consistency check vs the Rust spec builder (drift guard)
        let cfg = &manifest.model_cfg();
        let rust_spec = build_spec(model, &schema, cfg.0, &cfg.1, cfg.2);
        if rust_spec != spec {
            bail!(
                "param spec drift between manifest and rust for {}-{}",
                schema_name,
                model
            );
        }

        let microbatches = manifest.grad_microbatches(model.as_str(), schema_name);
        if microbatches.is_empty() {
            bail!("no grad artifacts for {}-{}", schema_name, model);
        }
        let mut grad_programs = Vec::new();
        for &mb in &microbatches {
            let a = manifest
                .find("grad", model.as_str(), schema_name, Some(mb), None)?
                .clone();
            grad_programs.push((mb, runtime.load(&a)?));
        }
        let apply_artifact = manifest
            .find("apply", model.as_str(), schema_name, None, Some(clip.as_str()))
            .with_context(|| format!("apply artifact for clip={clip}"))?
            .clone();
        let apply_program = runtime.load(&apply_artifact)?;
        let fwd_artifact = manifest
            .find("fwd", model.as_str(), schema_name, None, None)?
            .clone();
        let eval_batch = fwd_artifact.batch.unwrap();
        let fwd_program = runtime.load(&fwd_artifact)?;
        let has_dense = schema.n_dense > 0;

        Ok(HloEngine {
            runtime,
            model,
            schema,
            clip,
            spec,
            microbatches,
            eval_batch,
            grad_programs,
            apply_program,
            fwd_program,
            has_dense,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn grad(&self, params: &ParamSet, batch: &Batch) -> Result<GradOutput> {
        let b = batch.batch_size();
        let program = self
            .grad_programs
            .iter()
            .find(|(mb, _)| *mb == b)
            .map(|(_, p)| p)
            .with_context(|| format!("no grad artifact for microbatch {b}"))?;

        let n = params.len();
        let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
        inputs.push(&batch.x_cat);
        if self.has_dense {
            inputs.push(&batch.x_dense);
        }
        inputs.push(&batch.y);
        let mut out = program.run(&inputs)?;
        // outputs: grads..., counts, loss
        let loss_t = out.pop().unwrap();
        let counts_t = out.pop().unwrap();
        let loss = loss_t.as_f32()?[0];
        // the artifact emits dense counts; sparsify so the coordinator's
        // accumulate/all-reduce path stays O(touched) past this boundary
        let dense_counts = counts_t.as_f32()?;
        let counts = SparseRows::from_dense(dense_counts, dense_counts.len(), 1);
        debug_assert_eq!(out.len(), n);
        let grads = out.into_iter().map(GradTensor::Dense).collect();
        Ok(GradOutput { grads, counts, loss })
    }

    fn apply(
        &self,
        params: &mut ParamSet,
        m: &mut ParamSet,
        v: &mut ParamSet,
        grads: &[GradTensor],
        counts: &[f32],
        hv: &HypersVec,
    ) -> Result<()> {
        let n = params.len();
        let counts_t = Tensor::f32(vec![counts.len()], counts.to_vec());
        let hypers_t = hv.tensor();
        // the apply artifact wants dense inputs: borrow dense gradients
        // in place, materialize only the genuinely sparse ones
        let materialized: Vec<Option<Tensor>> = grads
            .iter()
            .map(|g| match g {
                GradTensor::Dense(_) => None,
                GradTensor::Sparse(s) => Some(s.to_tensor()),
            })
            .collect();
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(4 * n + 2);
        inputs.extend(params.tensors.iter());
        inputs.extend(m.tensors.iter());
        inputs.extend(v.tensors.iter());
        for (g, mat) in grads.iter().zip(&materialized) {
            match (g, mat) {
                (GradTensor::Dense(t), _) => inputs.push(t),
                (GradTensor::Sparse(_), Some(t)) => inputs.push(t),
                (GradTensor::Sparse(_), None) => unreachable!("materialized above"),
            }
        }
        inputs.push(&counts_t);
        inputs.push(&hypers_t);
        let mut out = self.apply_program.run(&inputs)?;
        debug_assert_eq!(out.len(), 3 * n);
        let vs = out.split_off(2 * n);
        let ms = out.split_off(n);
        params.tensors = out;
        m.tensors = ms;
        v.tensors = vs;
        Ok(())
    }

    fn fwd(&self, params: &ParamSet, batch: &Batch) -> Result<Vec<f32>> {
        if batch.batch_size() != self.eval_batch {
            bail!(
                "fwd batch {} != artifact batch {}",
                batch.batch_size(),
                self.eval_batch
            );
        }
        let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
        inputs.push(&batch.x_cat);
        if self.has_dense {
            inputs.push(&batch.x_dense);
        }
        let out = self.fwd_program.run(&inputs)?;
        Ok(out[0].as_f32()?.to_vec())
    }
}

/// The reference engine's resolved per-step apply context (warmup factor
/// folded into the dense LR). Shared by [`Engine::apply_store`] and
/// [`Engine::apply_store_halves`] so the eager and deferred-merge apply
/// paths can never drift on hyperparameter resolution.
fn reference_apply_ctx(e: &ReferenceEngine, hv: &HypersVec) -> ApplyCtx {
    let mut h = hv.hypers;
    h.lr_dense *= hv.dense_lr_factor;
    ApplyCtx {
        clip: e.clip_mode,
        clip_params: ClipParams { r: h.clip_r, zeta: h.clip_zeta, clip_t: h.clip_t },
        lr_embed: h.lr_embed,
        lr_dense: h.lr_dense,
        l2_embed: h.l2_embed,
        adam: e.adam_cfg(),
        step: hv.step as u32,
    }
}

// Thread-safety audit for the parallel fan-out: both engines must stay
// shareable across worker threads. The reference engine is plain data;
// the HLO path holds `Arc<Runtime>`/`Arc<Program>` whose only interior
// mutability (the compiled-program cache) is behind a `Mutex`. If a
// backend ever loses `Sync`, this fails to compile instead of breaking
// `Trainer::train_step` at a distance.
#[allow(dead_code)]
const _: () = {
    fn assert_sync<T: Sync>() {}
    fn engines_are_shareable() {
        assert_sync::<Engine>();
        assert_sync::<HloEngine>();
    }
};

/// Helper: pull (embed_dim, hidden, n_cross) out of the manifest.
trait ManifestExt {
    fn model_cfg(&self) -> (usize, Vec<usize>, usize);
}

impl ManifestExt for crate::model::manifest::Manifest {
    fn model_cfg(&self) -> (usize, Vec<usize>, usize) {
        (
            self.model_cfg.embed_dim,
            self.model_cfg.hidden.clone(),
            self.model_cfg.n_cross,
        )
    }
}
