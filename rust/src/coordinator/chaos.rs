//! Deterministic fault injection for the distributed transport.
//!
//! Every failure mode the fault-tolerance layer claims to survive is
//! reproducible on demand: a [`ChaosSpec`] (parsed from `--chaos SPEC`)
//! schedules faults against specific ranks and steps, and a
//! [`ChaosConn`] applies the frame-level ones on the write side of a
//! worker's connection. Process-level faults (kill, stall) are consumed
//! by the worker loop at step boundaries. Everything is seeded and
//! schedule-driven — two runs with the same spec inject bit-identical
//! faults at the same instants — which is what lets `fault_parity.rs`
//! assert that a recovered run is *bitwise* equal to an uninterrupted
//! one.
//!
//! # Spec grammar
//!
//! ```text
//! SPEC   := clause (';' clause)*
//! clause := KIND ':' arg (',' arg)*   |   'seed' ':' N
//! arg    := 'rank=' R | 'step=' N | 'ms=' T | 'times=' K
//! KIND   := 'kill' | 'hang' | 'corrupt' | 'drop' | 'trunc' | 'delay'
//! ```
//!
//! - `kill:rank=1,step=4` — rank 1's worker aborts at the step-4
//!   boundary, before computing or sending its contribution (simulated
//!   process death; under `--spawn-workers` the child exits nonzero and
//!   the coordinator respawns it).
//! - `hang:rank=0,step=3,ms=800` — the worker stalls 800 ms at step 3
//!   before sending, tripping the coordinator's io deadline.
//! - `corrupt:rank=1,step=3` — one payload bit of the frame sent at
//!   step 3 is flipped (CRC mismatch at the receiver; healed by the
//!   wire-link Nack/Resend exchange). `times=K` corrupts the first K
//!   frames flushed at that step — including retransmissions, which is
//!   how the retry budget is exhausted on purpose.
//! - `drop:rank=0,step=2` — the frame sent at step 2 is swallowed.
//! - `trunc:rank=0,step=2` — only the first half of the frame is sent
//!   (desyncs the stream; heals via reconnect, not retransmit).
//! - `delay:rank=0,step=2,ms=50` — the frame is sent 50 ms late.
//! - `rank=` is optional (default: every rank); `step=` is required;
//!   `seed:N` reseeds the corrupt-bit position generator.
//!
//! All events are one-shot (consumed when they fire), so a respawned or
//! reconnected replica does not re-trigger them; the CLI additionally
//! strips `--chaos` from respawned workers.

use std::fmt;
use std::io::{self, Read, Write};
use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::transport::{Conn, Endpoint, Listener};
use crate::wire::FRAME_HEADER_LEN;

/// The kind of injected fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Abort the worker process at a step boundary.
    Kill,
    /// Stall the worker `ms` at a step boundary before sending.
    Hang,
    /// Flip one payload bit of a frame sent at the step.
    Corrupt,
    /// Swallow a frame sent at the step.
    Drop,
    /// Send only the first half of a frame (stream desync).
    Trunc,
    /// Send a frame `ms` late.
    Delay,
}

impl ChaosKind {
    /// Frame-level faults are applied by [`ChaosConn`]; the rest are
    /// consumed by the worker loop.
    fn is_frame(self) -> bool {
        matches!(self, ChaosKind::Corrupt | ChaosKind::Drop | ChaosKind::Trunc | ChaosKind::Delay)
    }
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    pub kind: ChaosKind,
    /// Target rank; `None` targets every rank.
    pub rank: Option<u32>,
    /// 1-based training step the fault fires at.
    pub step: u64,
    /// Stall/delay duration for `hang`/`delay`.
    pub ms: u64,
    /// How many frames flushed at `step` the fault applies to
    /// (frame-level kinds only; each application consumes one).
    pub times: u32,
}

/// A parsed `--chaos` schedule. See the module docs for the grammar.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosSpec {
    pub seed: u64,
    pub events: Vec<ChaosEvent>,
}

impl FromStr for ChaosSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ChaosSpec> {
        let mut seed: u64 = 0x5eed;
        let mut events = Vec::new();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind_str, args) = match clause.split_once(':') {
                Some((k, a)) => (k.trim(), a.trim()),
                None => bail!("chaos: clause `{clause}` is missing `:` (grammar: kind:key=val,...)"),
            };
            if kind_str == "seed" {
                seed = args.parse().with_context(|| format!("chaos: bad seed `{args}`"))?;
                continue;
            }
            let kind = match kind_str {
                "kill" => ChaosKind::Kill,
                "hang" | "stall" => ChaosKind::Hang,
                "corrupt" => ChaosKind::Corrupt,
                "drop" => ChaosKind::Drop,
                "trunc" => ChaosKind::Trunc,
                "delay" => ChaosKind::Delay,
                other => bail!(
                    "chaos: unknown kind `{other}` (expected kill|hang|corrupt|drop|trunc|delay|seed)"
                ),
            };
            let mut ev = ChaosEvent { kind, rank: None, step: 0, ms: 0, times: 1 };
            for arg in args.split(',') {
                let arg = arg.trim();
                if arg.is_empty() {
                    continue;
                }
                let (key, val) = match arg.split_once('=') {
                    Some((k, v)) => (k.trim(), v.trim()),
                    None => bail!("chaos: bad argument `{arg}` in `{clause}` (expected key=val)"),
                };
                let parsed: u64 =
                    val.parse().with_context(|| format!("chaos: bad value `{val}` for `{key}`"))?;
                match key {
                    "rank" => ev.rank = Some(parsed as u32),
                    "step" => ev.step = parsed,
                    "ms" => ev.ms = parsed,
                    "times" => ev.times = parsed as u32,
                    other => bail!("chaos: unknown key `{other}` (expected rank|step|ms|times)"),
                }
            }
            ensure!(ev.step >= 1, "chaos: `{clause}` needs step=N (steps are 1-based)");
            ensure!(
                !matches!(ev.kind, ChaosKind::Hang | ChaosKind::Delay) || ev.ms > 0,
                "chaos: `{clause}` needs ms=T"
            );
            ensure!(ev.times >= 1, "chaos: `{clause}` has times=0 (it would never fire)");
            events.push(ev);
        }
        ensure!(!events.is_empty(), "chaos: spec `{s}` contains no events");
        Ok(ChaosSpec { seed, events })
    }
}

/// The live, consumable form of a [`ChaosSpec`] for one rank: events
/// are removed as they fire, so a schedule salvaged across a reconnect
/// (see [`ChaosConn::into_parts`]) does not re-inject healed faults.
#[derive(Debug, Default)]
pub struct ChaosSchedule {
    seed: u64,
    events: Vec<ChaosEvent>,
}

impl ChaosSchedule {
    /// A schedule that never fires.
    pub fn inert() -> ChaosSchedule {
        ChaosSchedule::default()
    }

    /// The subset of `spec` targeting `rank` (or all ranks).
    pub fn for_rank(spec: Option<&ChaosSpec>, rank: usize) -> ChaosSchedule {
        match spec {
            None => ChaosSchedule::inert(),
            Some(spec) => ChaosSchedule {
                seed: spec.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                events: spec
                    .events
                    .iter()
                    .filter(|e| e.rank.is_none() || e.rank == Some(rank as u32))
                    .copied()
                    .collect(),
            },
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Remove and return the process-level events (kill/hang) due at
    /// `step`. Called once per step by the worker loop.
    pub fn take_process(&mut self, step: u64) -> Vec<ChaosEvent> {
        let mut due = Vec::new();
        self.events.retain(|e| {
            if !e.kind.is_frame() && e.step == step {
                due.push(*e);
                false
            } else {
                true
            }
        });
        due
    }

    /// Consume one application of a frame-level event due at `step`.
    fn take_frame(&mut self, step: u64) -> Option<ChaosEvent> {
        let pos = self.events.iter().position(|e| e.kind.is_frame() && e.step == step)?;
        let ev = {
            let e = self.events.get_mut(pos)?;
            e.times = e.times.saturating_sub(1);
            *e
        };
        if ev.times == 0 {
            self.events.remove(pos);
        }
        Some(ev)
    }
}

/// The distinguished error a chaos `kill` raises in the worker: the
/// reconnect loop treats it as fatal (a real process death — the
/// process exits nonzero) rather than retrying in-process.
#[derive(Clone, Copy, Debug)]
pub struct ChaosKill {
    pub rank: usize,
    pub step: u64,
}

impl fmt::Display for ChaosKill {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "chaos: kill rank {} at step {} (injected fault)", self.rank, self.step)
    }
}

impl std::error::Error for ChaosKill {}

/// A [`Conn`] wrapper that injects the schedule's frame-level faults on
/// the write side. Writes are buffered until `flush` — `write_frame`
/// flushes exactly once per frame, so each flush is one frame and the
/// fault is applied to whole frames, never to a byte range spanning
/// two.
///
/// Reads pass through untouched: every fault is injected at its
/// *sender*, which keeps cause and schedule in one place.
pub struct ChaosConn {
    inner: Conn,
    sched: ChaosSchedule,
    step: u64,
    wbuf: Vec<u8>,
    rng: u64,
}

impl ChaosConn {
    pub fn new(inner: Conn, sched: ChaosSchedule) -> ChaosConn {
        let rng = sched.seed ^ 0x243F_6A88_85A3_08D3;
        ChaosConn { inner, sched, step: 0, wbuf: Vec::new(), rng }
    }

    /// A wrapper with an empty schedule — plain pass-through, used on
    /// the coordinator side and on fault-free workers.
    pub fn inert(inner: Conn) -> ChaosConn {
        ChaosConn::new(inner, ChaosSchedule::inert())
    }

    /// Point the schedule at the current training step.
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// The underlying connection (deadlines, shutdown).
    pub fn conn(&self) -> &Conn {
        &self.inner
    }

    pub fn schedule_mut(&mut self) -> &mut ChaosSchedule {
        &mut self.sched
    }

    /// Tear down the wrapper, salvaging the connection and whatever
    /// events have not fired yet (a reconnect carries them forward).
    pub fn into_parts(self) -> (Conn, ChaosSchedule) {
        (self.inner, self.sched)
    }

    /// splitmix64 — deterministic corrupt-bit positions from the seed.
    fn next_rand(&mut self) -> u64 {
        self.rng = self.rng.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    fn flush_frame(&mut self, mut frame: Vec<u8>) -> io::Result<()> {
        match self.sched.take_frame(self.step) {
            None => {
                self.inner.write_all(&frame)?;
                self.inner.flush()
            }
            Some(ev) => match ev.kind {
                ChaosKind::Drop => Ok(()),
                ChaosKind::Delay => {
                    std::thread::sleep(Duration::from_millis(ev.ms));
                    self.inner.write_all(&frame)?;
                    self.inner.flush()
                }
                ChaosKind::Trunc => {
                    let half = frame.len() / 2;
                    frame.truncate(half);
                    self.inner.write_all(&frame)?;
                    self.inner.flush()
                }
                ChaosKind::Corrupt => {
                    // Flip one bit in the payload (or, for an empty
                    // payload, in the CRC field) — never in the magic /
                    // kind bytes, so the receiver stays frame-aligned
                    // and the damage is exactly a CRC mismatch.
                    let r = self.next_rand();
                    let idx = if frame.len() > FRAME_HEADER_LEN {
                        FRAME_HEADER_LEN + (r as usize) % (frame.len() - FRAME_HEADER_LEN)
                    } else {
                        8 // first CRC byte
                    };
                    let bit = (r >> 32) % 8;
                    if let Some(b) = frame.get_mut(idx) {
                        *b ^= 1u8 << bit;
                    }
                    self.inner.write_all(&frame)?;
                    self.inner.flush()
                }
                // Process-level kinds never reach take_frame.
                ChaosKind::Kill | ChaosKind::Hang => {
                    self.inner.write_all(&frame)?;
                    self.inner.flush()
                }
            },
        }
    }
}

impl Read for ChaosConn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.inner.read(buf)
    }
}

impl Write for ChaosConn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.wbuf.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.wbuf.is_empty() {
            return Ok(());
        }
        let frame = std::mem::take(&mut self.wbuf);
        self.flush_frame(frame)
    }
}

/// A [`Listener`] whose accepted connections come pre-wrapped in
/// (inert) [`ChaosConn`]s, so both sides of the dist loop speak the
/// same stream type; faults are injected at the worker ranks.
pub struct ChaosListener {
    inner: Listener,
}

impl ChaosListener {
    pub fn bind(endpoint: &Endpoint) -> Result<ChaosListener> {
        Ok(ChaosListener { inner: endpoint.bind()? })
    }

    pub fn accept_deadline(&self, deadline: Duration) -> Result<ChaosConn> {
        Ok(ChaosConn::inert(self.inner.accept_deadline(deadline)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_full_grammar() {
        let spec: ChaosSpec =
            "seed:7; kill:rank=1,step=4; hang:rank=0,step=3,ms=800; corrupt:step=2,times=5; \
             drop:rank=0,step=2; trunc:step=5; delay:step=2,ms=50"
                .parse()
                .unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.events.len(), 6);
        assert_eq!(
            spec.events[0],
            ChaosEvent { kind: ChaosKind::Kill, rank: Some(1), step: 4, ms: 0, times: 1 }
        );
        assert_eq!(spec.events[2].kind, ChaosKind::Corrupt);
        assert_eq!(spec.events[2].times, 5);
        assert_eq!(spec.events[2].rank, None);
    }

    #[test]
    fn spec_rejects_malformed_clauses() {
        for bad in [
            "",
            "explode:step=1",
            "kill:rank=1",          // missing step
            "hang:step=2",          // missing ms
            "kill",                 // missing colon
            "kill:rank",            // missing =
            "corrupt:step=1,times=0",
            "kill:step=x",
        ] {
            assert!(bad.parse::<ChaosSpec>().is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn schedule_filters_by_rank_and_consumes_events() {
        let spec: ChaosSpec = "kill:rank=1,step=4; corrupt:step=2,times=2".parse().unwrap();
        let mut r0 = ChaosSchedule::for_rank(Some(&spec), 0);
        let mut r1 = ChaosSchedule::for_rank(Some(&spec), 1);
        // rank 0 only sees the all-rank corrupt event.
        assert!(r0.take_process(4).is_empty());
        assert_eq!(r0.take_frame(2).unwrap().kind, ChaosKind::Corrupt);
        assert_eq!(r0.take_frame(2).unwrap().kind, ChaosKind::Corrupt);
        assert!(r0.take_frame(2).is_none(), "times=2 exhausted");
        // rank 1 sees kill at step 4, exactly once.
        assert!(r1.take_process(3).is_empty());
        let due = r1.take_process(4);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].kind, ChaosKind::Kill);
        assert!(r1.take_process(4).is_empty(), "one-shot");
    }

    #[cfg(unix)]
    #[test]
    fn chaos_conn_applies_frame_faults() {
        use crate::wire::{read_frame, write_frame, FrameKind, FrameRead};
        use std::os::unix::net::UnixStream;

        let pair = |spec: &str, rank: usize| {
            let (a, b) = UnixStream::pair().unwrap();
            let spec: ChaosSpec = spec.parse().unwrap();
            let sched = ChaosSchedule::for_rank(Some(&spec), rank);
            (ChaosConn::new(Conn::Unix(a), sched), Conn::Unix(b))
        };

        // corrupt: receiver sees a CRC mismatch, stream stays aligned.
        let (mut tx, mut rx) = pair("corrupt:step=3", 0);
        tx.set_step(3);
        write_frame(&mut tx, FrameKind::Contrib, b"some gradient bytes").unwrap();
        match crate::wire::frame::read_frame_checked(&mut rx).unwrap() {
            FrameRead::Corrupt { kind, .. } => assert_eq!(kind, FrameKind::Contrib),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // The next frame (step moved on) is clean.
        tx.set_step(4);
        write_frame(&mut tx, FrameKind::Contrib, b"clean").unwrap();
        let (_, payload) = read_frame(&mut rx).unwrap();
        assert_eq!(payload, b"clean");

        // drop: nothing arrives; a later frame does.
        let (mut tx, mut rx) = pair("drop:step=1", 0);
        tx.set_step(1);
        write_frame(&mut tx, FrameKind::Contrib, b"swallowed").unwrap();
        tx.set_step(2);
        write_frame(&mut tx, FrameKind::Contrib, b"arrives").unwrap();
        let (_, payload) = read_frame(&mut rx).unwrap();
        assert_eq!(payload, b"arrives");

        // events scheduled for another rank do not fire.
        let (mut tx, mut rx) = pair("corrupt:rank=1,step=3", 0);
        tx.set_step(3);
        write_frame(&mut tx, FrameKind::Contrib, b"untouched").unwrap();
        let (_, payload) = read_frame(&mut rx).unwrap();
        assert_eq!(payload, b"untouched");
    }
}
