//! Persistent step-worker pool: the gradient fan-out without per-step
//! thread spawns.
//!
//! PR 2 scoped the worker threads inside every `train_step` call — tens
//! of µs of spawn cost per step, noise at 128K-row batches but real
//! overhead for µs-scale small-batch stepping (a ROADMAP item). The pool
//! is created **once** inside `Trainer::train`'s thread scope and lives
//! for the whole run: workers block on a shared job queue, compute one
//! [`WorkerShard`] contribution per job, and reply on the job's own
//! per-step channel.
//!
//! Workers read the parameters through the store's `RwLock` — the
//! fan-out holds read locks, the apply stage takes the write side — so
//! no borrow ties a step's data to the pool: jobs carry the batch as an
//! `Arc` and are `'static`.
//!
//! Jobs are queued in rank order and the queue is FIFO, so rank-adjacent
//! shards (which the fixed-pairing [`super::TreeReducer`] merges
//! together first) tend to finish close together — the same ordering
//! heuristic the scoped fan-out used.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::Scope;

use anyhow::Result;

use super::allreduce::Contribution;
use super::engine::Engine;
use super::worker::WorkerShard;
use crate::data::batcher::Batch;
use crate::model::params::ParamSet;

/// One gradient task: compute `rank`'s shard contribution for `batch`
/// and send it (tagged with the rank) over `reply`.
pub struct GradJob {
    pub rank: usize,
    pub world: usize,
    pub batch: Arc<Batch>,
    pub reply: Sender<(usize, Result<Contribution>)>,
}

/// A persistent pool of gradient workers (see module docs). Dropping the
/// pool closes the job queue; the scoped worker threads drain and exit
/// before the owning scope joins them.
pub struct StepPool {
    tx: Sender<GradJob>,
}

impl StepPool {
    /// Spawn `threads` workers on `scope`, each sharing `engine` and
    /// reading parameters through `params` for every job it picks up.
    pub fn spawn<'scope, 'env>(
        scope: &'scope Scope<'scope, 'env>,
        threads: usize,
        engine: &'env Engine,
        params: &'env RwLock<ParamSet>,
    ) -> StepPool {
        let (tx, rx) = channel::<GradJob>();
        let rx = Arc::new(Mutex::new(rx));
        // registered once at pool creation; each job is one relaxed bump
        let jobs_done = crate::obs::counter("pool.jobs");
        for _ in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let jobs_done = Arc::clone(&jobs_done);
            scope.spawn(move || {
                // one scratch arena per worker thread, alive for the
                // whole run: after the first job its buffers reach
                // steady-state capacity and the compute path stops
                // allocating
                let mut scratch = crate::reference::Scratch::new();
                loop {
                    // hold the queue lock only while waiting for a job;
                    // the compute below runs with the queue free
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => break, // pool dropped: shut down
                    };
                    let contribution = {
                        let guard = params.read().unwrap();
                        WorkerShard::new(job.rank, job.world)
                            .compute(engine, &guard, &job.batch, &mut scratch)
                    };
                    jobs_done.inc();
                    // a dropped reply receiver just means the leader
                    // already failed this step; keep serving the queue
                    let _ = job.reply.send((job.rank, contribution));
                }
            });
        }
        StepPool { tx }
    }

    /// Queue a gradient job.
    pub fn submit(&self, job: GradJob) {
        self.tx.send(job).expect("step pool workers exited early");
    }
}
