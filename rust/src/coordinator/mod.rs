//! The L3 training coordinator — the paper's system made concrete.
//!
//! Responsibilities:
//! * **Engine abstraction** ([`engine`]): one surface over the AOT/PJRT
//!   path and the pure-Rust reference path.
//! * **Large-batch composition** ([`accumulate`]): an effective batch of
//!   `s·b` is assembled by accumulating `s` microbatch gradients *and
//!   occurrence counts*, which is exactly Alg. 1's full-batch semantics.
//! * **Parallel data parallelism** ([`worker`], [`allreduce`], [`pool`]):
//!   logical workers compute shard gradients on a persistent step-worker
//!   pool ([`pool::StepPool`], spawned once per run) and stream them
//!   into a deterministic **tree-merge** reducer
//!   ([`allreduce::TreeReducer`]) — fixed pairing over contiguous rank
//!   ranges, so reduction overlaps the slowest shard's compute, the
//!   post-arrival critical path is O(log W), and the result is bitwise
//!   identical at any thread count; with the root merge deferred
//!   ([`allreduce::Reduced::Halves`]) the final, largest merge runs
//!   inside the sharded apply, split per parameter-shard row range.
//!   Traffic accounting covers the paper's multi-GPU extension;
//!   [`allreduce::tree_allreduce`] keeps the round-structured cost model
//!   for traffic studies.
//! * **Sharded apply**: the merged gradient is partitioned by the
//!   store's field-aligned shard plan and `clip → L2 → Adam` runs per
//!   parameter shard in parallel (see `model::store::ParamStore`), so
//!   the embedding-heavy optimizer stage no longer serializes on the
//!   leader.
//! * **The training loop** ([`trainer`]): scaling rules, warmup,
//!   prefetched batches, parallel eval, checkpoints (with resume),
//!   timing. See the [`trainer`] module docs for the threading model and
//!   determinism guarantees.
//! * **Multi-process distributed training** ([`dist`], [`transport`]):
//!   the same fixed-tree reduction promoted across process boundaries —
//!   a coordinator plus `cowclip worker` processes exchanging framed
//!   sparse contributions over Unix/TCP sockets (`wire` layer), with
//!   optional u16/u8 gradient quantization + error feedback on the
//!   uplink. Compression off is bitwise identical to the in-process
//!   path (`rust/tests/dist_parity.rs`).
//! * **Fault tolerance** ([`chaos`], [`dist`]): deterministic fault
//!   injection (`--chaos`), step-atomic recovery with a versioned
//!   rejoin handshake and local catch-up replay, bounded CRC
//!   retransmission, and CCKS snapshots — a mid-run rank kill recovers
//!   bitwise identical to the sequential path
//!   (`rust/tests/fault_parity.rs`).

pub mod accumulate;
pub mod allreduce;
pub mod chaos;
pub mod dist;
pub mod engine;
pub mod pool;
pub mod trainer;
pub mod transport;
pub mod worker;

pub use accumulate::GradAccumulator;
pub use allreduce::{tree_allreduce, Contribution, Reduced, ReduceStats, TreeReducer};
pub use chaos::{ChaosConn, ChaosEvent, ChaosKill, ChaosKind, ChaosListener, ChaosSchedule, ChaosSpec};
pub use dist::{
    coordinate, coordinate_with, worker as dist_worker, DistOptions, DistReport, DistStats,
    Respawn,
};
pub use engine::{Engine, HloEngine};
pub use pool::{GradJob, StepPool};
pub use trainer::{TrainConfig, TrainReport, Trainer};
pub use transport::Endpoint;
pub use worker::{BatchSlice, WorkerShard};
