//! The L3 training coordinator — the paper's system made concrete.
//!
//! Responsibilities:
//! * **Engine abstraction** ([`engine`]): one surface over the AOT/PJRT
//!   path and the pure-Rust reference path.
//! * **Large-batch composition** ([`accumulate`]): an effective batch of
//!   `s·b` is assembled by accumulating `s` microbatch gradients *and
//!   occurrence counts*, which is exactly Alg. 1's full-batch semantics.
//! * **Simulated data parallelism** ([`worker`], [`allreduce`]): logical
//!   workers compute shard gradients; a binary-tree all-reduce combines
//!   them, with traffic accounting (the paper's multi-GPU extension).
//! * **The training loop** ([`trainer`]): scaling rules, warmup, eval,
//!   checkpoints, timing.

pub mod accumulate;
pub mod allreduce;
pub mod engine;
pub mod trainer;
pub mod worker;

pub use accumulate::GradAccumulator;
pub use allreduce::{tree_allreduce, ReduceStats};
pub use engine::{Engine, HloEngine};
pub use trainer::{TrainConfig, TrainReport, Trainer};
pub use worker::WorkerShard;
