//! The 8-slot runtime hypers vector (layout fixed by the manifest).

use crate::scaling::rules::HyperSet;
use crate::tensor::Tensor;

/// Builder for the `hypers: f32[8]` input of `apply` artifacts.
#[derive(Clone, Copy, Debug)]
pub struct HypersVec {
    pub hypers: HyperSet,
    /// 1-based optimizer step (drives Adam bias correction).
    pub step: f32,
    /// Multiplier applied to the dense LR only (warmup).
    pub dense_lr_factor: f32,
}

impl HypersVec {
    pub fn new(hypers: HyperSet) -> HypersVec {
        HypersVec { hypers, step: 1.0, dense_lr_factor: 1.0 }
    }

    pub fn at_step(mut self, step: usize) -> HypersVec {
        self.step = step as f32;
        self
    }

    pub fn with_warmup(mut self, factor: f32) -> HypersVec {
        self.dense_lr_factor = factor;
        self
    }

    /// Materialize the `[8]` tensor.
    pub fn tensor(&self) -> Tensor {
        let mut v = self.hypers.to_vec(self.step);
        v[0] *= self.dense_lr_factor;
        Tensor::f32(vec![8], v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> HyperSet {
        HyperSet {
            lr_dense: 2e-3,
            lr_embed: 1e-3,
            l2_embed: 1e-4,
            clip_r: 1.0,
            clip_zeta: 1e-5,
            clip_t: 0.5,
        }
    }

    #[test]
    fn layout_and_warmup() {
        let hv = HypersVec::new(base()).at_step(17).with_warmup(0.25);
        let t = hv.tensor();
        let xs = t.as_f32().unwrap();
        assert_eq!(t.shape(), &[8]);
        assert!((xs[0] - 5e-4).abs() < 1e-9, "dense lr warmed");
        assert_eq!(xs[1], 1e-3);
        assert_eq!(xs[6], 17.0);
    }
}
