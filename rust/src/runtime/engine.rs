//! Executable cache + typed execute wrapper.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::model::manifest::{Artifact, Manifest};
use crate::tensor::Tensor;

/// A compiled HLO program plus its manifest metadata.
pub struct Program {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl Program {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.artifact.inputs.len() {
            bail!(
                "{}: got {} inputs, expected {}",
                self.artifact.id,
                inputs.len(),
                self.artifact.inputs.len()
            );
        }
        // Shape check against the manifest (cheap; catches host bugs early).
        for (t, d) in inputs.iter().zip(&self.artifact.inputs) {
            if t.shape() != d.shape.as_slice() {
                bail!(
                    "{}: input {} shape {:?}, expected {:?}",
                    self.artifact.id,
                    d.name,
                    t.shape(),
                    d.shape
                );
            }
        }
        // Build Rust-owned device buffers and run through `execute_b`.
        // (The crate's literal-taking `execute` leaks its inputs: the C
        // shim `release()`s each transferred buffer and PJRT does not
        // take ownership of non-donated arguments — ~10 MB leaked per
        // training step before this was caught; see EXPERIMENTS.md §Perf.)
        let client = self.exe.client();
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| match t {
                Tensor::F32 { shape, data } => {
                    client.buffer_from_host_buffer(data, shape, None)
                }
                Tensor::I32 { shape, data } => {
                    client.buffer_from_host_buffer(data, shape, None)
                }
            })
            .collect::<std::result::Result<_, _>>()?;
        let result = self.exe.execute_b(&buffers)?;
        // return_tuple=True at lowering: one buffer holding the out tuple.
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.artifact.n_outputs {
            bail!(
                "{}: got {} outputs, expected {}",
                self.artifact.id,
                parts.len(),
                self.artifact.n_outputs
            );
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// PJRT client + manifest + compiled-program cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Program>>>,
}

impl Runtime {
    /// Open the artifacts directory (compiles nothing yet).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts location: `$COWCLIP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("COWCLIP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Runtime::new(Path::new(&dir))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling and caching on first use) the program for an
    /// artifact id.
    pub fn load(&self, artifact: &Artifact) -> Result<Arc<Program>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(&artifact.id) {
                return Ok(p.clone());
            }
        }
        let path = self.manifest.hlo_path(artifact);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", artifact.id))?;
        let program = Arc::new(Program { artifact: artifact.clone(), exe });
        self.cache
            .lock()
            .unwrap()
            .insert(artifact.id.clone(), program.clone());
        Ok(program)
    }

    /// Convenience: find + load + run in one call.
    pub fn execute(
        &self,
        kind: &str,
        model: &str,
        schema: &str,
        batch: Option<usize>,
        clip: Option<&str>,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let artifact = self.manifest.find(kind, model, schema, batch, clip)?.clone();
        self.load(&artifact)?.run(inputs)
    }

    /// Number of compiled programs currently cached.
    pub fn cached_programs(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
