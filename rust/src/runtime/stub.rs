//! Pure-Rust stand-in for the PJRT runtime, compiled when the `pjrt`
//! cargo feature is off (the default).
//!
//! It keeps the exact public API of `engine.rs` so every consumer (the
//! CLI, the HLO engine, benches, examples) compiles unchanged, but it
//! refuses to execute: `Runtime::new` validates the manifest for a
//! useful error message and then reports that PJRT support is not built
//! in. The tier-1 verify therefore runs on any machine — all tests that
//! need real artifacts already skip when `artifacts/manifest.json` is
//! absent, and the reference engine covers the math.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::manifest::{Artifact, Manifest};
use crate::tensor::Tensor;

const NO_PJRT: &str =
    "this build has no PJRT runtime (rebuild with `--features pjrt` and a vendored `xla` \
     crate, or use `--engine reference`)";

/// A compiled HLO program plus its manifest metadata (stub: never
/// constructible without the `pjrt` feature).
pub struct Program {
    pub artifact: Artifact,
}

impl Program {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        bail!("{}: {NO_PJRT}", self.artifact.id)
    }
}

/// PJRT client + manifest + compiled-program cache (stub).
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Open the artifacts directory. The manifest is parsed (so format
    /// errors surface first), then the missing backend is reported.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let _manifest = Manifest::load(artifacts_dir)?;
        bail!("artifacts at {} are valid, but {NO_PJRT}", artifacts_dir.display())
    }

    /// Default artifacts location: `$COWCLIP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("COWCLIP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Runtime::new(Path::new(&dir))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable (built without pjrt)".into()
    }

    /// Fetch (compiling and caching on first use) the program for an
    /// artifact id.
    pub fn load(&self, artifact: &Artifact) -> Result<Arc<Program>> {
        bail!("{}: {NO_PJRT}", artifact.id)
    }

    /// Convenience: find + load + run in one call.
    pub fn execute(
        &self,
        _kind: &str,
        _model: &str,
        _schema: &str,
        _batch: Option<usize>,
        _clip: Option<&str>,
        _inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        bail!("{NO_PJRT}")
    }

    /// Number of compiled programs currently cached.
    pub fn cached_programs(&self) -> usize {
        0
    }
}
