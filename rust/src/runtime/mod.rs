//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). Compiled
//! executables are cached per artifact id; the training hot loop calls
//! [`Runtime::execute`] with host tensors and gets host tensors back.
//!
//! HLO **text** is the interchange format — see `python/compile/aot.py`
//! and /opt/xla-example/README.md for why serialized protos don't work
//! with xla_extension 0.5.1.
//!
//! The whole backend is gated behind the off-by-default `pjrt` cargo
//! feature: without it, `stub.rs` provides the same `Runtime`/`Program`
//! API but refuses to execute, so the default build is pure Rust (the
//! reference engine carries all tests). Enabling `pjrt` additionally
//! requires adding a vendored `xla` bindings crate to `[dependencies]`.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod engine;
pub mod hypers;

pub use engine::{Program, Runtime};
pub use hypers::HypersVec;
