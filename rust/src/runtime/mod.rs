//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). Compiled
//! executables are cached per artifact id; the training hot loop calls
//! [`Runtime::execute`] with host tensors and gets host tensors back.
//!
//! HLO **text** is the interchange format — see `python/compile/aot.py`
//! and /opt/xla-example/README.md for why serialized protos don't work
//! with xla_extension 0.5.1.

pub mod engine;
pub mod hypers;

pub use engine::{Program, Runtime};
pub use hypers::HypersVec;
