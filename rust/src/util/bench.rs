//! Tiny benchmark harness (offline build: no criterion). Used by the
//! `benches/` binaries: warmup + timed repetitions + robust summary.

use std::time::Instant;

use super::stats::{mean, percentile};

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ms: Vec<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        mean(&self.samples_ms)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.samples_ms, 50.0)
    }

    pub fn p95_ms(&self) -> f64 {
        percentile(&self.samples_ms, 95.0)
    }

    pub fn print(&self) {
        println!(
            "{:<44} mean {:>9.3} ms   p50 {:>9.3} ms   p95 {:>9.3} ms   (n={})",
            self.name,
            self.mean_ms(),
            self.p50_ms(),
            self.p95_ms(),
            self.samples_ms.len()
        );
    }
}

/// Run `f` `warmup + reps` times, timing the last `reps`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples_ms = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    let r = BenchResult { name: name.to_string(), samples_ms };
    r.print();
    r
}

/// Throughput helper: items/second given a per-call item count.
pub fn throughput(result: &BenchResult, items_per_call: usize) -> f64 {
    items_per_call as f64 / (result.mean_ms() / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut calls = 0;
        let r = bench("noop", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.samples_ms.len(), 5);
        assert!(r.mean_ms() >= 0.0);
        assert!(throughput(&r, 100) > 0.0);
    }
}
