//! Minimal JSON parser (offline build: no serde available).
//!
//! Supports the full JSON grammar the manifest uses: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Not streaming, not
//! zero-copy — the manifest is ~100 KiB, parsed once at startup.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -------- typed accessors ------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Array of usize convenience.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Array of String convenience.
    pub fn string_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .context("unexpected end of input")
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, got {:?}",
                c as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).context("invalid \\u escape")?);
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                other => {
                    // collect the full UTF-8 sequence
                    let start = self.pos - 1;
                    let len = utf8_len(other);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .with_context(|| format!("invalid number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
          "version": 2,
          "adam": {"beta1": 0.9, "eps": 1e-8},
          "hidden": [128, 128, 128],
          "artifacts": [{"id": "a-b", "batch": null, "ok": true}],
          "name": "criteo \"synth\"\n"
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 2);
        assert!((v.get("adam").unwrap().get("eps").unwrap().as_f64().unwrap() - 1e-8).abs() < 1e-20);
        assert_eq!(v.get("hidden").unwrap().usize_vec().unwrap(), vec![128, 128, 128]);
        let art = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert!(art.opt("batch").is_none());
        assert!(art.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "criteo \"synth\"\n");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12.5e2").unwrap().as_f64().unwrap(), -1250.0);
        assert!(Json::parse("3.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str().unwrap(), "Aé");
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        assert_eq!(v.as_arr().unwrap()[0].usize_vec().unwrap(), vec![1, 2]);
    }
}
