//! Wall-clock measurement helpers for the perf pass and Table 6.

use std::time::{Duration, Instant};

/// Accumulating stopwatch with named laps — used by the trainer to break
/// a step into grad / reduce / apply / host phases.
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a named lap (ends any active lap first).
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// End the active lap, if any.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.laps.push((name, t0.elapsed()));
        }
    }

    /// Total time spent in laps with the given name.
    pub fn total(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    /// Sum of all laps.
    pub fn grand_total(&self) -> Duration {
        self.laps.iter().map(|(_, d)| *d).sum()
    }

    /// (name, total) per distinct lap name, in first-seen order.
    pub fn summary(&self) -> Vec<(String, Duration)> {
        let mut names: Vec<String> = Vec::new();
        for (n, _) in &self.laps {
            if !names.contains(n) {
                names.push(n.clone());
            }
        }
        names
            .into_iter()
            .map(|n| {
                let t = self.total(&n);
                (n, t)
            })
            .collect()
    }

    pub fn reset(&mut self) {
        self.laps.clear();
        self.current = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_by_name() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.start("b");
        std::thread::sleep(Duration::from_millis(2));
        sw.start("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        assert!(sw.total("a") >= Duration::from_millis(4));
        assert!(sw.total("b") >= Duration::from_millis(2));
        assert_eq!(sw.summary().len(), 2);
        assert!(sw.grand_total() >= Duration::from_millis(6));
    }
}
