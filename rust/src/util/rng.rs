//! Deterministic, splittable PRNG (xoshiro256++ seeded via splitmix64).
//!
//! Every stochastic component of the system (data synthesis, shuffling,
//! parameter init, worker sharding) derives its stream from a root seed so
//! experiment runs are bit-reproducible — the paper runs three seeds and
//! reports <0.012% AUC stddev, and we mirror that protocol.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (`label` disambiguates siblings).
    pub fn split(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0xA076_1D64_78BD_642F);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::MIN_POSITIVE {
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal f32 values scaled by `scale`.
    pub fn gaussian_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_gaussian() as f32 * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn uniform_below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.25)).count();
        assert!((hits as f64 / 10_000.0 - 0.25).abs() < 0.02);
    }
}
