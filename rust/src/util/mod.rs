//! Small shared utilities: deterministic RNG, math helpers, timing.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::{mean, percentile, stddev};
pub use timer::Stopwatch;
