//! Full reference training step: grad → clip → L2 → Adam.
//!
//! `ReferenceEngine` mirrors the split AOT interface (`grad` and `apply`
//! as separate calls) so the coordinator can swap engines behind one
//! trait-shaped surface.

use std::borrow::Cow;

use anyhow::{bail, Result};

use super::model::{ModelKind, ReferenceModel};
use crate::clip::{clip_embedding_grads, clip_embedding_grads_sparse, ClipMode, ClipParams};
use crate::data::batcher::Batch;
use crate::data::schema::Schema;
use crate::model::manifest::ParamEntry;
use crate::model::params::ParamSet;
use crate::optim::{Adam, LazyAdam};
use crate::scaling::rules::HyperSet;
use crate::tensor::{GradTensor, SparseRows};

/// Output of a gradient computation: one dense-or-sparse gradient per
/// positional parameter, plus the batch's per-id occurrence counts as a
/// `d = 1` sparse vector over the vocabulary.
pub struct GradOutput {
    pub grads: Vec<GradTensor>,
    pub counts: SparseRows,
    pub loss: f32,
}

/// Per-stored-row counts aligned with `ids` — borrowed in the common
/// case where the gradient's id set *is* the counts' id set (true for
/// everything the trainer produces), materialized only on mismatch.
fn counts_for<'a>(ids: &[u32], counts: &'a SparseRows) -> Cow<'a, [f32]> {
    if counts.ids() == ids {
        Cow::Borrowed(counts.vals())
    } else {
        Cow::Owned(ids.iter().map(|&id| counts.value_at(id)).collect())
    }
}

/// Build the positional parameter spec for (model, schema) — must stay
/// identical to `python/compile/models/*.spec`; the manifest parity test
/// enforces this.
pub fn build_spec(
    kind: ModelKind,
    schema: &Schema,
    embed_dim: usize,
    hidden: &[usize],
    n_cross: usize,
) -> Vec<ParamEntry> {
    let v = schema.total_vocab();
    let d0 = schema.n_cat() * embed_dim + schema.n_dense;
    let entry = |name: &str, shape: Vec<usize>, group: &str| ParamEntry {
        name: name.into(),
        shape,
        group: group.into(),
    };
    let mut spec = vec![entry("embed_table", vec![v, embed_dim], "embed")];
    match kind {
        ModelKind::DeepFm | ModelKind::WideDeep => {
            spec.push(entry("wide_table", vec![v, 1], "wide"));
            spec.push(entry("wide_bias", vec![1], "dense"));
            let mut m = d0;
            for (i, &h) in hidden.iter().enumerate() {
                spec.push(entry(&format!("mlp_w{i}"), vec![m, h], "dense"));
                spec.push(entry(&format!("mlp_b{i}"), vec![h], "dense"));
                m = h;
            }
            spec.push(entry("mlp_wout", vec![m, 1], "dense"));
            spec.push(entry("mlp_bout", vec![1], "dense"));
        }
        ModelKind::Dcn | ModelKind::DcnV2 => {
            for i in 0..n_cross {
                if kind == ModelKind::Dcn {
                    spec.push(entry(&format!("cross_w{i}"), vec![d0], "dense"));
                } else {
                    spec.push(entry(&format!("cross_W{i}"), vec![d0, d0], "dense"));
                }
                spec.push(entry(&format!("cross_b{i}"), vec![d0], "dense"));
            }
            let mut m = d0;
            for (i, &h) in hidden.iter().enumerate() {
                spec.push(entry(&format!("mlp_w{i}"), vec![m, h], "dense"));
                spec.push(entry(&format!("mlp_b{i}"), vec![h], "dense"));
                m = h;
            }
            spec.push(entry("head_w", vec![d0 + m, 1], "dense"));
            spec.push(entry("head_b", vec![1], "dense"));
        }
    }
    spec
}

/// Pure-Rust engine implementing grad/apply/fwd.
///
/// The default path is **sparse**: row-indexed gradients (embed/wide)
/// arrive as [`GradTensor::Sparse`] and are clipped, L2-regularized and
/// Adam-stepped on their touched rows only ([`LazyAdam`]). Dense
/// gradients (the diagnostic `dense_grads` mode, or HLO-originated
/// tensors in parity tests) take the legacy eager path unchanged.
pub struct ReferenceEngine {
    pub model: ReferenceModel,
    pub clip_mode: ClipMode,
    adam: Adam,
    /// Per-param lazy-Adam row state, created on first sparse apply.
    lazy: Vec<Option<LazyAdam>>,
    /// Emit dense gradients from `grad()` (exercises the O(V·d) path;
    /// benches use this to measure the dense-vs-sparse gap).
    dense_grads: bool,
}

impl ReferenceEngine {
    pub fn new(model: ReferenceModel, clip_mode: ClipMode) -> ReferenceEngine {
        ReferenceEngine {
            model,
            clip_mode,
            adam: Adam::default(),
            lazy: Vec::new(),
            dense_grads: false,
        }
    }

    /// Builder: emit dense gradients instead of sparse ones.
    pub fn with_dense_grads(mut self, dense: bool) -> ReferenceEngine {
        self.dense_grads = dense;
        self
    }

    /// Adam constants (the shard-owned apply path builds its own
    /// optimizer state from these).
    pub fn adam_cfg(&self) -> crate::optim::AdamConfig {
        self.adam.cfg
    }

    pub fn spec(&self) -> Vec<ParamEntry> {
        build_spec(
            self.model.kind,
            &self.model.schema,
            self.model.embed_dim,
            &self.model.hidden,
            self.model.n_cross,
        )
    }

    /// Whether the diagnostic dense-gradient mode is on (the trainer's
    /// deferred-merge apply path requires sparse vocab payloads).
    pub fn emits_dense_grads(&self) -> bool {
        self.dense_grads
    }

    /// Forward-only (eval) logits.
    pub fn fwd(&self, params: &ParamSet, batch: &Batch) -> Result<Vec<f32>> {
        self.model.forward(params, batch)
    }

    /// Forward-only logits on a caller-owned scratch arena; the returned
    /// buffer was taken from `scratch` — recycle it after use to keep
    /// eval allocation-free.
    pub fn fwd_scratch(
        &self,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut crate::reference::Scratch,
    ) -> Result<Vec<f32>> {
        self.model.forward_scratch(params, batch, scratch)
    }

    /// Gradient + counts + loss for one microbatch (convenience form
    /// with a throwaway scratch arena).
    pub fn grad(&self, params: &ParamSet, batch: &Batch) -> Result<GradOutput> {
        let mut scratch = crate::reference::Scratch::new();
        self.grad_scratch(params, batch, &mut scratch)
    }

    /// [`ReferenceEngine::grad`] on a caller-owned scratch arena — the
    /// worker fan-out's hot path.
    pub fn grad_scratch(
        &self,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut crate::reference::Scratch,
    ) -> Result<GradOutput> {
        let (loss, grads, counts) = self.model.grad_with(params, batch, scratch)?;
        Ok(self.finish_grad(loss, grads, counts))
    }

    /// Gradient of rows `[lo, hi)` of `batch`, reading the batch storage
    /// in place (no row copies — see
    /// [`ReferenceModel::grad_range_with`]).
    pub fn grad_range_scratch(
        &self,
        params: &ParamSet,
        batch: &Batch,
        lo: usize,
        hi: usize,
        scratch: &mut crate::reference::Scratch,
    ) -> Result<GradOutput> {
        let (loss, grads, counts) = self.model.grad_range_with(params, batch, lo, hi, scratch)?;
        Ok(self.finish_grad(loss, grads, counts))
    }

    fn finish_grad(&self, loss: f32, mut grads: Vec<GradTensor>, counts: SparseRows) -> GradOutput {
        if self.dense_grads {
            for g in &mut grads {
                if matches!(g, GradTensor::Sparse(_)) {
                    let dense = g.to_tensor();
                    *g = GradTensor::Dense(dense);
                }
            }
        }
        GradOutput { grads, counts, loss }
    }

    /// Apply accumulated gradients: clip (embed group) → +L2 (embed+wide)
    /// → Adam (group learning rates). `step` is 1-based.
    ///
    /// This is the **leader-serial oracle**: the trainer now applies
    /// through the shard-owned `model::store::ParamStore` instead, and
    /// `rust/tests/shard_parity.rs` pins that path against this one.
    /// Kept `&mut self` (per-param [`LazyAdam`] state) and byte-for-byte
    /// unchanged so the oracle cannot drift with the refactor.
    ///
    /// Sparse gradients pay O(touched · d): sparse clip, L2 on touched
    /// rows only (lazy weight decay), and [`LazyAdam`] scatter updates.
    /// Dense gradients keep the original eager O(V · d) semantics.
    pub fn apply(
        &mut self,
        params: &mut ParamSet,
        m: &mut ParamSet,
        v: &mut ParamSet,
        grads: &mut [GradTensor],
        counts: &SparseRows,
        hypers: &HyperSet,
        step: f32,
    ) -> Result<()> {
        let d_embed = self.model.embed_dim;
        let clip_params = ClipParams {
            r: hypers.clip_r,
            zeta: hypers.clip_zeta,
            clip_t: hypers.clip_t,
        };
        let spec = &params.spec;
        let tensors = &mut params.tensors;
        if self.lazy.len() != spec.len() {
            self.lazy = (0..spec.len()).map(|_| None).collect();
        }
        for (i, entry) in spec.iter().enumerate() {
            let w = tensors[i].as_f32_mut()?;
            let mi = m.tensors[i].as_f32_mut()?;
            let vi = v.tensors[i].as_f32_mut()?;
            match &mut grads[i] {
                GradTensor::Sparse(sg) => {
                    let lr = match entry.group.as_str() {
                        "embed" => {
                            let cnt = counts_for(sg.ids(), counts);
                            clip_embedding_grads_sparse(
                                self.clip_mode,
                                sg,
                                w,
                                &cnt,
                                &self.model.schema,
                                &clip_params,
                            );
                            hypers.lr_embed
                        }
                        // wide: L2 but no clipping (1-d LR "embeddings")
                        "wide" => hypers.lr_embed,
                        other => bail!(
                            "sparse gradient for dense-group param {} ({other})",
                            entry.name
                        ),
                    };
                    // lazy L2: regularize touched rows only
                    let dd = sg.d();
                    {
                        let (ids, vals) = sg.ids_vals_mut();
                        for (k, &id) in ids.iter().enumerate() {
                            let base = id as usize * dd;
                            for j in 0..dd {
                                vals[k * dd + j] += hypers.l2_embed * w[base + j];
                            }
                        }
                    }
                    if self.lazy[i].is_none() {
                        self.lazy[i] = Some(LazyAdam::new(self.adam.cfg, entry.shape[0]));
                    }
                    let lazy = self.lazy[i].as_mut().unwrap();
                    lazy.step_rows(w, mi, vi, sg.ids(), sg.vals(), dd, lr, step as u32);
                }
                GradTensor::Dense(t) => {
                    let g = t.as_f32_mut()?;
                    let lr = match entry.group.as_str() {
                        "embed" => {
                            let dense_counts = counts.to_dense();
                            clip_embedding_grads(
                                self.clip_mode,
                                g,
                                w,
                                &dense_counts,
                                &self.model.schema,
                                d_embed,
                                &clip_params,
                            );
                            for (gv, wv) in g.iter_mut().zip(w.iter()) {
                                *gv += hypers.l2_embed * wv;
                            }
                            hypers.lr_embed
                        }
                        "wide" => {
                            for (gv, wv) in g.iter_mut().zip(w.iter()) {
                                *gv += hypers.l2_embed * wv;
                            }
                            hypers.lr_embed
                        }
                        _ => hypers.lr_dense,
                    };
                    self.adam.step(w, mi, vi, g, lr, step);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::Batch;
    use crate::model::init::{init_params, InitConfig};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn tiny_schema() -> Schema {
        Schema { name: "tiny".into(), n_dense: 3, vocab_sizes: vec![5, 4, 2] }
    }

    fn tiny_model(kind: ModelKind) -> ReferenceModel {
        ReferenceModel::new(kind, tiny_schema(), 4, vec![8, 8], 2)
    }

    fn tiny_batch(schema: &Schema, b: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let offs = schema.offsets();
        let mut x_cat = Vec::new();
        for _ in 0..b {
            for (f, &vs) in schema.vocab_sizes.iter().enumerate() {
                x_cat.push((offs[f] + rng.below(vs as u64) as usize) as i32);
            }
        }
        let x_dense: Vec<f32> = (0..b * schema.n_dense)
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        let y: Vec<f32> = (0..b).map(|_| rng.bernoulli(0.4) as u8 as f32).collect();
        Batch::new(
            Tensor::i32(vec![b, schema.n_cat()], x_cat),
            Tensor::f32(vec![b, schema.n_dense], x_dense),
            Tensor::f32(vec![b], y),
            b,
        )
    }

    fn loss_of(model: &ReferenceModel, params: &ParamSet, batch: &Batch) -> f32 {
        let logits = model.forward(params, batch).unwrap();
        let y = batch.y.as_f32().unwrap();
        super::super::layers::bce_fwd_bwd(&logits, y).0
    }

    /// The core correctness test of the whole reference engine: every
    /// model's analytic gradient matches central finite differences on a
    /// sample of coordinates from every parameter tensor.
    #[test]
    fn finite_difference_gradients_all_models() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let spec = build_spec(kind, &model.schema, 4, &[8, 8], 2);
            let mut params = init_params(&spec, &InitConfig { seed: 3, embed_sigma: 0.05 });
            // perturb biases away from 0 so their grads are informative
            for t in &mut params.tensors {
                for (j, x) in t.as_f32_mut().unwrap().iter_mut().enumerate() {
                    if *x == 0.0 {
                        *x = 0.01 * ((j % 7) as f32 - 3.0);
                    }
                }
            }
            let batch = tiny_batch(&model.schema, 6, 9);
            let (_, grads, _) = model.grad(&params, &batch).unwrap();
            // densify sparse (embed/wide) grads for coordinate access
            let grads: Vec<Tensor> = grads.iter().map(|g| g.to_tensor()).collect();

            let eps = 2e-3f32;
            let mut checked = 0;
            for ti in 0..params.len() {
                let n = params.tensors[ti].len();
                // sample a handful of coordinates per tensor
                let idxs: Vec<usize> = (0..n).step_by(1.max(n / 5)).take(5).collect();
                for &j in &idxs {
                    let orig = params.tensors[ti].as_f32().unwrap()[j];
                    params.tensors[ti].as_f32_mut().unwrap()[j] = orig + eps;
                    let hi = loss_of(&model, &params, &batch);
                    params.tensors[ti].as_f32_mut().unwrap()[j] = orig - eps;
                    let lo = loss_of(&model, &params, &batch);
                    params.tensors[ti].as_f32_mut().unwrap()[j] = orig;
                    let fd = (hi - lo) / (2.0 * eps);
                    let an = grads[ti].as_f32().unwrap()[j];
                    assert!(
                        (fd - an).abs() < 2e-3 + 0.05 * an.abs().max(fd.abs()),
                        "{kind}: tensor {} ({}) idx {j}: fd {fd} vs analytic {an}",
                        ti,
                        params.spec[ti].name,
                    );
                    checked += 1;
                }
            }
            assert!(checked > 20, "{kind}: too few coordinates checked");
        }
    }

    #[test]
    fn counts_match_batch_occurrences() {
        let model = tiny_model(ModelKind::WideDeep);
        let spec = model_spec(&model);
        let params = init_params(&spec, &InitConfig::baseline(0));
        let batch = tiny_batch(&model.schema, 16, 4);
        let (_, _, counts) = model.grad(&params, &batch).unwrap();
        assert_eq!(counts.vals().iter().sum::<f32>(), (16 * 3) as f32);
        assert_eq!(counts.n_rows(), model.schema.total_vocab());
    }

    fn model_spec(model: &ReferenceModel) -> Vec<ParamEntry> {
        build_spec(model.kind, &model.schema, model.embed_dim, &model.hidden, model.n_cross)
    }

    #[test]
    fn training_reduces_loss_every_model() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let mut engine = ReferenceEngine::new(model.clone(), ClipMode::CowClip);
            let spec = engine.spec();
            let mut params = init_params(&spec, &InitConfig { seed: 1, embed_sigma: 0.01 });
            let mut m = params.zeros_like();
            let mut v = params.zeros_like();
            let batch = tiny_batch(&model.schema, 32, 2);
            let hypers = HyperSet {
                lr_dense: 1e-2,
                lr_embed: 1e-2,
                l2_embed: 1e-5,
                clip_r: 1.0,
                clip_zeta: 1e-5,
                clip_t: 1.0,
            };
            let mut losses = Vec::new();
            for t in 1..=20 {
                let mut out = engine.grad(&params, &batch).unwrap();
                losses.push(out.loss);
                let t = t as f32;
                engine
                    .apply(&mut params, &mut m, &mut v, &mut out.grads, &out.counts, &hypers, t)
                    .unwrap();
            }
            assert!(
                losses[19] < losses[0] * 0.98,
                "{kind}: {:?}",
                (&losses[0], &losses[19])
            );
        }
    }

    #[test]
    fn spec_matches_reference_grad_arity() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let spec = model_spec(&model);
            let params = init_params(&spec, &InitConfig::baseline(0));
            let batch = tiny_batch(&model.schema, 4, 1);
            let (_, grads, _) = model.grad(&params, &batch).unwrap();
            assert_eq!(grads.len(), spec.len(), "{kind}");
        }
    }
}
