//! Row-major matrix helpers for the reference engine.
//!
//! Deliberately simple loops: the reference engine is a correctness
//! oracle, not the hot path (the AOT artifacts are). The matmul uses the
//! k-in-the-middle loop order so the inner loop is contiguous in both
//! operands — good enough to keep the parity tests fast.

/// `y[b, n] = x[b, m] @ w[m, n]` (accumulates into zeroed output).
pub fn matmul(x: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(w.len(), m * n);
    let mut y = vec![0.0f32; b * n];
    for i in 0..b {
        let xrow = &x[i * m..(i + 1) * m];
        let yrow = &mut y[i * n..(i + 1) * n];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * n..(k + 1) * n];
            for (yj, &wj) in yrow.iter_mut().zip(wrow) {
                *yj += xv * wj;
            }
        }
    }
    y
}

/// `y[b, m] = g[b, n] @ w^T` where `w` is `[m, n]`.
pub fn matmul_nt(g: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(w.len(), m * n);
    let mut y = vec![0.0f32; b * m];
    for i in 0..b {
        let grow = &g[i * n..(i + 1) * n];
        let yrow = &mut y[i * m..(i + 1) * m];
        for k in 0..m {
            let wrow = &w[k * n..(k + 1) * n];
            let mut acc = 0.0f32;
            for (gv, wv) in grow.iter().zip(wrow) {
                acc += gv * wv;
            }
            yrow[k] = acc;
        }
    }
    y
}

/// `dw[m, n] = x^T[m, b] @ g[b, n]` where `x` is `[b, m]`.
pub fn matmul_tn(x: &[f32], g: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(g.len(), b * n);
    let mut dw = vec![0.0f32; m * n];
    for i in 0..b {
        let xrow = &x[i * m..(i + 1) * m];
        let grow = &g[i * n..(i + 1) * n];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let drow = &mut dw[k * n..(k + 1) * n];
            for (dv, &gv) in drow.iter_mut().zip(grow) {
                *dv += xv * gv;
            }
        }
    }
    dw
}

/// Column sums: `db[n] = sum_b g[b, n]`.
pub fn colsum(g: &[f32], b: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    for i in 0..b {
        for (dv, &gv) in db.iter_mut().zip(&g[i * n..(i + 1) * n]) {
            *dv += gv;
        }
    }
    db
}

/// Per-row dot products of two `[b, n]` matrices -> `[b]`.
pub fn rowdot(a: &[f32], c: &[f32], b: usize, n: usize) -> Vec<f32> {
    (0..b)
        .map(|i| {
            a[i * n..(i + 1) * n]
                .iter()
                .zip(&c[i * n..(i + 1) * n])
                .map(|(x, y)| x * y)
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let y = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_identities() {
        // For y = x@w: dX = dY@w^T and dW = x^T@dY must satisfy the
        // trace identity <dY, x@w_dir> = <matmul_tn(x,dY), w_dir>.
        let x = [0.5f32, -1.0, 2.0, 0.0, 1.0, -0.5];
        let w = [1.0f32, 0.0, -1.0, 2.0, 0.5, 1.5];
        let dy = [1.0f32, -1.0, 0.5, 2.0];
        let (b, m, n) = (2, 3, 2);
        let dx = matmul_nt(&dy, &w, b, m, n);
        let dw = matmul_tn(&x, &dy, b, m, n);
        // directional check
        let xdir = [0.1f32, 0.2, -0.1, 0.3, -0.2, 0.05];
        let wdir = [0.2f32, -0.3, 0.1, 0.4, -0.1, 0.2];
        let lhs: f32 = matmul(&xdir, &w, b, m, n).iter().zip(&dy).map(|(a, g)| a * g).sum();
        let rhs: f32 = dx.iter().zip(&xdir).map(|(a, d)| a * d).sum();
        assert!((lhs - rhs).abs() < 1e-5);
        let lhs2: f32 = matmul(&x, &wdir, b, m, n).iter().zip(&dy).map(|(a, g)| a * g).sum();
        let rhs2: f32 = dw.iter().zip(&wdir).map(|(a, d)| a * d).sum();
        assert!((lhs2 - rhs2).abs() < 1e-5);
    }

    #[test]
    fn colsum_and_rowdot() {
        let g = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(colsum(&g, 2, 2), vec![4.0, 6.0]);
        assert_eq!(rowdot(&g, &g, 2, 2), vec![5.0, 25.0]);
    }
}
