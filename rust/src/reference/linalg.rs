//! Row-major matrix kernels for the reference engine's hot path.
//!
//! Two tiers live here:
//!
//! * The **vectorized kernels** (top level) — blocked, unit-stride loops
//!   whose inner bodies are written so the compiler auto-vectorizes them
//!   (row-[`axpy`] accumulation for the `i-k-j` matmuls, an 8-lane
//!   [`dot`] for the transposed products). Every kernel has a
//!   write-into-output `_into` variant so the per-step compute path can
//!   run on reusable [`super::Scratch`] buffers with zero allocation;
//!   the allocating names are thin wrappers kept for tests and cold
//!   callers.
//! * The **naive oracles** ([`naive`]) — the original deliberately
//!   simple loops, kept verbatim so the property tests (and
//!   `benches/kernels.rs`) can pin the vectorized kernels against a
//!   known-good reference and report the speedup.
//!
//! Determinism notes: [`matmul_into`] and [`matmul_tn_into`] accumulate
//! each output element in the same index order as the naive loops, so
//! they are bitwise identical to the oracles. [`dot`] (and therefore
//! [`matmul_nt_into`] / [`rowdot_into`]) sums through 8 fixed lanes, so
//! it is deterministic run-to-run but differs from the serial sum by
//! normal f32 association (≤1e-6 relative on test-scale data — the
//! property tests pin this). All call sites use the same kernels, so
//! train-vs-serve and threaded-vs-sequential parity are unaffected.

/// Row blocking factor for the `i-k-j` matmul: the weight rows touched
/// by a block of samples stay resident across the block.
const BLOCK: usize = 32;

/// `y += a * x`, element-wise. The body is chunked by 8 so the compiler
/// emits FMA vector code; per-element arithmetic is unchanged (each
/// output lane sees exactly one fused `y[i] + a * x[i]` per call), so
/// this is bitwise identical to the scalar loop.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let n8 = y.len() - y.len() % 8;
    let (y8, y_tail) = y.split_at_mut(n8);
    let (x8, x_tail) = x.split_at(n8);
    for (yc, xc) in y8.chunks_exact_mut(8).zip(x8.chunks_exact(8)) {
        for i in 0..8 {
            yc[i] += a * xc[i];
        }
    }
    for (yv, &xv) in y_tail.iter_mut().zip(x_tail) {
        *yv += a * xv;
    }
}

/// Dot product over 8 fixed accumulator lanes (vectorizable, and
/// deterministic: the lane-combine order never changes).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() - a.len() % 8;
    let mut lanes = [0.0f32; 8];
    for (ac, bc) in a[..n8].chunks_exact(8).zip(b[..n8].chunks_exact(8)) {
        for i in 0..8 {
            lanes[i] += ac[i] * bc[i];
        }
    }
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    for (&x, &y) in a[n8..].iter().zip(&b[n8..]) {
        s += x * y;
    }
    s
}

/// `y[b, n] = x[b, m] @ w[m, n]`, written into `y`.
///
/// Loop order is `i-k-j` (sample, contraction, output) with a row-axpy
/// inner loop — both operand reads are unit-stride — and samples are
/// blocked so each block re-reads the weight rows while they are hot.
/// Accumulation order per output element is `k`-ascending, identical to
/// [`naive::matmul`] (bitwise).
pub fn matmul_into(x: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * n);
    y.fill(0.0);
    let mut i0 = 0usize;
    while i0 < b {
        let i1 = (i0 + BLOCK).min(b);
        for k in 0..m {
            let wrow = &w[k * n..(k + 1) * n];
            for i in i0..i1 {
                let xv = x[i * m + k];
                if xv != 0.0 {
                    axpy(&mut y[i * n..(i + 1) * n], wrow, xv);
                }
            }
        }
        i0 = i1;
    }
}

/// Allocating wrapper over [`matmul_into`].
pub fn matmul(x: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; b * n];
    matmul_into(x, w, &mut y, b, m, n);
    y
}

/// `y[b, m] = g[b, n] @ w^T` where `w` is `[m, n]`, written into `y`.
/// Each output is a unit-stride [`dot`] of a `g` row with a `w` row.
pub fn matmul_nt_into(g: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * m);
    for i in 0..b {
        let grow = &g[i * n..(i + 1) * n];
        let yrow = &mut y[i * m..(i + 1) * m];
        for (k, yv) in yrow.iter_mut().enumerate() {
            *yv = dot(grow, &w[k * n..(k + 1) * n]);
        }
    }
}

/// Allocating wrapper over [`matmul_nt_into`].
pub fn matmul_nt(g: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; b * m];
    matmul_nt_into(g, w, &mut y, b, m, n);
    y
}

/// `dw[m, n] = x^T[m, b] @ g[b, n]` where `x` is `[b, m]`, written into
/// `dw`. Output rows are blocked so a block of `dw` stays hot across the
/// whole batch sweep; per-element accumulation stays `i`-ascending
/// (bitwise identical to [`naive::matmul_tn`]).
pub fn matmul_tn_into(x: &[f32], g: &[f32], dw: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(dw.len(), m * n);
    dw.fill(0.0);
    let mut k0 = 0usize;
    while k0 < m {
        let k1 = (k0 + BLOCK).min(m);
        for i in 0..b {
            let grow = &g[i * n..(i + 1) * n];
            for k in k0..k1 {
                let xv = x[i * m + k];
                if xv != 0.0 {
                    axpy(&mut dw[k * n..(k + 1) * n], grow, xv);
                }
            }
        }
        k0 = k1;
    }
}

/// Allocating wrapper over [`matmul_tn_into`].
pub fn matmul_tn(x: &[f32], g: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; m * n];
    matmul_tn_into(x, g, &mut dw, b, m, n);
    dw
}

/// Column sums `db[n] = sum_b g[b, n]`, written into `db`.
pub fn colsum_into(g: &[f32], db: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(db.len(), n);
    db.fill(0.0);
    for i in 0..b {
        axpy(db, &g[i * n..(i + 1) * n], 1.0);
    }
}

/// Allocating wrapper over [`colsum_into`].
pub fn colsum(g: &[f32], b: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    colsum_into(g, &mut db, b, n);
    db
}

/// Per-row dot products of two `[b, n]` matrices, written into `out[b]`.
pub fn rowdot_into(a: &[f32], c: &[f32], out: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(a.len(), b * n);
    debug_assert_eq!(c.len(), b * n);
    debug_assert_eq!(out.len(), b);
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&a[i * n..(i + 1) * n], &c[i * n..(i + 1) * n]);
    }
}

/// Allocating wrapper over [`rowdot_into`].
pub fn rowdot(a: &[f32], c: &[f32], b: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b];
    rowdot_into(a, c, &mut out, b, n);
    out
}

/// The original scalar kernels, kept byte-for-byte as correctness
/// oracles for the vectorized tier. Used by the `linalg` property tests
/// and `benches/kernels.rs` (speedup reporting); not part of the compute
/// path.
pub mod naive {
    /// `y[b, n] = x[b, m] @ w[m, n]` (accumulates into zeroed output).
    pub fn matmul(x: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), b * m);
        debug_assert_eq!(w.len(), m * n);
        let mut y = vec![0.0f32; b * n];
        for i in 0..b {
            let xrow = &x[i * m..(i + 1) * m];
            let yrow = &mut y[i * n..(i + 1) * n];
            for (k, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[k * n..(k + 1) * n];
                for (yj, &wj) in yrow.iter_mut().zip(wrow) {
                    *yj += xv * wj;
                }
            }
        }
        y
    }

    /// `y[b, m] = g[b, n] @ w^T` where `w` is `[m, n]`.
    pub fn matmul_nt(g: &[f32], w: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(g.len(), b * n);
        debug_assert_eq!(w.len(), m * n);
        let mut y = vec![0.0f32; b * m];
        for i in 0..b {
            let grow = &g[i * n..(i + 1) * n];
            let yrow = &mut y[i * m..(i + 1) * m];
            for k in 0..m {
                let wrow = &w[k * n..(k + 1) * n];
                let mut acc = 0.0f32;
                for (gv, wv) in grow.iter().zip(wrow) {
                    acc += gv * wv;
                }
                yrow[k] = acc;
            }
        }
        y
    }

    /// `dw[m, n] = x^T[m, b] @ g[b, n]` where `x` is `[b, m]`.
    pub fn matmul_tn(x: &[f32], g: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), b * m);
        debug_assert_eq!(g.len(), b * n);
        let mut dw = vec![0.0f32; m * n];
        for i in 0..b {
            let xrow = &x[i * m..(i + 1) * m];
            let grow = &g[i * n..(i + 1) * n];
            for (k, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let drow = &mut dw[k * n..(k + 1) * n];
                for (dv, &gv) in drow.iter_mut().zip(grow) {
                    *dv += xv * gv;
                }
            }
        }
        dw
    }

    /// Column sums: `db[n] = sum_b g[b, n]`.
    pub fn colsum(g: &[f32], b: usize, n: usize) -> Vec<f32> {
        let mut db = vec![0.0f32; n];
        for i in 0..b {
            for (dv, &gv) in db.iter_mut().zip(&g[i * n..(i + 1) * n]) {
                *dv += gv;
            }
        }
        db
    }

    /// Per-row dot products of two `[b, n]` matrices -> `[b]`.
    pub fn rowdot(a: &[f32], c: &[f32], b: usize, n: usize) -> Vec<f32> {
        (0..b)
            .map(|i| {
                a[i * n..(i + 1) * n]
                    .iter()
                    .zip(&c[i * n..(i + 1) * n])
                    .map(|(x, y)| x * y)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let y = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_identities() {
        // For y = x@w: dX = dY@w^T and dW = x^T@dY must satisfy the
        // trace identity <dY, x@w_dir> = <matmul_tn(x,dY), w_dir>.
        let x = [0.5f32, -1.0, 2.0, 0.0, 1.0, -0.5];
        let w = [1.0f32, 0.0, -1.0, 2.0, 0.5, 1.5];
        let dy = [1.0f32, -1.0, 0.5, 2.0];
        let (b, m, n) = (2, 3, 2);
        let dx = matmul_nt(&dy, &w, b, m, n);
        let dw = matmul_tn(&x, &dy, b, m, n);
        // directional check
        let xdir = [0.1f32, 0.2, -0.1, 0.3, -0.2, 0.05];
        let wdir = [0.2f32, -0.3, 0.1, 0.4, -0.1, 0.2];
        let lhs: f32 = matmul(&xdir, &w, b, m, n).iter().zip(&dy).map(|(a, g)| a * g).sum();
        let rhs: f32 = dx.iter().zip(&xdir).map(|(a, d)| a * d).sum();
        assert!((lhs - rhs).abs() < 1e-5);
        let lhs2: f32 = matmul(&x, &wdir, b, m, n).iter().zip(&dy).map(|(a, g)| a * g).sum();
        let rhs2: f32 = dw.iter().zip(&wdir).map(|(a, d)| a * d).sum();
        assert!((lhs2 - rhs2).abs() < 1e-5);
    }

    #[test]
    fn colsum_and_rowdot() {
        let g = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(colsum(&g, 2, 2), vec![4.0, 6.0]);
        assert_eq!(rowdot(&g, &g, 2, 2), vec![5.0, 25.0]);
    }

    fn rand_vec(rng: &mut Rng, n: usize, zeros: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if zeros && rng.bernoulli(0.2) {
                    0.0
                } else {
                    rng.next_gaussian() as f32
                }
            })
            .collect()
    }

    fn close(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-6f32 * (1.0 + x.abs().max(y.abs()));
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    /// Property: every vectorized kernel matches its naive oracle within
    /// 1e-6 relative over random shapes — including odd (non-multiple-
    /// of-8 / non-multiple-of-block) dimensions and the empty batch.
    #[test]
    fn prop_vectorized_matches_naive_oracles() {
        let mut rng = Rng::new(0x51AD);
        for case in 0..200 {
            let b = (rng.below(70)) as usize; // 0 included: empty batch
            let m = 1 + rng.below(45) as usize;
            let n = 1 + rng.below(37) as usize;
            let x = rand_vec(&mut rng, b * m, true);
            let w = rand_vec(&mut rng, m * n, false);
            let g = rand_vec(&mut rng, b * n, true);

            // matmul (bitwise: same per-element accumulation order)
            assert_eq!(
                matmul(&x, &w, b, m, n),
                naive::matmul(&x, &w, b, m, n),
                "case {case}: matmul ({b},{m},{n})"
            );
            // matmul_tn (bitwise for the same reason)
            assert_eq!(
                matmul_tn(&x, &g, b, m, n),
                naive::matmul_tn(&x, &g, b, m, n),
                "case {case}: matmul_tn ({b},{m},{n})"
            );
            // lane-summed kernels: 1e-6 relative
            close(
                &matmul_nt(&g, &w, b, m, n),
                &naive::matmul_nt(&g, &w, b, m, n),
                &format!("case {case}: matmul_nt ({b},{m},{n})"),
            );
            close(
                &colsum(&g, b, n),
                &naive::colsum(&g, b, n),
                &format!("case {case}: colsum ({b},{n})"),
            );
            let a2 = rand_vec(&mut rng, b * n, false);
            close(
                &rowdot(&g, &a2, b, n),
                &naive::rowdot(&g, &a2, b, n),
                &format!("case {case}: rowdot ({b},{n})"),
            );
        }
    }

    #[test]
    fn axpy_and_dot_odd_lengths() {
        let mut rng = Rng::new(7);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 65] {
            let x = rand_vec(&mut rng, len, false);
            let mut y = rand_vec(&mut rng, len, false);
            let y0 = y.clone();
            axpy(&mut y, &x, 0.5);
            for i in 0..len {
                assert_eq!(y[i], y0[i] + 0.5 * x[i], "axpy len {len} idx {i}");
            }
            let serial: f32 = x.iter().zip(&y0).map(|(a, b)| a * b).sum();
            let d = dot(&x, &y0);
            assert!((d - serial).abs() <= 1e-5 * (1.0 + serial.abs()), "dot len {len}");
        }
    }

    #[test]
    fn into_variants_overwrite_stale_output() {
        // _into targets are reused scratch buffers: stale contents must
        // not leak into results.
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [5.0f32, 6.0, 7.0, 8.0];
        let mut y = vec![99.0f32; 4];
        matmul_into(&x, &w, &mut y, 2, 2, 2);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
        let mut dw = vec![-3.0f32; 4];
        matmul_tn_into(&x, &w, &mut dw, 2, 2, 2);
        assert_eq!(dw, naive::matmul_tn(&x, &w, 2, 2, 2));
        let mut db = vec![42.0f32; 2];
        colsum_into(&w, &mut db, 2, 2);
        assert_eq!(db, vec![12.0, 14.0]);
    }
}
