//! NEON microkernels (aarch64) — 4-lane twins of the AVX2 tier.
//!
//! Same structure as [`super::x86`], scaled to 128-bit registers:
//!
//! * [`matmul_into`] / [`matmul_tn_into`] — 4-row × 4-column `vfmaq`
//!   register tiles (4 accumulators + 1 strip in the 32 `v` registers,
//!   each strip load reused four times), contraction `k`- resp.
//!   `i`-ascending.
//! * [`matmul_nt_into`] / [`rowdot_into`] / [`dot`] — one 4-lane FMA
//!   accumulator per output, reduced with `vaddvq_f32` (the fixed
//!   `faddp` pairwise tree).
//! * [`axpy`], [`colsum_into`], [`relu_mask`], [`dequant_row`],
//!   [`embed_concat_fwd`] — 4-wide streaming loops.
//!
//! Remainders split as `n4 = n - n % 4` (`b4` for tile rows) with the
//! naive oracle's scalar loop on the tail — no alignment or padding
//! assumptions. Determinism story is identical to the AVX2 module:
//! bitwise within the mode (fixed contraction and reduction order),
//! ≤1e-6 vs scalar for the FMA kernels, and bitwise across modes for
//! [`colsum_into`] (pure `vaddq` in scalar order),
//! [`embed_concat_fwd`] (pure copy), [`relu_mask`] (`vcleq`+`vbicq`
//! zero-mask, NaN keeps the gradient like the scalar branch) and
//! [`dequant_row`] (explicit `vmulq`+`vaddq`, never fused).

// The one place in the crate (together with `x86.rs`) where unsafe is
// permitted; `cowclip-lint`'s unsafe-confinement rule enforces that.
#![allow(unsafe_code)]

use core::arch::aarch64::*;

use super::Kernels;

/// The NEON vtable. Only handed out by `super::resolve` after
/// `is_aarch64_feature_detected!("neon")` reports true.
pub static NEON: Kernels = Kernels {
    name: "neon",
    axpy,
    dot,
    matmul_into,
    matmul_nt_into,
    matmul_tn_into,
    colsum_into,
    rowdot_into,
    relu_mask,
    embed_concat_fwd,
    dequant_row,
};

/// `y += a * x`, 4 lanes at a time.
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    // Safety: reachable only through the `NEON` vtable, which is
    // installed strictly after runtime NEON detection.
    unsafe { axpy_neon(y, x, a) }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_neon(y: &mut [f32], x: &[f32], a: f32) {
    let n = y.len();
    let n4 = n - n % 4;
    let av = vdupq_n_f32(a);
    let mut k = 0;
    while k < n4 {
        let yv = vld1q_f32(y.as_ptr().add(k));
        let xv = vld1q_f32(x.as_ptr().add(k));
        vst1q_f32(y.as_mut_ptr().add(k), vfmaq_f32(yv, av, xv));
        k += 4;
    }
    while k < n {
        y[k] += a * x[k];
        k += 1;
    }
}

/// Unit-stride dot product: one 4-lane FMA accumulator + scalar tail.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { dot_neon(a, b) }
}

#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let n4 = n - n % 4;
    let mut acc = vdupq_n_f32(0.0);
    let mut k = 0;
    while k < n4 {
        let av = vld1q_f32(a.as_ptr().add(k));
        let bv = vld1q_f32(b.as_ptr().add(k));
        acc = vfmaq_f32(acc, av, bv);
        k += 4;
    }
    let mut s = vaddvq_f32(acc);
    while k < n {
        s += a[k] * b[k];
        k += 1;
    }
    s
}

/// `y[b,n] = x[b,m] @ w[m,n]`: 4×4 FMA register tile, `k`-ascending.
pub fn matmul_into(x: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * n);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { matmul_neon(x, w, y, b, m, n) }
}

#[target_feature(enable = "neon")]
unsafe fn matmul_neon(x: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    let n4 = n - n % 4;
    let b4 = b - b % 4;
    let mut i = 0;
    while i < b4 {
        let x0 = x.as_ptr().add(i * m);
        let x1 = x.as_ptr().add((i + 1) * m);
        let x2 = x.as_ptr().add((i + 2) * m);
        let x3 = x.as_ptr().add((i + 3) * m);
        let mut j = 0;
        while j < n4 {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut acc2 = vdupq_n_f32(0.0);
            let mut acc3 = vdupq_n_f32(0.0);
            let mut wp = w.as_ptr().add(j);
            for k in 0..m {
                let wv = vld1q_f32(wp);
                acc0 = vfmaq_f32(acc0, vdupq_n_f32(*x0.add(k)), wv);
                acc1 = vfmaq_f32(acc1, vdupq_n_f32(*x1.add(k)), wv);
                acc2 = vfmaq_f32(acc2, vdupq_n_f32(*x2.add(k)), wv);
                acc3 = vfmaq_f32(acc3, vdupq_n_f32(*x3.add(k)), wv);
                wp = wp.add(n);
            }
            vst1q_f32(y.as_mut_ptr().add(i * n + j), acc0);
            vst1q_f32(y.as_mut_ptr().add((i + 1) * n + j), acc1);
            vst1q_f32(y.as_mut_ptr().add((i + 2) * n + j), acc2);
            vst1q_f32(y.as_mut_ptr().add((i + 3) * n + j), acc3);
            j += 4;
        }
        while j < n {
            for r in 0..4 {
                let xr = x.as_ptr().add((i + r) * m);
                let mut s = 0.0f32;
                for k in 0..m {
                    s += *xr.add(k) * w[k * n + j];
                }
                y[(i + r) * n + j] = s;
            }
            j += 1;
        }
        i += 4;
    }
    while i < b {
        let xr = x.as_ptr().add(i * m);
        let mut j = 0;
        while j < n4 {
            let mut acc = vdupq_n_f32(0.0);
            let mut wp = w.as_ptr().add(j);
            for k in 0..m {
                acc = vfmaq_f32(acc, vdupq_n_f32(*xr.add(k)), vld1q_f32(wp));
                wp = wp.add(n);
            }
            vst1q_f32(y.as_mut_ptr().add(i * n + j), acc);
            j += 4;
        }
        while j < n {
            let mut s = 0.0f32;
            for k in 0..m {
                s += *xr.add(k) * w[k * n + j];
            }
            y[i * n + j] = s;
            j += 1;
        }
        i += 1;
    }
}

/// `y[b,m] = g[b,n] @ w[m,n]^T`: one 4-lane dot per output element.
pub fn matmul_nt_into(g: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * m);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { matmul_nt_neon(g, w, y, b, m, n) }
}

#[target_feature(enable = "neon")]
unsafe fn matmul_nt_neon(g: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    for i in 0..b {
        let grow = &g[i * n..(i + 1) * n];
        let yrow = &mut y[i * m..(i + 1) * m];
        for (k, yv) in yrow.iter_mut().enumerate() {
            *yv = dot_neon(grow, &w[k * n..(k + 1) * n]);
        }
    }
}

/// `dw[m,n] = x[b,m]^T @ g[b,n]`: the 4×4 tile with roles swapped.
pub fn matmul_tn_into(x: &[f32], g: &[f32], dw: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(dw.len(), m * n);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { matmul_tn_neon(x, g, dw, b, m, n) }
}

#[target_feature(enable = "neon")]
unsafe fn matmul_tn_neon(x: &[f32], g: &[f32], dw: &mut [f32], b: usize, m: usize, n: usize) {
    let n4 = n - n % 4;
    let m4 = m - m % 4;
    let mut k = 0;
    while k < m4 {
        let mut j = 0;
        while j < n4 {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut acc2 = vdupq_n_f32(0.0);
            let mut acc3 = vdupq_n_f32(0.0);
            for i in 0..b {
                let gv = vld1q_f32(g.as_ptr().add(i * n + j));
                let xp = x.as_ptr().add(i * m + k);
                acc0 = vfmaq_f32(acc0, vdupq_n_f32(*xp), gv);
                acc1 = vfmaq_f32(acc1, vdupq_n_f32(*xp.add(1)), gv);
                acc2 = vfmaq_f32(acc2, vdupq_n_f32(*xp.add(2)), gv);
                acc3 = vfmaq_f32(acc3, vdupq_n_f32(*xp.add(3)), gv);
            }
            vst1q_f32(dw.as_mut_ptr().add(k * n + j), acc0);
            vst1q_f32(dw.as_mut_ptr().add((k + 1) * n + j), acc1);
            vst1q_f32(dw.as_mut_ptr().add((k + 2) * n + j), acc2);
            vst1q_f32(dw.as_mut_ptr().add((k + 3) * n + j), acc3);
            j += 4;
        }
        while j < n {
            for r in 0..4 {
                let mut s = 0.0f32;
                for i in 0..b {
                    s += x[i * m + k + r] * g[i * n + j];
                }
                dw[(k + r) * n + j] = s;
            }
            j += 1;
        }
        k += 4;
    }
    while k < m {
        let mut j = 0;
        while j < n4 {
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..b {
                let gv = vld1q_f32(g.as_ptr().add(i * n + j));
                acc = vfmaq_f32(acc, vdupq_n_f32(x[i * m + k]), gv);
            }
            vst1q_f32(dw.as_mut_ptr().add(k * n + j), acc);
            j += 4;
        }
        while j < n {
            let mut s = 0.0f32;
            for i in 0..b {
                s += x[i * m + k] * g[i * n + j];
            }
            dw[k * n + j] = s;
            j += 1;
        }
        k += 1;
    }
}

/// `db[n] = sum_i g[i,n]`: pure `vaddq` in the scalar fold's exact
/// `i`-ascending order — bitwise identical to the scalar tier.
pub fn colsum_into(g: &[f32], db: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(db.len(), n);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { colsum_neon(g, db, b, n) }
}

#[target_feature(enable = "neon")]
unsafe fn colsum_neon(g: &[f32], db: &mut [f32], b: usize, n: usize) {
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        let mut acc = vdupq_n_f32(0.0);
        for i in 0..b {
            acc = vaddq_f32(acc, vld1q_f32(g.as_ptr().add(i * n + j)));
        }
        vst1q_f32(db.as_mut_ptr().add(j), acc);
        j += 4;
    }
    while j < n {
        let mut s = 0.0f32;
        for i in 0..b {
            s += g[i * n + j];
        }
        db[j] = s;
        j += 1;
    }
}

/// `out[i] = dot(a[i,:], c[i,:])` over `[b, n]` operands.
pub fn rowdot_into(a: &[f32], c: &[f32], out: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(a.len(), b * n);
    debug_assert_eq!(c.len(), b * n);
    debug_assert_eq!(out.len(), b);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { rowdot_neon(a, c, out, b, n) }
}

#[target_feature(enable = "neon")]
unsafe fn rowdot_neon(a: &[f32], c: &[f32], out: &mut [f32], b: usize, n: usize) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot_neon(&a[i * n..(i + 1) * n], &c[i * n..(i + 1) * n]);
    }
}

/// Zero `dy` where `pre <= 0.0`; NaN pre-activations keep the gradient,
/// exactly like the scalar branch — bitwise identical across modes.
pub fn relu_mask(dy: &mut [f32], pre: &[f32]) {
    debug_assert_eq!(dy.len(), pre.len());
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { relu_mask_neon(dy, pre) }
}

#[target_feature(enable = "neon")]
unsafe fn relu_mask_neon(dy: &mut [f32], pre: &[f32]) {
    let n = dy.len();
    let n4 = n - n % 4;
    let zero = vdupq_n_f32(0.0);
    let mut k = 0;
    while k < n4 {
        let p = vld1q_f32(pre.as_ptr().add(k));
        let d = vld1q_f32(dy.as_ptr().add(k));
        // mask lanes are all-ones where p <= 0 (false for NaN);
        // bic keeps d where the mask is clear.
        let mask = vcleq_f32(p, zero);
        let kept = vbicq_u32(vreinterpretq_u32_f32(d), mask);
        vst1q_f32(dy.as_mut_ptr().add(k), vreinterpretq_f32_u32(kept));
        k += 4;
    }
    while k < n {
        if pre[k] <= 0.0 {
            dy[k] = 0.0;
        }
        k += 1;
    }
}

/// Fused embedding gather + `x0` concat: 4-wide row copies straight
/// into the concat layout. Pure copy — bitwise identical across modes.
#[allow(clippy::too_many_arguments)]
pub fn embed_concat_fwd(
    table: &[f32],
    ids: &[i32],
    dense_x: &[f32],
    b: usize,
    f: usize,
    d: usize,
    nd: usize,
    x0: &mut [f32],
) {
    let d0 = f * d + nd;
    debug_assert_eq!(ids.len(), b * f);
    debug_assert_eq!(dense_x.len(), b * nd);
    debug_assert_eq!(x0.len(), b * d0);
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { embed_concat_neon(table, ids, dense_x, b, f, d, nd, x0) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn embed_concat_neon(
    table: &[f32],
    ids: &[i32],
    dense_x: &[f32],
    b: usize,
    f: usize,
    d: usize,
    nd: usize,
    x0: &mut [f32],
) {
    let d0 = f * d + nd;
    let d4 = d - d % 4;
    for i in 0..b {
        let row = i * d0;
        for (j, &id) in ids[i * f..(i + 1) * f].iter().enumerate() {
            let src = table.as_ptr().add(id as usize * d);
            let dst = x0.as_mut_ptr().add(row + j * d);
            let mut t = 0;
            while t < d4 {
                vst1q_f32(dst.add(t), vld1q_f32(src.add(t)));
                t += 4;
            }
            while t < d {
                *dst.add(t) = *src.add(t);
                t += 1;
            }
        }
        if nd > 0 {
            x0[row + f * d..row + d0].copy_from_slice(&dense_x[i * nd..(i + 1) * nd]);
        }
    }
}

/// Serving's fused dequantize: widen 4 `u16` codes through `u32` to
/// `f32`, then multiply-then-add (two roundings, deliberately *not*
/// fused) — bitwise identical to the scalar `min + c as f32 * step`.
pub fn dequant_row(codes: &[u16], min: f32, step: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    // Safety: reachable only through the `NEON` vtable (see `axpy`).
    unsafe { dequant_row_neon(codes, min, step, out) }
}

#[target_feature(enable = "neon")]
unsafe fn dequant_row_neon(codes: &[u16], min: f32, step: f32, out: &mut [f32]) {
    let n = codes.len();
    let n4 = n - n % 4;
    let minv = vdupq_n_f32(min);
    let stepv = vdupq_n_f32(step);
    let mut k = 0;
    while k < n4 {
        let raw = vld1_u16(codes.as_ptr().add(k));
        let wide = vcvtq_f32_u32(vmovl_u16(raw));
        vst1q_f32(out.as_mut_ptr().add(k), vaddq_f32(minv, vmulq_f32(wide, stepv)));
        k += 4;
    }
    while k < n {
        out[k] = min + codes[k] as f32 * step;
        k += 1;
    }
}
