//! Explicit SIMD microkernels with one-shot runtime dispatch.
//!
//! PR 5 left the hot path allocation-free but still hostage to whatever
//! the compiler auto-vectorizes; this module makes the instruction
//! selection explicit. Three tiers:
//!
//! * **scalar** — the PR-5 blocked kernels in [`super::linalg`] and the
//!   fused passes in [`super::layers`], unchanged. Always available,
//!   always the fallback, and the only tier `linalg::naive` needs to be
//!   compared against bitwise.
//! * **avx2** (`x86_64`, requires AVX2 **and** FMA) — 8-lane `f32`
//!   tiles in [`x86`].
//! * **neon** (`aarch64`) — 4-lane `f32` twins in [`neon`].
//!
//! ## Dispatch model
//!
//! Selection happens **once per process**: [`active`] resolves the
//! `COWCLIP_KERNEL` environment variable (`auto` | `scalar` | `avx2` |
//! `neon`, default `auto`) through [`resolve`] into a `&'static`
//! [`Kernels`] vtable and caches it in a `OnceLock`; the `--kernel` CLI
//! flag calls [`select`] before the first model is built and wins if it
//! runs first. Every [`super::ReferenceModel`] clone, every worker
//! thread, every param shard and every serving scorer then calls
//! through the *same* function pointers for the lifetime of the
//! process. That is the whole determinism argument: within a fixed
//! mode there is no per-call, per-thread or per-size re-dispatch, so
//! any thread/shard count replays the identical instruction stream and
//! stays bitwise-invariant — the same property the scalar tier had,
//! now per mode.
//!
//! Requesting a mode the host cannot run (`neon` on x86_64, `avx2`
//! without the CPUID bits) falls back to **scalar**, never to UB: the
//! arch vtables are only reachable behind `is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!` checks in [`resolve`].
//!
//! ## Precision contract (why two gates)
//!
//! The FMA-based kernels (`matmul*`, `dot`, `axpy`, `rowdot`) contract
//! `a*b + c` in one rounding where the scalar tier rounds twice, so
//! SIMD-vs-scalar results differ in the low bits; cross-mode parity is
//! therefore gated at ≤1e-6 (relative) by `rust/tests/kernel_parity.rs`
//! and the model-level suites. Four kernels are *bitwise* identical to
//! scalar by construction and keep the serving exactness story intact:
//! `colsum_into` (pure lane adds, same i-ascending order, one rounding
//! each — identical to the scalar `axpy(db, row, 1.0)` fold),
//! `embed_concat_fwd` (pure copy), `dequant_row` (explicit
//! multiply-then-add, never FMA, matching `min + code as f32 * step`),
//! and `relu_mask` (a zero-mask with ordered-quiet `<= 0.0` compare —
//! NaN lanes survive exactly like the scalar branch).
//!
//! ## Safety confinement
//!
//! This module subtree is the **only** place in the crate where
//! `unsafe` is permitted: the crate root carries
//! `#![deny(unsafe_code)]`, the arch submodules opt back in with a
//! scoped `#![allow(unsafe_code)]`, and `cowclip-lint`'s
//! `unsafe-confinement` rule fails CI if the token appears anywhere
//! outside `reference/simd/`. Tile shapes and remainder handling are
//! documented in the arch modules themselves.

use std::fmt;
use std::str::FromStr;
use std::sync::OnceLock;

use super::{layers, linalg};

#[cfg(target_arch = "x86_64")]
pub mod x86;

#[cfg(target_arch = "aarch64")]
pub mod neon;

/// Requested dispatch mode (`COWCLIP_KERNEL` / `--kernel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Pick the widest tier the host supports (the default).
    Auto,
    /// Force the PR-5 blocked scalar kernels.
    Scalar,
    /// AVX2+FMA tier; falls back to scalar off-x86 or without the bits.
    Avx2,
    /// NEON tier; falls back to scalar off-aarch64.
    Neon,
}

impl FromStr for KernelMode {
    type Err = String;

    fn from_str(s: &str) -> Result<KernelMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelMode::Auto),
            "scalar" => Ok(KernelMode::Scalar),
            "avx2" => Ok(KernelMode::Avx2),
            "neon" => Ok(KernelMode::Neon),
            other => Err(format!(
                "unknown kernel mode {other:?} (expected auto|scalar|avx2|neon)"
            )),
        }
    }
}

/// The kernel vtable: one function pointer per hot-path primitive,
/// resolved once at startup and threaded through
/// [`super::ReferenceModel`] and the serving tier. Shapes and layouts
/// are exactly those of the [`super::linalg`] / [`super::layers`]
/// scalar forms the pointers default to.
pub struct Kernels {
    /// Tier name as reported by logs, benches and the fallback tests.
    pub name: &'static str,
    /// `y += a * x`.
    pub axpy: fn(&mut [f32], &[f32], f32),
    /// Unit-stride dot product.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y[b,n] = x[b,m] @ w[m,n]`.
    pub matmul_into: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    /// `y[b,m] = g[b,n] @ w[m,n]^T`.
    pub matmul_nt_into: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    /// `dw[m,n] = x[b,m]^T @ g[b,n]`.
    pub matmul_tn_into: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    /// `db[n] = sum_i g[i,n]` (bitwise equal to scalar in every tier).
    pub colsum_into: fn(&[f32], &mut [f32], usize, usize),
    /// `out[i] = dot(a[i,:], c[i,:])` over `[b, n]` operands.
    pub rowdot_into: fn(&[f32], &[f32], &mut [f32], usize, usize),
    /// Zero `dy` where the cached pre-activation is `<= 0.0`
    /// (bitwise equal to scalar in every tier, NaN included).
    pub relu_mask: fn(&mut [f32], &[f32]),
    /// Fused embedding gather + `x0` concat
    /// (`table, ids, dense_x, b, f, d, nd, x0`; pure copy, bitwise).
    pub embed_concat_fwd: fn(&[f32], &[i32], &[f32], usize, usize, usize, usize, &mut [f32]),
    /// Serving's fused dequantize: `out[j] = min + codes[j] as f32 * step`
    /// (explicit mul-then-add, bitwise equal to scalar in every tier).
    pub dequant_row: fn(&[u16], f32, f32, &mut [f32]),
}

impl fmt::Debug for Kernels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Kernels").field("name", &self.name).finish()
    }
}

impl Kernels {
    /// Allocating wrapper over `matmul_tn_into` (backward-pass call
    /// sites where the gradient payload escapes the step).
    pub fn matmul_tn(&self, x: &[f32], g: &[f32], b: usize, m: usize, n: usize) -> Vec<f32> {
        let mut dw = vec![0.0f32; m * n];
        (self.matmul_tn_into)(x, g, &mut dw, b, m, n);
        dw
    }

    /// Allocating wrapper over `colsum_into` (escaping bias gradients).
    pub fn colsum(&self, g: &[f32], b: usize, n: usize) -> Vec<f32> {
        let mut db = vec![0.0f32; n];
        (self.colsum_into)(g, &mut db, b, n);
        db
    }
}

fn dequant_row_scalar(codes: &[u16], min: f32, step: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = min + c as f32 * step;
    }
}

/// The scalar tier: the PR-5 blocked kernels, unchanged, as a vtable.
static SCALAR: Kernels = Kernels {
    name: "scalar",
    axpy: linalg::axpy,
    dot: linalg::dot,
    matmul_into: linalg::matmul_into,
    matmul_nt_into: linalg::matmul_nt_into,
    matmul_tn_into: linalg::matmul_tn_into,
    colsum_into: linalg::colsum_into,
    rowdot_into: linalg::rowdot_into,
    relu_mask: layers::relu_mask,
    embed_concat_fwd: layers::embed_concat_fwd,
    dequant_row: dequant_row_scalar,
};

/// The scalar vtable — the cross-mode parity baseline for tests and
/// the `speedup vs scalar` denominator for benches.
pub fn scalar() -> &'static Kernels {
    &SCALAR
}

/// Resolve a requested mode against what this host can actually run.
/// Unsupported requests degrade to scalar — never to UB: the arch
/// vtables are only returned behind their feature-detection checks.
pub fn resolve(mode: KernelMode) -> &'static Kernels {
    match mode {
        KernelMode::Scalar => &SCALAR,
        KernelMode::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return &x86::AVX2;
            }
            &SCALAR
        }
        KernelMode::Neon => {
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &neon::NEON;
            }
            &SCALAR
        }
        KernelMode::Auto => {
            #[cfg(target_arch = "x86_64")]
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return &x86::AVX2;
            }
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &neon::NEON;
            }
            &SCALAR
        }
    }
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide vtable. First call wins: either [`select`] (the
/// `--kernel` CLI flag) or this function's `COWCLIP_KERNEL` environment
/// lookup (default `auto`); every later call returns the same pointer,
/// so a running process never changes instruction streams.
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(|| {
        let mode = match std::env::var("COWCLIP_KERNEL") {
            Ok(v) => v.parse().unwrap_or_else(|e: String| {
                eprintln!("cowclip: {e}; falling back to auto dispatch");
                KernelMode::Auto
            }),
            Err(_) => KernelMode::Auto,
        };
        resolve(mode)
    })
}

/// Pin the process-wide vtable to an explicit mode (the `--kernel`
/// flag). A no-op if [`active`] already resolved — call it before
/// building models. Returns the vtable that is actually in effect.
pub fn select(mode: KernelMode) -> &'static Kernels {
    ACTIVE.get_or_init(|| resolve(mode))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_case_insensitively_and_rejects_junk() {
        assert_eq!("AVX2".parse::<KernelMode>().unwrap(), KernelMode::Avx2);
        assert_eq!("auto".parse::<KernelMode>().unwrap(), KernelMode::Auto);
        assert_eq!("Scalar".parse::<KernelMode>().unwrap(), KernelMode::Scalar);
        assert_eq!("neon".parse::<KernelMode>().unwrap(), KernelMode::Neon);
        assert!("sse9".parse::<KernelMode>().is_err());
    }

    #[test]
    fn dispatch_falls_back_cleanly() {
        // A mode the host cannot run must resolve to the scalar tier —
        // never panic, never hand out an undetected arch vtable.
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(resolve(KernelMode::Neon).name, "scalar");
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(resolve(KernelMode::Avx2).name, "scalar");
        assert_eq!(resolve(KernelMode::Scalar).name, "scalar");
        // Auto resolves to *something* runnable, and resolution is stable.
        let a = resolve(KernelMode::Auto);
        let b = resolve(KernelMode::Auto);
        assert!(std::ptr::eq(a, b));
    }

    #[test]
    fn scalar_vtable_points_at_linalg() {
        // The scalar tier is the PR-5 kernels, not re-implementations:
        // spot-check a couple of pointers and one computed value.
        let k = scalar();
        assert_eq!(k.name, "scalar");
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!((k.dot)(&a, &b), linalg::dot(&a, &b));
        let mut out = [0.0f32; 3];
        (k.dequant_row)(&[0u16, 1, 65535], -1.0, 0.5, &mut out);
        assert_eq!(out, [-1.0, -0.5, -1.0 + 65535.0 * 0.5]);
    }

    #[test]
    fn allocating_helpers_match_into_forms() {
        let k = scalar();
        let (b, m, n) = (3usize, 4usize, 5usize);
        let x: Vec<f32> = (0..b * m).map(|i| i as f32 * 0.3 - 1.0).collect();
        let g: Vec<f32> = (0..b * n).map(|i| i as f32 * 0.2 - 0.7).collect();
        assert_eq!(k.matmul_tn(&x, &g, b, m, n), linalg::matmul_tn(&x, &g, b, m, n));
        assert_eq!(k.colsum(&g, b, n), linalg::colsum(&g, b, n));
    }
}
