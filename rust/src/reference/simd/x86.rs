//! AVX2+FMA microkernels (x86_64).
//!
//! ## Tile shapes
//!
//! * [`matmul_into`] — a 4-row × 8-column register tile: four `ymm`
//!   accumulators, and per contraction step one 8-wide load of a `w`
//!   row strip plus four scalar broadcasts from `x`, combined with
//!   `vfmadd`. 4×8 is chosen to fit comfortably in the 16 `ymm`
//!   registers (4 accumulators + 1 strip + broadcasts) while reusing
//!   each `w` load four times; rows and columns come straight from the
//!   caller's [`super::super::Scratch`] blocks, so no packing buffer is
//!   needed (`m`, `n` are ≤ a few hundred for every model config).
//! * [`matmul_tn_into`] — the same tile with the roles swapped: four
//!   `dw` rows × 8 `g` columns, accumulating `i`-ascending over the
//!   batch.
//! * [`matmul_nt_into`] / [`rowdot_into`] / [`dot`] — one 8-lane FMA
//!   accumulator per output element, reduced by a fixed
//!   `extract/movehl/shuffle` pairwise tree.
//! * [`axpy`], [`colsum_into`], [`relu_mask`], [`dequant_row`],
//!   [`embed_concat_fwd`] — straight 8-wide streaming loops.
//!
//! ## Remainder handling
//!
//! Nothing here requires alignment or padded shapes: every kernel
//! splits its trip count as `n8 = n - n % 8` (`b4 = b - b % 4` for the
//! row dimension of the tiles), runs the vector body to `n8`, and
//! finishes with the same scalar loop the naive oracle uses. The
//! property sweep in `rust/tests/kernel_parity.rs` drives odd sizes and
//! misaligned lengths through every branch.
//!
//! ## Determinism
//!
//! Per output element the contraction order is fixed (`k`- resp.
//! `i`-ascending, lane `l` owning elements `l, l+8, …`, then one fixed
//! pairwise lane reduction), so results are bitwise-reproducible for a
//! given shape on every call, thread and shard — the within-mode
//! invariant. Versus the scalar tier, `vfmadd` contracts `a*b + c`
//! with a single rounding where scalar rounds the product and the sum
//! separately, so FMA kernels differ from scalar in the last bits
//! (cross-mode gate: ≤1e-6 relative). [`colsum_into`],
//! [`embed_concat_fwd`], [`relu_mask`] and [`dequant_row`] perform the
//! same single-rounding operations in the same order as scalar and are
//! bitwise identical across modes.

// The one place in the crate (together with `neon.rs`) where unsafe is
// permitted; `cowclip-lint`'s unsafe-confinement rule enforces that.
#![allow(unsafe_code)]

use core::arch::x86_64::*;

use super::Kernels;

/// The AVX2+FMA vtable. Only handed out by `super::resolve` after
/// `is_x86_feature_detected!("avx2")` and `("fma")` both report true.
pub static AVX2: Kernels = Kernels {
    name: "avx2",
    axpy,
    dot,
    matmul_into,
    matmul_nt_into,
    matmul_tn_into,
    colsum_into,
    rowdot_into,
    relu_mask,
    embed_concat_fwd,
    dequant_row,
};

/// `y += a * x`, 8 lanes at a time.
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    // Safety: reachable only through the `AVX2` vtable, which is
    // installed strictly after runtime AVX2+FMA detection.
    unsafe { axpy_avx2(y, x, a) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(y: &mut [f32], x: &[f32], a: f32) {
    let n = y.len();
    let n8 = n - n % 8;
    let av = _mm256_set1_ps(a);
    let mut k = 0;
    while k < n8 {
        let yv = _mm256_loadu_ps(y.as_ptr().add(k));
        let xv = _mm256_loadu_ps(x.as_ptr().add(k));
        _mm256_storeu_ps(y.as_mut_ptr().add(k), _mm256_fmadd_ps(av, xv, yv));
        k += 8;
    }
    while k < n {
        y[k] += a * x[k];
        k += 1;
    }
}

/// Unit-stride dot product: one 8-lane FMA accumulator + scalar tail.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { dot_avx2(a, b) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let n8 = n - n % 8;
    let mut acc = _mm256_setzero_ps();
    let mut k = 0;
    while k < n8 {
        let av = _mm256_loadu_ps(a.as_ptr().add(k));
        let bv = _mm256_loadu_ps(b.as_ptr().add(k));
        acc = _mm256_fmadd_ps(av, bv, acc);
        k += 8;
    }
    let mut s = hsum8(acc);
    while k < n {
        s += a[k] * b[k];
        k += 1;
    }
    s
}

/// Fixed pairwise horizontal sum of the 8 lanes:
/// `(lo+hi)` quad → `movehl` pair → `shuffle` single.
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let q = _mm_add_ps(lo, hi);
    let p = _mm_add_ps(q, _mm_movehl_ps(q, q));
    let s = _mm_add_ss(p, _mm_shuffle_ps(p, p, 1));
    _mm_cvtss_f32(s)
}

/// `y[b,n] = x[b,m] @ w[m,n]`: 4×8 FMA register tile, `k`-ascending.
pub fn matmul_into(x: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * n);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { matmul_avx2(x, w, y, b, m, n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_avx2(x: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    let n8 = n - n % 8;
    let b4 = b - b % 4;
    let mut i = 0;
    while i < b4 {
        let x0 = x.as_ptr().add(i * m);
        let x1 = x.as_ptr().add((i + 1) * m);
        let x2 = x.as_ptr().add((i + 2) * m);
        let x3 = x.as_ptr().add((i + 3) * m);
        let mut j = 0;
        while j < n8 {
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut acc2 = _mm256_setzero_ps();
            let mut acc3 = _mm256_setzero_ps();
            let mut wp = w.as_ptr().add(j);
            for k in 0..m {
                let wv = _mm256_loadu_ps(wp);
                acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*x0.add(k)), wv, acc0);
                acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*x1.add(k)), wv, acc1);
                acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*x2.add(k)), wv, acc2);
                acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*x3.add(k)), wv, acc3);
                wp = wp.add(n);
            }
            _mm256_storeu_ps(y.as_mut_ptr().add(i * n + j), acc0);
            _mm256_storeu_ps(y.as_mut_ptr().add((i + 1) * n + j), acc1);
            _mm256_storeu_ps(y.as_mut_ptr().add((i + 2) * n + j), acc2);
            _mm256_storeu_ps(y.as_mut_ptr().add((i + 3) * n + j), acc3);
            j += 8;
        }
        while j < n {
            for r in 0..4 {
                let xr = x.as_ptr().add((i + r) * m);
                let mut s = 0.0f32;
                for k in 0..m {
                    s += *xr.add(k) * w[k * n + j];
                }
                y[(i + r) * n + j] = s;
            }
            j += 1;
        }
        i += 4;
    }
    while i < b {
        let xr = x.as_ptr().add(i * m);
        let mut j = 0;
        while j < n8 {
            let mut acc = _mm256_setzero_ps();
            let mut wp = w.as_ptr().add(j);
            for k in 0..m {
                acc = _mm256_fmadd_ps(_mm256_set1_ps(*xr.add(k)), _mm256_loadu_ps(wp), acc);
                wp = wp.add(n);
            }
            _mm256_storeu_ps(y.as_mut_ptr().add(i * n + j), acc);
            j += 8;
        }
        while j < n {
            let mut s = 0.0f32;
            for k in 0..m {
                s += *xr.add(k) * w[k * n + j];
            }
            y[i * n + j] = s;
            j += 1;
        }
        i += 1;
    }
}

/// `y[b,m] = g[b,n] @ w[m,n]^T`: one 8-lane dot per output element.
pub fn matmul_nt_into(g: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), b * m);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { matmul_nt_avx2(g, w, y, b, m, n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_nt_avx2(g: &[f32], w: &[f32], y: &mut [f32], b: usize, m: usize, n: usize) {
    for i in 0..b {
        let grow = &g[i * n..(i + 1) * n];
        let yrow = &mut y[i * m..(i + 1) * m];
        for (k, yv) in yrow.iter_mut().enumerate() {
            *yv = dot_avx2(grow, &w[k * n..(k + 1) * n]);
        }
    }
}

/// `dw[m,n] = x[b,m]^T @ g[b,n]`: the 4×8 tile with roles swapped —
/// four `dw` rows, eight `g` columns, `i`-ascending over the batch.
pub fn matmul_tn_into(x: &[f32], g: &[f32], dw: &mut [f32], b: usize, m: usize, n: usize) {
    debug_assert_eq!(x.len(), b * m);
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(dw.len(), m * n);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { matmul_tn_avx2(x, g, dw, b, m, n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn matmul_tn_avx2(x: &[f32], g: &[f32], dw: &mut [f32], b: usize, m: usize, n: usize) {
    let n8 = n - n % 8;
    let m4 = m - m % 4;
    let mut k = 0;
    while k < m4 {
        let mut j = 0;
        while j < n8 {
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut acc2 = _mm256_setzero_ps();
            let mut acc3 = _mm256_setzero_ps();
            for i in 0..b {
                let gv = _mm256_loadu_ps(g.as_ptr().add(i * n + j));
                let xp = x.as_ptr().add(i * m + k);
                acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*xp), gv, acc0);
                acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*xp.add(1)), gv, acc1);
                acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*xp.add(2)), gv, acc2);
                acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*xp.add(3)), gv, acc3);
            }
            _mm256_storeu_ps(dw.as_mut_ptr().add(k * n + j), acc0);
            _mm256_storeu_ps(dw.as_mut_ptr().add((k + 1) * n + j), acc1);
            _mm256_storeu_ps(dw.as_mut_ptr().add((k + 2) * n + j), acc2);
            _mm256_storeu_ps(dw.as_mut_ptr().add((k + 3) * n + j), acc3);
            j += 8;
        }
        while j < n {
            for r in 0..4 {
                let mut s = 0.0f32;
                for i in 0..b {
                    s += x[i * m + k + r] * g[i * n + j];
                }
                dw[(k + r) * n + j] = s;
            }
            j += 1;
        }
        k += 4;
    }
    while k < m {
        let mut j = 0;
        while j < n8 {
            let mut acc = _mm256_setzero_ps();
            for i in 0..b {
                let gv = _mm256_loadu_ps(g.as_ptr().add(i * n + j));
                acc = _mm256_fmadd_ps(_mm256_set1_ps(x[i * m + k]), gv, acc);
            }
            _mm256_storeu_ps(dw.as_mut_ptr().add(k * n + j), acc);
            j += 8;
        }
        while j < n {
            let mut s = 0.0f32;
            for i in 0..b {
                s += x[i * m + k] * g[i * n + j];
            }
            dw[k * n + j] = s;
            j += 1;
        }
        k += 1;
    }
}

/// `db[n] = sum_i g[i,n]`: pure `vaddps` in the scalar fold's exact
/// `i`-ascending order — bitwise identical to the scalar tier.
pub fn colsum_into(g: &[f32], db: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(g.len(), b * n);
    debug_assert_eq!(db.len(), n);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { colsum_avx2(g, db, b, n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn colsum_avx2(g: &[f32], db: &mut [f32], b: usize, n: usize) {
    let n8 = n - n % 8;
    let mut j = 0;
    while j < n8 {
        let mut acc = _mm256_setzero_ps();
        for i in 0..b {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(g.as_ptr().add(i * n + j)));
        }
        _mm256_storeu_ps(db.as_mut_ptr().add(j), acc);
        j += 8;
    }
    while j < n {
        let mut s = 0.0f32;
        for i in 0..b {
            s += g[i * n + j];
        }
        db[j] = s;
        j += 1;
    }
}

/// `out[i] = dot(a[i,:], c[i,:])` over `[b, n]` operands.
pub fn rowdot_into(a: &[f32], c: &[f32], out: &mut [f32], b: usize, n: usize) {
    debug_assert_eq!(a.len(), b * n);
    debug_assert_eq!(c.len(), b * n);
    debug_assert_eq!(out.len(), b);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { rowdot_avx2(a, c, out, b, n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn rowdot_avx2(a: &[f32], c: &[f32], out: &mut [f32], b: usize, n: usize) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot_avx2(&a[i * n..(i + 1) * n], &c[i * n..(i + 1) * n]);
    }
}

/// Zero `dy` where `pre <= 0.0`. The ordered-quiet compare treats NaN
/// pre-activations as "keep", exactly like the scalar branch — bitwise
/// identical across modes.
pub fn relu_mask(dy: &mut [f32], pre: &[f32]) {
    debug_assert_eq!(dy.len(), pre.len());
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { relu_mask_avx2(dy, pre) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn relu_mask_avx2(dy: &mut [f32], pre: &[f32]) {
    let n = dy.len();
    let n8 = n - n % 8;
    let zero = _mm256_setzero_ps();
    let mut k = 0;
    while k < n8 {
        let p = _mm256_loadu_ps(pre.as_ptr().add(k));
        let d = _mm256_loadu_ps(dy.as_ptr().add(k));
        // mask lanes are all-ones where p <= 0 (false for NaN);
        // andnot keeps d where the mask is clear.
        let mask = _mm256_cmp_ps::<_CMP_LE_OQ>(p, zero);
        _mm256_storeu_ps(dy.as_mut_ptr().add(k), _mm256_andnot_ps(mask, d));
        k += 8;
    }
    while k < n {
        if pre[k] <= 0.0 {
            dy[k] = 0.0;
        }
        k += 1;
    }
}

/// Fused embedding gather + `x0` concat: 8-wide row copies straight
/// into the concat layout. Pure copy — bitwise identical across modes.
#[allow(clippy::too_many_arguments)]
pub fn embed_concat_fwd(
    table: &[f32],
    ids: &[i32],
    dense_x: &[f32],
    b: usize,
    f: usize,
    d: usize,
    nd: usize,
    x0: &mut [f32],
) {
    let d0 = f * d + nd;
    debug_assert_eq!(ids.len(), b * f);
    debug_assert_eq!(dense_x.len(), b * nd);
    debug_assert_eq!(x0.len(), b * d0);
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { embed_concat_avx2(table, ids, dense_x, b, f, d, nd, x0) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn embed_concat_avx2(
    table: &[f32],
    ids: &[i32],
    dense_x: &[f32],
    b: usize,
    f: usize,
    d: usize,
    nd: usize,
    x0: &mut [f32],
) {
    let d0 = f * d + nd;
    let d8 = d - d % 8;
    for i in 0..b {
        let row = i * d0;
        for (j, &id) in ids[i * f..(i + 1) * f].iter().enumerate() {
            let src = table.as_ptr().add(id as usize * d);
            let dst = x0.as_mut_ptr().add(row + j * d);
            let mut t = 0;
            while t < d8 {
                _mm256_storeu_ps(dst.add(t), _mm256_loadu_ps(src.add(t)));
                t += 8;
            }
            while t < d {
                *dst.add(t) = *src.add(t);
                t += 1;
            }
        }
        if nd > 0 {
            x0[row + f * d..row + d0].copy_from_slice(&dense_x[i * nd..(i + 1) * nd]);
        }
    }
}

/// Serving's fused dequantize: widen 8 `u16` codes through `i32` to
/// `f32`, then multiply-then-add (two roundings, deliberately *not*
/// FMA) — bitwise identical to the scalar `min + c as f32 * step`.
pub fn dequant_row(codes: &[u16], min: f32, step: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    // Safety: reachable only through the `AVX2` vtable (see `axpy`).
    unsafe { dequant_row_avx2(codes, min, step, out) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn dequant_row_avx2(codes: &[u16], min: f32, step: f32, out: &mut [f32]) {
    let n = codes.len();
    let n8 = n - n % 8;
    let minv = _mm256_set1_ps(min);
    let stepv = _mm256_set1_ps(step);
    let mut k = 0;
    while k < n8 {
        let raw = _mm_loadu_si128(codes.as_ptr().add(k) as *const __m128i);
        let wide = _mm256_cvtepi32_ps(_mm256_cvtepu16_epi32(raw));
        let v = _mm256_add_ps(minv, _mm256_mul_ps(wide, stepv));
        _mm256_storeu_ps(out.as_mut_ptr().add(k), v);
        k += 8;
    }
    while k < n {
        out[k] = min + codes[k] as f32 * step;
        k += 1;
    }
}
