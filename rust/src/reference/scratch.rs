//! Reusable per-worker scratch buffers for the compute hot path.
//!
//! The reference engine's forward/backward/infer passes need a dozen
//! intermediate `f32` buffers per call. Allocating them fresh every step
//! (the pre-PR-5 behavior: ~24 heap allocations per forward) puts the
//! allocator on the critical path of every microbatch. A [`Scratch`] is
//! a small free-list arena owned by exactly one worker thread: passes
//! [`take`](Scratch::take) buffers for their intermediates and
//! [`recycle`](Scratch::recycle) them on the way out, so once every
//! buffer has grown to its steady-state capacity, the compute path
//! performs **zero heap allocation per step**.
//!
//! Design notes:
//!
//! * `take` is **best-fit**: it returns the smallest free buffer whose
//!   capacity already covers the request, so varying request sizes (the
//!   serving path's fluctuating micro-batches, eval tails) converge to a
//!   stable buffer set instead of thrashing.
//! * Reused buffers keep their **stale contents** (always finite floats
//!   from a previous pass — never uninitialized memory): every consumer
//!   on the compute path fully overwrites its buffer before reading it
//!   (the `_into` kernels either `fill(0.0)` accumulation targets
//!   themselves or assign every element), so zero-filling on `take`
//!   would memset each intermediate a second time per step. Callers
//!   that genuinely need zeros use [`take_zeroed`](Scratch::take_zeroed);
//!   the steady-state tests pin value stability across repeated calls,
//!   so an accidental read-before-write of stale data fails loudly.
//! * [`grow_events`](Scratch::grow_events) counts every take that had to
//!   allocate. The steady-state-zero-allocation property is *tested*
//!   (not just claimed): see `reference::model`'s
//!   `steady_state_grad_performs_no_scratch_allocation` and
//!   `train_integration.rs`.
//! * A `Scratch` is deliberately **not** shared: it is `Send` but has no
//!   interior mutability; every worker/scoring thread owns its own (the
//!   persistent pools in `coordinator::pool` and `serve::queue` keep one
//!   per thread for the lifetime of the run).

/// Free-list arena of reusable `f32` buffers (see module docs).
#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
    grown: usize,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { free: Vec::new(), grown: 0 }
    }

    /// A buffer of exactly `len` elements whose contents are
    /// **unspecified but finite** (stale values from a previous pass, or
    /// zeros for the extension of a fresh/grown buffer) — the caller
    /// must fully overwrite it before reading. Reuses the best-fitting
    /// free buffer when one exists; otherwise allocates (counted in
    /// [`grow_events`](Scratch::grow_events)).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len
                && best.map_or(true, |j| b.capacity() < self.free[j].capacity())
            {
                best = Some(i);
            }
        }
        let mut buf = match best {
            Some(i) => self.free.swap_remove(i),
            None => {
                self.grown += 1;
                Vec::with_capacity(len)
            }
        };
        if buf.len() > len {
            buf.truncate(len); // O(1) for f32: no drop glue, no writes
        } else {
            buf.resize(len, 0.0); // zero-writes only the extension
        }
        buf
    }

    /// [`take`](Scratch::take), but zero-filled — for accumulation
    /// targets that genuinely start from zero.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.take(len);
        buf.fill(0.0);
        buf
    }

    /// Return a buffer to the free list. Buffers that escape to callers
    /// instead (e.g. logits handed to eval) are simply not returned —
    /// the arena never aliases them. Zero-capacity vecs (empty optional
    /// cache fields) are dropped so the free list stays bounded by the
    /// peak number of live buffers.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Number of `take` calls that had to allocate since construction.
    /// Flat across steps == the compute path is allocation-free.
    pub fn grow_events(&self) -> usize {
        self.grown
    }

    /// Buffers currently parked in the free list (diagnostics).
    pub fn free_buffers(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycles_without_rewriting() {
        let mut s = Scratch::new();
        let mut a = s.take(16);
        assert_eq!(a.len(), 16);
        assert_eq!(a, vec![0.0f32; 16], "a fresh buffer extends with zeros");
        a.iter_mut().for_each(|x| *x = 7.0);
        let cap = a.capacity();
        s.recycle(a);
        let b = s.take(10);
        assert_eq!(b.len(), 10);
        assert_eq!(b.capacity(), cap, "the same buffer comes back");
        assert!(b.iter().all(|x| x.is_finite()), "stale contents are finite floats");
        assert_eq!(s.grow_events(), 1, "second take must not allocate");
        // growing within capacity zero-fills only the extension
        s.recycle(b);
        let c = s.take(12);
        assert_eq!(c.len(), 12);
        assert!(c[10..].iter().all(|&x| x == 0.0), "extension beyond prior len is zeroed");
    }

    #[test]
    fn take_zeroed_always_zeroes() {
        let mut s = Scratch::new();
        let mut a = s.take(8);
        a.iter_mut().for_each(|x| *x = f32::NAN);
        s.recycle(a);
        let b = s.take_zeroed(8);
        assert_eq!(b, vec![0.0f32; 8]);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut s = Scratch::new();
        let big = s.take(100);
        let small = s.take(8);
        s.recycle(big);
        s.recycle(small);
        let got = s.take(4);
        assert!(got.capacity() < 100, "best fit should pick the small buffer");
        s.recycle(got);
        let got = s.take(50);
        assert!(got.capacity() >= 100, "only the big buffer fits 50");
    }

    #[test]
    fn steady_state_has_no_growth() {
        let mut s = Scratch::new();
        // warm up with the sequence a hot loop would issue
        for _ in 0..2 {
            let a = s.take(32);
            let b = s.take(8);
            let c = s.take(32);
            s.recycle(a);
            s.recycle(b);
            s.recycle(c);
        }
        let grown = s.grow_events();
        for _ in 0..100 {
            let a = s.take(32);
            let b = s.take(8);
            let c = s.take(32);
            s.recycle(c);
            s.recycle(b);
            s.recycle(a);
        }
        assert_eq!(s.grow_events(), grown, "steady state must not allocate");
    }
}
