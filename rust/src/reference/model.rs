//! The four CTR models: forward + hand-derived backward, positional
//! parameter layout identical to `python/compile/models/*` specs.
//!
//! # Memory discipline (PR 5)
//!
//! Every intermediate of forward/backward/infer lives in a caller-owned
//! [`Scratch`] arena: the embedding gather is fused with the deep-stream
//! concat (`x0`'s first `F·d` columns *are* the embeds tensor — no
//! separate `[b, F·d]` buffer exists), layer caches hold recycled
//! buffers instead of fresh `Vec`s, and the only per-step heap
//! allocations left on the gradient path are the escaping outputs
//! themselves (the sparse/dense gradient payloads and the touched-id
//! list) plus a few layer-count pointer spines. The
//! `steady_state_grad_performs_no_scratch_allocation` test pins the
//! arena at zero growth across steps.

use std::str::FromStr;

use anyhow::{bail, ensure, Result};

use super::layers::*;
use super::scratch::Scratch;
use super::simd::{self, Kernels};
use crate::data::batcher::{touched_of, Batch};
use crate::data::schema::Schema;
use crate::model::params::ParamSet;
use crate::tensor::{GradTensor, SparseRows, Tensor};

/// Which architecture to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    DeepFm,
    WideDeep,
    Dcn,
    DcnV2,
}

impl ModelKind {
    pub const ALL: [ModelKind; 4] =
        [ModelKind::DeepFm, ModelKind::WideDeep, ModelKind::Dcn, ModelKind::DcnV2];

    /// Manifest / artifact-id name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::DeepFm => "deepfm",
            ModelKind::WideDeep => "wd",
            ModelKind::Dcn => "dcn",
            ModelKind::DcnV2 => "dcnv2",
        }
    }

    /// Paper row label.
    pub fn label(&self) -> &'static str {
        match self {
            ModelKind::DeepFm => "DeepFM",
            ModelKind::WideDeep => "W&D",
            ModelKind::Dcn => "DCN",
            ModelKind::DcnV2 => "DCN v2",
        }
    }
}

impl FromStr for ModelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "deepfm" => ModelKind::DeepFm,
            "wd" => ModelKind::WideDeep,
            "dcn" => ModelKind::Dcn,
            "dcnv2" => ModelKind::DcnV2,
            other => bail!("unknown model {other:?}"),
        })
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Reference model: architecture constants + schema.
#[derive(Clone, Debug)]
pub struct ReferenceModel {
    pub kind: ModelKind,
    pub schema: Schema,
    pub embed_dim: usize,
    pub hidden: Vec<usize>,
    pub n_cross: usize,
    /// The SIMD vtable every kernel call routes through — resolved once
    /// per process ([`simd::active`]) and shared by every clone, so all
    /// workers/shards run the identical instruction stream.
    kernels: &'static Kernels,
}

impl ReferenceModel {
    pub fn new(kind: ModelKind, schema: Schema, embed_dim: usize, hidden: Vec<usize>, n_cross: usize) -> Self {
        ReferenceModel { kind, schema, embed_dim, hidden, n_cross, kernels: simd::active() }
    }

    /// Override the kernel vtable (tests and cross-mode parity harnesses;
    /// production callers go through the process-wide [`simd::active`]).
    pub fn with_kernels(mut self, kernels: &'static Kernels) -> Self {
        self.kernels = kernels;
        self
    }

    /// The vtable this model instance dispatches through (the serving
    /// tier routes its fused gather–dequantize pass through the same one).
    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Deep-stream input dimension.
    pub fn d0(&self) -> usize {
        self.schema.n_cat() * self.embed_dim + self.schema.n_dense
    }

    /// Whether this architecture has a wide (LR/FM first-order) stream.
    pub fn uses_wide(&self) -> bool {
        matches!(self.kind, ModelKind::DeepFm | ModelKind::WideDeep)
    }

    /// Forward pass: logits `[b]` (convenience form; allocates a
    /// throwaway scratch arena — hot callers use
    /// [`ReferenceModel::forward_scratch`]).
    pub fn forward(&self, params: &ParamSet, batch: &Batch) -> Result<Vec<f32>> {
        let mut scratch = Scratch::new();
        self.forward_scratch(params, batch, &mut scratch)
    }

    /// Forward pass on a caller-owned scratch arena. The returned logits
    /// buffer was taken from `scratch`; recycle it there when done to
    /// keep the steady state allocation-free.
    pub fn forward_scratch(
        &self,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut Scratch,
    ) -> Result<Vec<f32>> {
        let (logits, cache) = self.forward_on(
            params,
            batch.x_cat.as_i32()?,
            batch.x_dense.as_f32()?,
            batch.batch_size(),
            scratch,
        )?;
        cache.recycle(scratch);
        Ok(logits)
    }

    /// Loss + positional gradients + per-id occurrence counts — the
    /// reference twin of the AOT `grad` program (convenience form with a
    /// throwaway scratch arena; hot callers use
    /// [`ReferenceModel::grad_with`]).
    ///
    /// Row-indexed gradients (embedding + wide tables) come back
    /// **sparse** over the batch's touched ids, and the counts are the
    /// matching `d = 1` sparse vector, so nothing on this path ever
    /// allocates O(V · d).
    pub fn grad(
        &self,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f32, Vec<GradTensor>, SparseRows)> {
        let mut scratch = Scratch::new();
        self.grad_with(params, batch, &mut scratch)
    }

    /// [`ReferenceModel::grad`] on a caller-owned scratch arena: all
    /// forward/backward intermediates come from (and return to)
    /// `scratch`; only the gradient payloads themselves allocate.
    pub fn grad_with(
        &self,
        params: &ParamSet,
        batch: &Batch,
        scratch: &mut Scratch,
    ) -> Result<(f32, Vec<GradTensor>, SparseRows)> {
        let (touched, cnts) = batch.touched()?;
        self.grad_on(
            params,
            batch.x_cat.as_i32()?,
            batch.x_dense.as_f32()?,
            batch.y.as_f32()?,
            batch.batch_size(),
            touched,
            cnts,
            scratch,
        )
    }

    /// Gradient of rows `[lo, hi)` of `batch`, reading the batch storage
    /// in place — the worker fan-out's shard path, which used to copy
    /// its row range into a fresh `Batch` every step. The whole-batch
    /// range reuses the batch's cached touched set.
    pub fn grad_range_with(
        &self,
        params: &ParamSet,
        batch: &Batch,
        lo: usize,
        hi: usize,
        scratch: &mut Scratch,
    ) -> Result<(f32, Vec<GradTensor>, SparseRows)> {
        let b = batch.batch_size();
        ensure!(lo < hi && hi <= b, "row range [{lo}, {hi}) out of bounds for batch {b}");
        if lo == 0 && hi == b {
            return self.grad_with(params, batch, scratch);
        }
        let f = self.schema.n_cat();
        let nd = self.schema.n_dense;
        let ids = &batch.x_cat.as_i32()?[lo * f..hi * f];
        let dense = &batch.x_dense.as_f32()?[lo * nd..hi * nd];
        let y = &batch.y.as_f32()?[lo..hi];
        let (touched, cnts) = touched_of(ids);
        self.grad_on(params, ids, dense, y, hi - lo, touched, cnts, scratch)
    }

    #[allow(clippy::too_many_arguments)]
    fn grad_on(
        &self,
        params: &ParamSet,
        ids: &[i32],
        dense: &[f32],
        y: &[f32],
        b: usize,
        touched: Vec<u32>,
        cnts: Vec<f32>,
        scratch: &mut Scratch,
    ) -> Result<(f32, Vec<GradTensor>, SparseRows)> {
        let (logits, cache) = self.forward_on(params, ids, dense, b, scratch)?;
        let mut dlogits = scratch.take(b);
        let loss = bce_fwd_bwd_into(&logits, y, &mut dlogits);
        scratch.recycle(logits);
        let grads = self.backward_on(params, ids, b, &cache, &dlogits, &touched, scratch)?;
        scratch.recycle(dlogits);
        cache.recycle(scratch);
        let counts = SparseRows::new(self.schema.total_vocab(), 1, touched, cnts);
        Ok((loss, grads, counts))
    }

    /// Batched **inference-only** forward over a pre-built `x0` — the
    /// serving tier's scoring path. The caller gathers (and, under
    /// quantization, dequantizes) the vocab-table rows directly into the
    /// first `F·d` columns of each `x0` row and the dense features into
    /// the tail, in one fused pass (see `serve::model`):
    ///
    /// * `dense_params` — the non-vocab parameters (every spec entry
    ///   whose group is not `embed`/`wide`), in spec order.
    /// * `x0` — `[b, d0]` rows of `[gathered embeds | dense features]`.
    /// * `wide_sums` — per row `Σ_f wide_table[ids[f]]` (bias *not*
    ///   included), required by the wide-stream models (DeepFM, W&D)
    ///   and ignored otherwise.
    ///
    /// The op order mirrors [`ReferenceModel::forward`] exactly — the
    /// same fused/vectorized kernels run on both sides — so with f32
    /// gathers the logits are bit-identical to the training-side
    /// forward; no backward caches are allocated, and every intermediate
    /// comes from `scratch` (the returned logits buffer included —
    /// recycle it after use).
    pub fn infer_x0(
        &self,
        dense_params: &[Tensor],
        x0: &[f32],
        wide_sums: Option<&[f32]>,
        b: usize,
        scratch: &mut Scratch,
    ) -> Result<Vec<f32>> {
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let d0 = self.d0();
        ensure!(x0.len() == b * d0, "x0 shape mismatch");

        let mut r = SliceReader::new(dense_params);
        let logits = match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                let sums = wide_sums
                    .ok_or_else(|| anyhow::anyhow!("{} needs wide_sums", self.kind))?;
                ensure!(sums.len() == b, "wide_sums length mismatch");
                let wide_bias = r.next()?[0];
                let mut lg = scratch.take(b);
                for (l, &s) in lg.iter_mut().zip(sums) {
                    *l = wide_bias + s;
                }
                if self.kind == ModelKind::DeepFm {
                    let mut fm = scratch.take(b);
                    let mut fsums = scratch.take(b * d);
                    let mut sq = scratch.take(d);
                    fm2_fwd_strided(x0, d0, b, f, d, &mut fm, &mut fsums, &mut sq);
                    for (l, &v) in lg.iter_mut().zip(fm.iter()) {
                        *l += v;
                    }
                    scratch.recycle(fm);
                    scratch.recycle(fsums);
                    scratch.recycle(sq);
                }
                let mut m = d0;
                let mut h: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates (placeholder: input is x0)
                for &nn in &self.hidden {
                    let w = r.next()?;
                    let bias = r.next()?;
                    let mut out = scratch.take(b * nn);
                    {
                        let input: &[f32] = if h.is_empty() { x0 } else { &h };
                        dense_infer_into(self.kernels, input, w, bias, b, m, nn, true, &mut out);
                    }
                    let old = std::mem::replace(&mut h, out);
                    if !old.is_empty() {
                        scratch.recycle(old);
                    }
                    m = nn;
                }
                let w = r.next()?;
                let bias = r.next()?;
                let mut out1 = scratch.take(b);
                {
                    let input: &[f32] = if h.is_empty() { x0 } else { &h };
                    dense_infer_into(self.kernels, input, w, bias, b, m, 1, false, &mut out1);
                }
                if !h.is_empty() {
                    scratch.recycle(h);
                }
                for (l, &o) in lg.iter_mut().zip(out1.iter()) {
                    *l += o;
                }
                scratch.recycle(out1);
                lg
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                // cross stream (ping-pong buffers; empty = x0)
                let mut xl: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates (ping-pong placeholder)
                for _ in 0..self.n_cross {
                    let w = r.next()?;
                    let bias = r.next()?;
                    let mut next = scratch.take(b * d0);
                    match self.kind {
                        ModelKind::Dcn => {
                            let cur: &[f32] = if xl.is_empty() { x0 } else { &xl };
                            for i in 0..b {
                                let s = (self.kernels.dot)(&cur[i * d0..(i + 1) * d0], w);
                                for j in 0..d0 {
                                    next[i * d0 + j] =
                                        x0[i * d0 + j] * s + bias[j] + cur[i * d0 + j];
                                }
                            }
                        }
                        ModelKind::DcnV2 => {
                            let mut u = scratch.take(b * d0);
                            {
                                let cur: &[f32] = if xl.is_empty() { x0 } else { &xl };
                                (self.kernels.matmul_into)(cur, w, &mut u, b, d0, d0);
                                for row in u.chunks_exact_mut(d0) {
                                    for (uv, &bv) in row.iter_mut().zip(bias) {
                                        *uv += bv;
                                    }
                                }
                                for j in 0..b * d0 {
                                    next[j] = x0[j] * u[j] + cur[j];
                                }
                            }
                            scratch.recycle(u);
                        }
                        _ => unreachable!(),
                    }
                    let old = std::mem::replace(&mut xl, next);
                    if !old.is_empty() {
                        scratch.recycle(old);
                    }
                }
                // deep stream (hidden only)
                let mut m = d0;
                let mut h: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates (placeholder: input is x0)
                for &nn in &self.hidden {
                    let w = r.next()?;
                    let bias = r.next()?;
                    let mut out = scratch.take(b * nn);
                    {
                        let input: &[f32] = if h.is_empty() { x0 } else { &h };
                        dense_infer_into(self.kernels, input, w, bias, b, m, nn, true, &mut out);
                    }
                    let old = std::mem::replace(&mut h, out);
                    if !old.is_empty() {
                        scratch.recycle(old);
                    }
                    m = nn;
                }
                // head over concat(xl, deep)
                let hc = d0 + m;
                let mut head_in = scratch.take(b * hc);
                {
                    let xl_f: &[f32] = if xl.is_empty() { x0 } else { &xl };
                    let deep: &[f32] = if h.is_empty() { x0 } else { &h };
                    for i in 0..b {
                        head_in[i * hc..i * hc + d0]
                            .copy_from_slice(&xl_f[i * d0..(i + 1) * d0]);
                        head_in[i * hc + d0..(i + 1) * hc]
                            .copy_from_slice(&deep[i * m..(i + 1) * m]);
                    }
                }
                if !xl.is_empty() {
                    scratch.recycle(xl);
                }
                if !h.is_empty() {
                    scratch.recycle(h);
                }
                let head_w = r.next()?;
                let head_b = r.next()?;
                let mut lg = scratch.take(b);
                dense_infer_into(self.kernels, &head_in, head_w, head_b, b, hc, 1, false, &mut lg);
                scratch.recycle(head_in);
                lg
            }
        };
        r.finish()?;
        Ok(logits)
    }

    // ------------------------------------------------------------------

    /// Forward over raw id/dense slices: logits + backward caches, all on
    /// scratch buffers. `x0`'s first `F·d` columns double as the embeds
    /// tensor (fused gather+concat), so DeepFM's FM term and the embed
    /// backward read it strided instead of through a separate buffer.
    fn forward_on(
        &self,
        params: &ParamSet,
        ids: &[i32],
        dense: &[f32],
        b: usize,
        scratch: &mut Scratch,
    ) -> Result<(Vec<f32>, Cache)> {
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let nd = self.schema.n_dense;
        let d0 = self.d0();
        ensure!(ids.len() == b * f, "batch/cat shape mismatch");
        ensure!(dense.len() == b * nd, "batch/dense shape mismatch");

        let mut reader = Reader::new(params);
        let embed_table = reader.next()?;
        let mut x0 = scratch.take(b * d0);
        {
            let _gather = crate::obs::span(crate::obs::Phase::Gather);
            (self.kernels.embed_concat_fwd)(embed_table, ids, dense, b, f, d, nd, &mut x0);
        }
        let _fwd = crate::obs::span(crate::obs::Phase::Forward);

        let n_hidden = self.hidden.len();
        let mut fm_sums: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates (kind-dependent cache slot)
        let mut mlp_pre: Vec<Vec<f32>> = Vec::with_capacity(n_hidden);
        let mut mlp_h: Vec<Vec<f32>> = Vec::with_capacity(n_hidden);
        let mut cross_su: Vec<Vec<f32>> = Vec::with_capacity(self.n_cross);
        let mut cross_out: Vec<Vec<f32>> = Vec::with_capacity(self.n_cross);
        let mut head_in: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates (kind-dependent cache slot)

        let logits: Vec<f32> = match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                let wide_table = reader.next()?;
                let wide_bias = reader.next()?[0];
                let mut lg = scratch.take(b);
                wide_fwd_into(wide_table, wide_bias, ids, b, f, &mut lg);
                if self.kind == ModelKind::DeepFm {
                    let mut fm = scratch.take(b);
                    let mut sums = scratch.take(b * d);
                    let mut sq = scratch.take(d);
                    fm2_fwd_strided(&x0, d0, b, f, d, &mut fm, &mut sums, &mut sq);
                    for (l, &v) in lg.iter_mut().zip(fm.iter()) {
                        *l += v;
                    }
                    scratch.recycle(fm);
                    scratch.recycle(sq);
                    fm_sums = sums;
                }
                // MLP with scalar head
                let mut m = d0;
                for (li, &nn) in self.hidden.iter().enumerate() {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    let mut pre = scratch.take(b * nn);
                    let mut out = scratch.take(b * nn);
                    {
                        let input: &[f32] = if li == 0 { &x0 } else { &mlp_h[li - 1] };
                        dense_fwd_into(self.kernels, input, w, bias, b, m, nn, true, &mut pre, &mut out);
                    }
                    mlp_pre.push(pre);
                    mlp_h.push(out);
                    m = nn;
                }
                let w = reader.next()?;
                let bias = reader.next()?;
                let mut out1 = scratch.take(b);
                {
                    let input: &[f32] =
                        if n_hidden == 0 { &x0 } else { &mlp_h[n_hidden - 1] };
                    dense_infer_into(self.kernels, input, w, bias, b, m, 1, false, &mut out1);
                }
                for (l, &o) in lg.iter_mut().zip(out1.iter()) {
                    *l += o;
                }
                scratch.recycle(out1);
                lg
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                // cross stream
                for l in 0..self.n_cross {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    match self.kind {
                        ModelKind::Dcn => {
                            // s[i] = xl[i,:] . w ; x_{l+1} = x0*s + b + xl
                            let mut sbuf = scratch.take(b);
                            let mut next = scratch.take(b * d0);
                            {
                                let xl: &[f32] =
                                    if l == 0 { &x0 } else { &cross_out[l - 1] };
                                for (i, sv) in sbuf.iter_mut().enumerate() {
                                    *sv = (self.kernels.dot)(&xl[i * d0..(i + 1) * d0], w);
                                }
                                for i in 0..b {
                                    for j in 0..d0 {
                                        next[i * d0 + j] = x0[i * d0 + j] * sbuf[i]
                                            + bias[j]
                                            + xl[i * d0 + j];
                                    }
                                }
                            }
                            cross_su.push(sbuf);
                            cross_out.push(next);
                        }
                        ModelKind::DcnV2 => {
                            // u = xl@W + b ; x_{l+1} = x0 ⊙ u + xl
                            let mut u = scratch.take(b * d0);
                            let mut next = scratch.take(b * d0);
                            {
                                let xl: &[f32] =
                                    if l == 0 { &x0 } else { &cross_out[l - 1] };
                                (self.kernels.matmul_into)(xl, w, &mut u, b, d0, d0);
                                for row in u.chunks_exact_mut(d0) {
                                    for (uv, &bv) in row.iter_mut().zip(bias) {
                                        *uv += bv;
                                    }
                                }
                                for j in 0..b * d0 {
                                    next[j] = x0[j] * u[j] + xl[j];
                                }
                            }
                            cross_su.push(u);
                            cross_out.push(next);
                        }
                        _ => unreachable!(),
                    }
                }
                // deep stream (hidden only)
                let mut m = d0;
                for (li, &nn) in self.hidden.iter().enumerate() {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    let mut pre = scratch.take(b * nn);
                    let mut out = scratch.take(b * nn);
                    {
                        let input: &[f32] = if li == 0 { &x0 } else { &mlp_h[li - 1] };
                        dense_fwd_into(self.kernels, input, w, bias, b, m, nn, true, &mut pre, &mut out);
                    }
                    mlp_pre.push(pre);
                    mlp_h.push(out);
                    m = nn;
                }
                // head over concat(xl, deep)
                let hc = d0 + m;
                head_in = scratch.take(b * hc);
                {
                    let xl_f: &[f32] = if self.n_cross == 0 {
                        &x0
                    } else {
                        &cross_out[self.n_cross - 1]
                    };
                    let deep: &[f32] =
                        if n_hidden == 0 { &x0 } else { &mlp_h[n_hidden - 1] };
                    for i in 0..b {
                        head_in[i * hc..i * hc + d0]
                            .copy_from_slice(&xl_f[i * d0..(i + 1) * d0]);
                        head_in[i * hc + d0..(i + 1) * hc]
                            .copy_from_slice(&deep[i * m..(i + 1) * m]);
                    }
                }
                let head_w = reader.next()?;
                let head_b = reader.next()?;
                let mut lg = scratch.take(b);
                dense_infer_into(self.kernels, &head_in, head_w, head_b, b, hc, 1, false, &mut lg);
                lg
            }
        };
        reader.finish()?;
        Ok((logits, Cache { x0, fm_sums, mlp_pre, mlp_h, cross_su, cross_out, head_in }))
    }

    fn backward_on(
        &self,
        params: &ParamSet,
        ids: &[i32],
        b: usize,
        cache: &Cache,
        dlogits: &[f32],
        touched: &[u32],
        scratch: &mut Scratch,
    ) -> Result<Vec<GradTensor>> {
        let _bwd = crate::obs::span(crate::obs::Phase::Backward);
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let d0 = self.d0();
        let v = self.schema.total_vocab();

        // gradients per positional slot, filled in spec order at the end
        let mut grads: Vec<GradTensor> = Vec::with_capacity(params.len());
        let mut dx0 = scratch.take(b * d0);

        match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                // wide stream (sparse over the touched ids)
                let (dwide, dbias) = wide_bwd_sparse(dlogits, ids, touched, f);
                // deep stream: head + hidden layers, walked backward
                let n_hidden = self.hidden.len();
                let mut dims = vec![d0]; // lint:allow(hotpath-alloc): O(layers) shape bookkeeping, not per-element churn
                dims.extend_from_slice(&self.hidden);
                dims.push(1);
                // collect weight refs in forward order
                let mut weights: Vec<&[f32]> = Vec::with_capacity(n_hidden + 1);
                {
                    let mut r = Reader::new(params);
                    let _ = r.next()?; // embed
                    let _ = r.next()?; // wide
                    let _ = r.next()?; // wide_bias
                    for _ in 0..=n_hidden {
                        weights.push(r.next()?);
                        let _ = r.next()?; // bias
                    }
                }
                let mut dy = scratch.take(b); // head upstream grad [b, 1]
                dy.copy_from_slice(dlogits);
                let mut dws: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_hidden + 1);
                for layer in (0..=n_hidden).rev() {
                    let (m, n) = (dims[layer], dims[layer + 1]);
                    if layer < n_hidden {
                        (self.kernels.relu_mask)(&mut dy, &cache.mlp_pre[layer]);
                    }
                    let input: &[f32] =
                        if layer == 0 { &cache.x0 } else { &cache.mlp_h[layer - 1] };
                    let dw = self.kernels.matmul_tn(input, &dy, b, m, n);
                    let db = self.kernels.colsum(&dy, b, n);
                    dws.push((dw, db));
                    if layer == 0 {
                        // the layer-0 dx *is* the deep-stream dx0
                        (self.kernels.matmul_nt_into)(&dy, weights[layer], &mut dx0, b, m, n);
                    } else {
                        let mut dx = scratch.take(b * m);
                        (self.kernels.matmul_nt_into)(&dy, weights[layer], &mut dx, b, m, n);
                        scratch.recycle(std::mem::replace(&mut dy, dx));
                    }
                }
                scratch.recycle(dy);
                dws.reverse();
                // FM stream: accumulate straight into dx0's embed block
                if self.kind == ModelKind::DeepFm {
                    fm2_bwd_strided_acc(
                        &cache.x0,
                        d0,
                        &cache.fm_sums,
                        dlogits,
                        b,
                        f,
                        d,
                        &mut dx0,
                        d0,
                    );
                }
                // assemble positional grads: embed, wide, wide_bias, mlp...
                let dtable = embed_bwd_sparse_strided(&dx0, d0, ids, touched, f, d);
                grads.push(GradTensor::Sparse(SparseRows::new(v, d, touched.to_vec(), dtable))); // lint:allow(hotpath-alloc): escaping payload: sparse grad owns its touched-row copy
                grads.push(GradTensor::Sparse(SparseRows::new(v, 1, touched.to_vec(), dwide))); // lint:allow(hotpath-alloc): escaping payload: sparse grad owns its touched-row copy
                grads.push(GradTensor::Dense(Tensor::f32(vec![1], vec![dbias]))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                for (dw, db) in dws {
                    let n = db.len();
                    let m = dw.len() / n;
                    grads.push(GradTensor::Dense(Tensor::f32(vec![m, n], dw))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                    grads.push(GradTensor::Dense(Tensor::f32(vec![n], db))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                }
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                let n_hidden = self.hidden.len();
                let h_last = *self.hidden.last().unwrap();
                let hc = d0 + h_last;

                // weight refs in forward order
                let mut cross_ws: Vec<&[f32]> = Vec::with_capacity(self.n_cross);
                let mut mlp_ws: Vec<&[f32]> = Vec::with_capacity(n_hidden);
                let head_w: &[f32];
                {
                    let mut r = Reader::new(params);
                    let _ = r.next()?; // embed
                    for _ in 0..self.n_cross {
                        cross_ws.push(r.next()?);
                        let _ = r.next()?;
                    }
                    for _ in 0..n_hidden {
                        mlp_ws.push(r.next()?);
                        let _ = r.next()?;
                    }
                    head_w = r.next()?;
                    let _ = r.next()?;
                    r.finish()?;
                }

                // head backward
                let dhead_w = self.kernels.matmul_tn(&cache.head_in, dlogits, b, hc, 1);
                let dhead_b = self.kernels.colsum(dlogits, b, 1);
                let mut dhead_in = scratch.take(b * hc);
                (self.kernels.matmul_nt_into)(dlogits, head_w, &mut dhead_in, b, hc, 1);
                let mut dxl = scratch.take(b * d0);
                let mut dy = scratch.take(b * h_last);
                for i in 0..b {
                    dxl[i * d0..(i + 1) * d0]
                        .copy_from_slice(&dhead_in[i * hc..i * hc + d0]);
                    dy[i * h_last..(i + 1) * h_last]
                        .copy_from_slice(&dhead_in[i * hc + d0..(i + 1) * hc]);
                }
                scratch.recycle(dhead_in);

                // deep stream backward
                let mut dims = vec![d0]; // lint:allow(hotpath-alloc): O(layers) shape bookkeeping, not per-element churn
                dims.extend_from_slice(&self.hidden);
                let mut mlp_grads: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_hidden);
                for layer in (0..n_hidden).rev() {
                    let (m, n) = (dims[layer], dims[layer + 1]);
                    (self.kernels.relu_mask)(&mut dy, &cache.mlp_pre[layer]);
                    let input: &[f32] =
                        if layer == 0 { &cache.x0 } else { &cache.mlp_h[layer - 1] };
                    let dw = self.kernels.matmul_tn(input, &dy, b, m, n);
                    let db = self.kernels.colsum(&dy, b, n);
                    mlp_grads.push((dw, db));
                    if layer == 0 {
                        (self.kernels.matmul_nt_into)(&dy, mlp_ws[layer], &mut dx0, b, m, n);
                    } else {
                        let mut dx = scratch.take(b * m);
                        (self.kernels.matmul_nt_into)(&dy, mlp_ws[layer], &mut dx, b, m, n);
                        scratch.recycle(std::mem::replace(&mut dy, dx));
                    }
                }
                scratch.recycle(dy);
                mlp_grads.reverse();

                // cross stream backward
                let mut cross_grads: Vec<(Vec<f32>, Vec<f32>)> =
                    Vec::with_capacity(self.n_cross);
                for l in (0..self.n_cross).rev() {
                    let xl_in: &[f32] =
                        if l == 0 { &cache.x0 } else { &cache.cross_out[l - 1] };
                    let su = &cache.cross_su[l];
                    match self.kind {
                        ModelKind::Dcn => {
                            // x_{l+1} = x0 * s + b + xl, s = xl . w
                            let mut ds = scratch.take(b);
                            (self.kernels.rowdot_into)(&cache.x0, &dxl, &mut ds, b, d0);
                            let mut dw = vec![0.0f32; d0]; // lint:allow(hotpath-alloc): escaping payload: per-layer cross grad accumulator
                            for i in 0..b {
                                (self.kernels.axpy)(&mut dw, &xl_in[i * d0..(i + 1) * d0], ds[i]);
                            }
                            let db = self.kernels.colsum(&dxl, b, d0);
                            // dx0 += s * dxl ; dxl += ds ⊗ w (in place:
                            // each element's old value is read first)
                            let w = cross_ws[l];
                            for i in 0..b {
                                for j in 0..d0 {
                                    dx0[i * d0 + j] += su[i] * dxl[i * d0 + j];
                                    dxl[i * d0 + j] += ds[i] * w[j];
                                }
                            }
                            cross_grads.push((dw, db));
                            scratch.recycle(ds);
                        }
                        ModelKind::DcnV2 => {
                            // x_{l+1} = x0 ⊙ u + xl, u = xl@W + b
                            let mut du = scratch.take(b * d0);
                            for j in 0..b * d0 {
                                du[j] = cache.x0[j] * dxl[j];
                                dx0[j] += su[j] * dxl[j];
                            }
                            let dw = self.kernels.matmul_tn(xl_in, &du, b, d0, d0);
                            let db = self.kernels.colsum(&du, b, d0);
                            let mut tmp = scratch.take(b * d0);
                            (self.kernels.matmul_nt_into)(&du, cross_ws[l], &mut tmp, b, d0, d0);
                            (self.kernels.axpy)(&mut dxl, &tmp, 1.0);
                            scratch.recycle(tmp);
                            scratch.recycle(du);
                            cross_grads.push((dw, db));
                        }
                        _ => unreachable!(),
                    }
                }
                cross_grads.reverse();
                // x0 also receives the layer-0 dxl (xl starts as x0)
                (self.kernels.axpy)(&mut dx0, &dxl, 1.0);
                scratch.recycle(dxl);

                let dtable = embed_bwd_sparse_strided(&dx0, d0, ids, touched, f, d);
                grads.push(GradTensor::Sparse(SparseRows::new(v, d, touched.to_vec(), dtable))); // lint:allow(hotpath-alloc): escaping payload: sparse grad owns its touched-row copy
                for (dw, db) in cross_grads {
                    if self.kind == ModelKind::Dcn {
                        grads.push(GradTensor::Dense(Tensor::f32(vec![d0], dw))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                    } else {
                        grads.push(GradTensor::Dense(Tensor::f32(vec![d0, d0], dw))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                    }
                    grads.push(GradTensor::Dense(Tensor::f32(vec![d0], db))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                }
                for (dw, db) in mlp_grads {
                    let n = db.len();
                    let m = dw.len() / n;
                    grads.push(GradTensor::Dense(Tensor::f32(vec![m, n], dw))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                    grads.push(GradTensor::Dense(Tensor::f32(vec![n], db))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                }
                grads.push(GradTensor::Dense(Tensor::f32(vec![hc, 1], dhead_w))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
                grads.push(GradTensor::Dense(Tensor::f32(vec![1], dhead_b))); // lint:allow(hotpath-alloc): escaping payload: grad tensor shape
            }
        }
        scratch.recycle(dx0);

        ensure!(grads.len() == params.len(), "gradient arity mismatch");
        for (g, e) in grads.iter().zip(&params.spec) {
            ensure!(g.matches_shape(&e.shape), "grad shape mismatch for {}", e.name);
        }
        Ok(grads)
    }
}

/// Forward caches reused by backward — every buffer is scratch-owned and
/// returned via [`Cache::recycle`]. `x0`'s embed block doubles as the
/// embeds tensor (no separate `[b, F·d]` buffer).
struct Cache {
    x0: Vec<f32>,
    /// DeepFM field-sums `[b, d]`; empty otherwise.
    fm_sums: Vec<f32>,
    /// Hidden-layer pre-activations (ReLU mask inputs).
    mlp_pre: Vec<Vec<f32>>,
    /// Hidden-layer outputs (the next layer's backward input).
    mlp_h: Vec<Vec<f32>>,
    /// Per cross layer: DCN `s [b]`, DCNv2 `u [b, d0]`.
    cross_su: Vec<Vec<f32>>,
    /// Per cross layer: its *output* `x_{l+1}` (layer `l`'s backward
    /// input is `cross_out[l-1]`, or `x0` for the first layer).
    cross_out: Vec<Vec<f32>>,
    /// DCN-family head input `[b, d0 + h_last]`; empty otherwise.
    head_in: Vec<f32>,
}

impl Cache {
    fn recycle(self, scratch: &mut Scratch) {
        scratch.recycle(self.x0);
        scratch.recycle(self.fm_sums);
        for v in self.mlp_pre {
            scratch.recycle(v);
        }
        for v in self.mlp_h {
            scratch.recycle(v);
        }
        for v in self.cross_su {
            scratch.recycle(v);
        }
        for v in self.cross_out {
            scratch.recycle(v);
        }
        scratch.recycle(self.head_in);
    }
}

/// Positional walker over the non-vocab parameter tensors handed to
/// [`ReferenceModel::infer_x0`].
struct SliceReader<'a> {
    tensors: &'a [Tensor],
    i: usize,
}

impl<'a> SliceReader<'a> {
    fn new(tensors: &'a [Tensor]) -> Self {
        SliceReader { tensors, i: 0 }
    }

    fn next(&mut self) -> Result<&'a [f32]> {
        ensure!(self.i < self.tensors.len(), "dense parameter underflow");
        let t = self.tensors[self.i].as_f32()?;
        self.i += 1;
        Ok(t)
    }

    fn finish(&self) -> Result<()> {
        ensure!(
            self.i == self.tensors.len(),
            "consumed {} of {} dense params",
            self.i,
            self.tensors.len()
        );
        Ok(())
    }
}

/// Positional parameter walker (twin of python's ParamReader).
struct Reader<'a> {
    params: &'a ParamSet,
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(params: &'a ParamSet) -> Self {
        Reader { params, i: 0 }
    }

    fn next(&mut self) -> Result<&'a [f32]> {
        ensure!(self.i < self.params.len(), "parameter underflow");
        let t = self.params.tensors[self.i].as_f32()?;
        self.i += 1;
        Ok(t)
    }

    fn finish(&self) -> Result<()> {
        ensure!(self.i == self.params.len(), "consumed {} of {} params", self.i, self.params.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_params, InitConfig};
    use crate::reference::step::build_spec;
    use crate::util::Rng;

    fn tiny_schema() -> Schema {
        Schema { name: "model_tiny".into(), n_dense: 3, vocab_sizes: vec![5, 4, 2] }
    }

    fn tiny_model(kind: ModelKind) -> ReferenceModel {
        ReferenceModel::new(kind, tiny_schema(), 4, vec![8, 8], 2)
    }

    fn tiny_batch(schema: &Schema, b: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let offs = schema.offsets();
        let mut x_cat = Vec::new();
        for _ in 0..b {
            for (f, &vs) in schema.vocab_sizes.iter().enumerate() {
                x_cat.push((offs[f] + rng.below(vs as u64) as usize) as i32);
            }
        }
        let x_dense: Vec<f32> = (0..b * schema.n_dense)
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        let y: Vec<f32> = (0..b).map(|_| rng.bernoulli(0.4) as u8 as f32).collect();
        Batch::new(
            Tensor::i32(vec![b, schema.n_cat()], x_cat),
            Tensor::f32(vec![b, schema.n_dense], x_dense),
            Tensor::f32(vec![b], y),
            b,
        )
    }

    /// The zero-allocation acceptance gate at the model level: after one
    /// warmup call, further grad calls on the same shapes must not grow
    /// the scratch arena — the whole forward/backward intermediate set
    /// is recycled.
    #[test]
    fn steady_state_grad_performs_no_scratch_allocation() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let spec = build_spec(kind, &model.schema, 4, &[8, 8], 2);
            let params = init_params(&spec, &InitConfig { seed: 2, embed_sigma: 0.05 });
            let batch = tiny_batch(&model.schema, 8, 3);
            let mut scratch = Scratch::new();
            let (loss0, grads0, _) = model.grad_with(&params, &batch, &mut scratch).unwrap();
            let grown = scratch.grow_events();
            assert!(grown > 0, "{kind}: warmup must populate the arena");
            for it in 0..4 {
                // value stability doubles as the stale-data guard: every
                // reused buffer must be fully overwritten, so repeated
                // calls are bitwise identical to the first
                let (loss, grads, _) = model.grad_with(&params, &batch, &mut scratch).unwrap();
                assert_eq!(loss, loss0, "{kind}: iter {it} loss drifted (stale scratch read?)");
                for (gi, (a, b)) in grads.iter().zip(&grads0).enumerate() {
                    assert_eq!(
                        a.to_tensor().as_f32().unwrap(),
                        b.to_tensor().as_f32().unwrap(),
                        "{kind}: iter {it} grad[{gi}] drifted (stale scratch read?)"
                    );
                }
            }
            assert_eq!(
                scratch.grow_events(),
                grown,
                "{kind}: steady-state grad allocated new scratch buffers"
            );
            // forward-only (eval) path: recycle the returned logits and
            // the arena stays flat too
            let lg = model.forward_scratch(&params, &batch, &mut scratch).unwrap();
            let lg0 = lg.clone();
            scratch.recycle(lg);
            let grown = scratch.grow_events();
            for _ in 0..3 {
                let lg = model.forward_scratch(&params, &batch, &mut scratch).unwrap();
                assert_eq!(lg, lg0, "{kind}: eval logits drifted (stale scratch read?)");
                scratch.recycle(lg);
            }
            assert_eq!(scratch.grow_events(), grown, "{kind}: eval path allocated");
        }
    }

    /// Row-range gradients read the batch in place and must equal the
    /// gradient of a materialized row-slice batch.
    #[test]
    fn grad_range_matches_sliced_batch() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let spec = build_spec(kind, &model.schema, 4, &[8, 8], 2);
            let params = init_params(&spec, &InitConfig { seed: 9, embed_sigma: 0.04 });
            let batch = tiny_batch(&model.schema, 12, 5);
            let (lo, hi) = (4usize, 10usize);
            let mut scratch = Scratch::new();
            let (loss_r, grads_r, counts_r) =
                model.grad_range_with(&params, &batch, lo, hi, &mut scratch).unwrap();

            // materialized slice (the old copy path)
            let f = model.schema.n_cat();
            let nd = model.schema.n_dense;
            let cat = batch.x_cat.as_i32().unwrap();
            let dense = batch.x_dense.as_f32().unwrap();
            let yv = batch.y.as_f32().unwrap();
            let sliced = Batch::new(
                Tensor::i32(vec![hi - lo, f], cat[lo * f..hi * f].to_vec()),
                Tensor::f32(vec![hi - lo, nd], dense[lo * nd..hi * nd].to_vec()),
                Tensor::f32(vec![hi - lo], yv[lo..hi].to_vec()),
                hi - lo,
            );
            let (loss_s, grads_s, counts_s) = model.grad(&params, &sliced).unwrap();

            assert_eq!(loss_r, loss_s, "{kind}: loss");
            assert_eq!(counts_r, counts_s, "{kind}: counts");
            assert_eq!(grads_r.len(), grads_s.len());
            for (i, (a, b)) in grads_r.iter().zip(&grads_s).enumerate() {
                assert_eq!(
                    a.to_tensor().as_f32().unwrap(),
                    b.to_tensor().as_f32().unwrap(),
                    "{kind}: grad[{i}]"
                );
            }
        }
    }

    /// The scratch-based infer path equals the training forward exactly
    /// (f32 serving is bit-identical to eval).
    #[test]
    fn infer_x0_matches_forward_all_models() {
        for kind in ModelKind::ALL {
            let model = tiny_model(kind);
            let spec = build_spec(kind, &model.schema, 4, &[8, 8], 2);
            let params = init_params(&spec, &InitConfig { seed: 4, embed_sigma: 0.05 });
            let batch = tiny_batch(&model.schema, 6, 11);
            let want = model.forward(&params, &batch).unwrap();

            // build x0 + wide sums the way the serving tier does
            let b = batch.batch_size();
            let f = model.schema.n_cat();
            let d = model.embed_dim;
            let nd = model.schema.n_dense;
            let d0 = model.d0();
            let ids = batch.x_cat.as_i32().unwrap();
            let dense = batch.x_dense.as_f32().unwrap();
            let mut embed_t: Option<&[f32]> = None;
            let mut wide_t: Option<&[f32]> = None;
            let mut dense_params: Vec<Tensor> = Vec::new();
            for (e, t) in spec.iter().zip(&params.tensors) {
                match e.group.as_str() {
                    "embed" => embed_t = Some(t.as_f32().unwrap()),
                    "wide" => wide_t = Some(t.as_f32().unwrap()),
                    _ => dense_params.push(t.clone()),
                }
            }
            let table = embed_t.unwrap();
            let mut x0 = vec![0.0f32; b * d0];
            embed_concat_fwd(table, ids, dense, b, f, d, nd, &mut x0);
            let wide_sums: Option<Vec<f32>> = wide_t.map(|wt| {
                (0..b)
                    .map(|i| {
                        let mut s = 0.0f32;
                        for &id in &ids[i * f..(i + 1) * f] {
                            s += wt[id as usize];
                        }
                        s
                    })
                    .collect()
            });
            let mut scratch = Scratch::new();
            let got = model
                .infer_x0(&dense_params, &x0, wide_sums.as_deref(), b, &mut scratch)
                .unwrap();
            assert_eq!(got, want, "{kind}: infer_x0 vs forward");
        }
    }
}
