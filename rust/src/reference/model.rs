//! The four CTR models: forward + hand-derived backward, positional
//! parameter layout identical to `python/compile/models/*` specs.

use std::str::FromStr;

use anyhow::{bail, ensure, Result};

use super::layers::*;
use super::linalg::{colsum, matmul, matmul_nt, matmul_tn, rowdot};
use crate::data::batcher::Batch;
use crate::data::schema::Schema;
use crate::model::params::ParamSet;
use crate::tensor::{GradTensor, SparseRows, Tensor};

/// Which architecture to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    DeepFm,
    WideDeep,
    Dcn,
    DcnV2,
}

impl ModelKind {
    pub const ALL: [ModelKind; 4] =
        [ModelKind::DeepFm, ModelKind::WideDeep, ModelKind::Dcn, ModelKind::DcnV2];

    /// Manifest / artifact-id name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::DeepFm => "deepfm",
            ModelKind::WideDeep => "wd",
            ModelKind::Dcn => "dcn",
            ModelKind::DcnV2 => "dcnv2",
        }
    }

    /// Paper row label.
    pub fn label(&self) -> &'static str {
        match self {
            ModelKind::DeepFm => "DeepFM",
            ModelKind::WideDeep => "W&D",
            ModelKind::Dcn => "DCN",
            ModelKind::DcnV2 => "DCN v2",
        }
    }
}

impl FromStr for ModelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "deepfm" => ModelKind::DeepFm,
            "wd" => ModelKind::WideDeep,
            "dcn" => ModelKind::Dcn,
            "dcnv2" => ModelKind::DcnV2,
            other => bail!("unknown model {other:?}"),
        })
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Reference model: architecture constants + schema.
#[derive(Clone, Debug)]
pub struct ReferenceModel {
    pub kind: ModelKind,
    pub schema: Schema,
    pub embed_dim: usize,
    pub hidden: Vec<usize>,
    pub n_cross: usize,
}

impl ReferenceModel {
    pub fn new(kind: ModelKind, schema: Schema, embed_dim: usize, hidden: Vec<usize>, n_cross: usize) -> Self {
        ReferenceModel { kind, schema, embed_dim, hidden, n_cross }
    }

    /// Deep-stream input dimension.
    pub fn d0(&self) -> usize {
        self.schema.n_cat() * self.embed_dim + self.schema.n_dense
    }

    /// Whether this architecture has a wide (LR/FM first-order) stream.
    pub fn uses_wide(&self) -> bool {
        matches!(self.kind, ModelKind::DeepFm | ModelKind::WideDeep)
    }

    /// Forward pass: logits `[b]`.
    pub fn forward(&self, params: &ParamSet, batch: &Batch) -> Result<Vec<f32>> {
        Ok(self.forward_cached(params, batch)?.0)
    }

    /// Loss + positional gradients + per-id occurrence counts — the
    /// reference twin of the AOT `grad` program.
    ///
    /// Row-indexed gradients (embedding + wide tables) come back
    /// **sparse** over the batch's touched ids, and the counts are the
    /// matching `d = 1` sparse vector, so nothing on this path ever
    /// allocates O(V · d).
    pub fn grad(
        &self,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f32, Vec<GradTensor>, SparseRows)> {
        let (logits, cache) = self.forward_cached(params, batch)?;
        let y = batch.y.as_f32()?;
        let (loss, dlogits) = bce_fwd_bwd(&logits, y);
        let (touched, cnts) = batch.touched()?;
        let grads = self.backward(params, batch, &cache, &dlogits, &touched)?;
        let counts = SparseRows::new(self.schema.total_vocab(), 1, touched, cnts);
        Ok((loss, grads, counts))
    }

    /// Batched **inference-only** forward over pre-gathered embeddings —
    /// the serving tier's scoring path. The caller gathers (and, under
    /// quantization, dequantizes) the vocab-table rows itself:
    ///
    /// * `dense` — the non-vocab parameters (every spec entry whose
    ///   group is not `embed`/`wide`), in spec order.
    /// * `embeds` — `[b, n_cat, embed_dim]` gathered embedding rows.
    /// * `wide_sums` — per row `Σ_f wide_table[ids[f]]` (bias *not*
    ///   included), required by the wide-stream models (DeepFM, W&D)
    ///   and ignored otherwise.
    /// * `x_dense` — `[b, n_dense]` dense features.
    ///
    /// The op order mirrors [`ReferenceModel::forward`] exactly, so with
    /// f32 gathers the logits are bit-identical to the training-side
    /// forward; no backward caches are allocated.
    pub fn infer_gathered(
        &self,
        dense: &[&Tensor],
        embeds: &[f32],
        wide_sums: Option<&[f32]>,
        x_dense: &[f32],
        b: usize,
    ) -> Result<Vec<f32>> {
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let nd = self.schema.n_dense;
        let d0 = self.d0();
        ensure!(embeds.len() == b * f * d, "embeds shape mismatch");
        ensure!(x_dense.len() == b * nd, "dense-feature shape mismatch");

        // x0 = concat(flatten(embeds), dense)
        let mut x0 = vec![0.0f32; b * d0];
        for i in 0..b {
            x0[i * d0..i * d0 + f * d].copy_from_slice(&embeds[i * f * d..(i + 1) * f * d]);
            if nd > 0 {
                x0[i * d0 + f * d..(i + 1) * d0].copy_from_slice(&x_dense[i * nd..(i + 1) * nd]);
            }
        }

        let mut r = SliceReader::new(dense);
        let logits = match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                let sums = wide_sums
                    .ok_or_else(|| anyhow::anyhow!("{} needs wide_sums", self.kind))?;
                ensure!(sums.len() == b, "wide_sums length mismatch");
                let wide_bias = r.next()?[0];
                let mut logits: Vec<f32> = sums.iter().map(|&s| wide_bias + s).collect();
                if self.kind == ModelKind::DeepFm {
                    let (fm, _) = fm2_fwd(embeds, b, f, d);
                    for (l, v) in logits.iter_mut().zip(&fm) {
                        *l += v;
                    }
                }
                let mut h = x0;
                let mut m = d0;
                for &n in &self.hidden {
                    let w = r.next()?;
                    let bias = r.next()?;
                    h = dense_infer(&h, w, bias, b, m, n, true);
                    m = n;
                }
                let w = r.next()?;
                let bias = r.next()?;
                let out = dense_infer(&h, w, bias, b, m, 1, false);
                for i in 0..b {
                    logits[i] += out[i];
                }
                logits
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                // cross stream
                let mut xl = x0.clone();
                for _ in 0..self.n_cross {
                    let w = r.next()?;
                    let bias = r.next()?;
                    match self.kind {
                        ModelKind::Dcn => {
                            let s: Vec<f32> = (0..b)
                                .map(|i| {
                                    xl[i * d0..(i + 1) * d0]
                                        .iter()
                                        .zip(w)
                                        .map(|(x, wv)| x * wv)
                                        .sum()
                                })
                                .collect();
                            let mut next = vec![0.0f32; b * d0];
                            for i in 0..b {
                                for j in 0..d0 {
                                    next[i * d0 + j] =
                                        x0[i * d0 + j] * s[i] + bias[j] + xl[i * d0 + j];
                                }
                            }
                            xl = next;
                        }
                        ModelKind::DcnV2 => {
                            let mut u = matmul(&xl, w, b, d0, d0);
                            for i in 0..b {
                                for (uv, &bv) in u[i * d0..(i + 1) * d0].iter_mut().zip(bias) {
                                    *uv += bv;
                                }
                            }
                            let mut next = vec![0.0f32; b * d0];
                            for j in 0..b * d0 {
                                next[j] = x0[j] * u[j] + xl[j];
                            }
                            xl = next;
                        }
                        _ => unreachable!(),
                    }
                }
                // deep stream (hidden only)
                let mut h = x0;
                let mut m = d0;
                for &n in &self.hidden {
                    let w = r.next()?;
                    let bias = r.next()?;
                    h = dense_infer(&h, w, bias, b, m, n, true);
                    m = n;
                }
                // head over concat(xl, deep)
                let hc = d0 + m;
                let mut head_in = vec![0.0f32; b * hc];
                for i in 0..b {
                    head_in[i * hc..i * hc + d0].copy_from_slice(&xl[i * d0..(i + 1) * d0]);
                    head_in[i * hc + d0..(i + 1) * hc].copy_from_slice(&h[i * m..(i + 1) * m]);
                }
                let head_w = r.next()?;
                let head_b = r.next()?;
                dense_infer(&head_in, head_w, head_b, b, hc, 1, false)
            }
        };
        r.finish()?;
        Ok(logits)
    }

    // ------------------------------------------------------------------

    fn forward_cached(&self, params: &ParamSet, batch: &Batch) -> Result<(Vec<f32>, Cache)> {
        let ids = batch.x_cat.as_i32()?;
        let dense = batch.x_dense.as_f32()?;
        let b = batch.batch_size();
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let nd = self.schema.n_dense;
        let d0 = self.d0();
        ensure!(ids.len() == b * f, "batch/cat shape mismatch");

        let mut reader = Reader::new(params);
        let embed_table = reader.next()?; // embed_table
        let embeds = embed_fwd(embed_table, ids, b, f, d);

        // x0 = concat(flatten(embeds), dense)
        let mut x0 = vec![0.0f32; b * d0];
        for i in 0..b {
            x0[i * d0..i * d0 + f * d].copy_from_slice(&embeds[i * f * d..(i + 1) * f * d]);
            if nd > 0 {
                x0[i * d0 + f * d..(i + 1) * d0].copy_from_slice(&dense[i * nd..(i + 1) * nd]);
            }
        }

        let mut cache = Cache {
            embeds,
            x0: x0.clone(),
            fm_sums: Vec::new(),
            wide_used: false,
            mlp: Vec::new(),
            cross: Vec::new(),
            head_in: Vec::new(),
        };

        let mut logits;
        match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                let wide_table = reader.next()?;
                let wide_bias = reader.next()?[0];
                cache.wide_used = true;
                logits = wide_fwd(wide_table, wide_bias, ids, b, f);
                if self.kind == ModelKind::DeepFm {
                    let (fm, sums) = fm2_fwd(&cache.embeds, b, f, d);
                    for (l, v) in logits.iter_mut().zip(&fm) {
                        *l += v;
                    }
                    cache.fm_sums = sums;
                }
                // MLP with scalar head
                let mut h = x0;
                let mut m = d0;
                for &n in &self.hidden {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    let (out, c) = dense_fwd(&h, w, bias, b, m, n, true);
                    cache.mlp.push(c);
                    h = out;
                    m = n;
                }
                let w = reader.next()?;
                let bias = reader.next()?;
                let (out, c) = dense_fwd(&h, w, bias, b, m, 1, false);
                cache.mlp.push(c);
                for i in 0..b {
                    logits[i] += out[i];
                }
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                // cross stream
                let mut xl = x0.clone();
                for _ in 0..self.n_cross {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    match self.kind {
                        ModelKind::Dcn => {
                            // s[i] = xl[i,:] . w ; x_{l+1} = x0*s + b + xl
                            let s: Vec<f32> = (0..b)
                                .map(|i| {
                                    xl[i * d0..(i + 1) * d0]
                                        .iter()
                                        .zip(w)
                                        .map(|(x, wv)| x * wv)
                                        .sum()
                                })
                                .collect();
                            let mut next = vec![0.0f32; b * d0];
                            for i in 0..b {
                                for j in 0..d0 {
                                    next[i * d0 + j] =
                                        x0[i * d0 + j] * s[i] + bias[j] + xl[i * d0 + j];
                                }
                            }
                            cache.cross.push(CrossCache { xl: xl.clone(), su: s });
                            xl = next;
                        }
                        ModelKind::DcnV2 => {
                            // u = xl@W + b ; x_{l+1} = x0 ⊙ u + xl
                            let mut u = matmul(&xl, w, b, d0, d0);
                            for i in 0..b {
                                for (uv, &bv) in u[i * d0..(i + 1) * d0].iter_mut().zip(bias) {
                                    *uv += bv;
                                }
                            }
                            let mut next = vec![0.0f32; b * d0];
                            for j in 0..b * d0 {
                                next[j] = x0[j] * u[j] + xl[j];
                            }
                            cache.cross.push(CrossCache { xl: xl.clone(), su: u });
                            xl = next;
                        }
                        _ => unreachable!(),
                    }
                }
                // deep stream (hidden only)
                let mut h = x0;
                let mut m = d0;
                for &n in &self.hidden {
                    let w = reader.next()?;
                    let bias = reader.next()?;
                    let (out, c) = dense_fwd(&h, w, bias, b, m, n, true);
                    cache.mlp.push(c);
                    h = out;
                    m = n;
                }
                // head over concat(xl, deep)
                let hc = d0 + m;
                let mut head_in = vec![0.0f32; b * hc];
                for i in 0..b {
                    head_in[i * hc..i * hc + d0].copy_from_slice(&xl[i * d0..(i + 1) * d0]);
                    head_in[i * hc + d0..(i + 1) * hc].copy_from_slice(&h[i * m..(i + 1) * m]);
                }
                let head_w = reader.next()?;
                let head_b = reader.next()?;
                let (out, _) = dense_fwd(&head_in, head_w, head_b, b, hc, 1, false);
                cache.head_in = head_in;
                logits = out;
            }
        }
        reader.finish()?;
        Ok((logits, cache))
    }

    fn backward(
        &self,
        params: &ParamSet,
        batch: &Batch,
        cache: &Cache,
        dlogits: &[f32],
        touched: &[u32],
    ) -> Result<Vec<GradTensor>> {
        let ids = batch.x_cat.as_i32()?;
        let b = batch.batch_size();
        let f = self.schema.n_cat();
        let d = self.embed_dim;
        let d0 = self.d0();
        let v = self.schema.total_vocab();

        // gradients per positional slot, filled in spec order at the end
        let mut grads: Vec<GradTensor> = Vec::with_capacity(params.len());
        let mut dx0 = vec![0.0f32; b * d0];
        let mut dembeds = vec![0.0f32; b * f * d];

        match self.kind {
            ModelKind::DeepFm | ModelKind::WideDeep => {
                // wide stream (sparse over the touched ids)
                let (dwide, dbias) = wide_bwd_sparse(dlogits, ids, touched, f);
                // FM stream
                if self.kind == ModelKind::DeepFm {
                    let dfm = fm2_bwd(&cache.embeds, &cache.fm_sums, dlogits, b, f, d);
                    for (a, g) in dembeds.iter_mut().zip(&dfm) {
                        *a += g;
                    }
                }
                // deep stream: walk MLP caches backward
                let n_hidden = self.hidden.len();
                let mut dims = vec![d0];
                dims.extend_from_slice(&self.hidden);
                dims.push(1);
                // collect weight refs in forward order
                let mut weights: Vec<&[f32]> = Vec::new();
                {
                    let mut r = Reader::new(params);
                    let _ = r.next()?; // embed
                    let _ = r.next()?; // wide
                    let _ = r.next()?; // wide_bias
                    for _ in 0..=n_hidden {
                        weights.push(r.next()?);
                        let _ = r.next()?; // bias
                    }
                }
                let mut dy: Vec<f32> = dlogits.to_vec(); // [b,1]
                let mut dws: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                for layer in (0..=n_hidden).rev() {
                    let relu = layer < n_hidden;
                    let (m, n) = (dims[layer], dims[layer + 1]);
                    let (dx, dw, db) =
                        dense_bwd(&dy, &cache.mlp[layer], weights[layer], b, m, n, relu);
                    dws.push((dw, db));
                    dy = dx;
                }
                dws.reverse();
                for (a, g) in dx0.iter_mut().zip(&dy) {
                    *a += g;
                }
                // assemble positional grads: embed, wide, wide_bias, mlp...
                // embed grad needs dx0's embedding slice + dembeds
                for i in 0..b {
                    for t in 0..f * d {
                        dembeds[i * f * d + t] += dx0[i * d0 + t];
                    }
                }
                let dtable = embed_bwd_sparse(&dembeds, ids, touched, d);
                grads.push(GradTensor::Sparse(SparseRows::new(v, d, touched.to_vec(), dtable)));
                grads.push(GradTensor::Sparse(SparseRows::new(v, 1, touched.to_vec(), dwide)));
                grads.push(GradTensor::Dense(Tensor::f32(vec![1], vec![dbias])));
                for (dw, db) in dws {
                    let n = db.len();
                    let m = dw.len() / n;
                    grads.push(GradTensor::Dense(Tensor::f32(vec![m, n], dw)));
                    grads.push(GradTensor::Dense(Tensor::f32(vec![n], db)));
                }
            }
            ModelKind::Dcn | ModelKind::DcnV2 => {
                let n_hidden = self.hidden.len();
                let h_last = *self.hidden.last().unwrap();
                let hc = d0 + h_last;

                // weight refs in forward order
                let mut cross_ws: Vec<&[f32]> = Vec::new();
                let mut mlp_ws: Vec<&[f32]> = Vec::new();
                let head_w: &[f32];
                {
                    let mut r = Reader::new(params);
                    let _ = r.next()?; // embed
                    for _ in 0..self.n_cross {
                        cross_ws.push(r.next()?);
                        let _ = r.next()?;
                    }
                    for _ in 0..n_hidden {
                        mlp_ws.push(r.next()?);
                        let _ = r.next()?;
                    }
                    head_w = r.next()?;
                    let _ = r.next()?;
                    r.finish()?;
                }

                // head backward
                let dhead_w = matmul_tn(&cache.head_in, dlogits, b, hc, 1);
                let dhead_b = colsum(dlogits, b, 1);
                let dhead_in = matmul_nt(dlogits, head_w, b, hc, 1);
                let mut dxl = vec![0.0f32; b * d0];
                let mut dh = vec![0.0f32; b * h_last];
                for i in 0..b {
                    dxl[i * d0..(i + 1) * d0]
                        .copy_from_slice(&dhead_in[i * hc..i * hc + d0]);
                    dh[i * h_last..(i + 1) * h_last]
                        .copy_from_slice(&dhead_in[i * hc + d0..(i + 1) * hc]);
                }

                // deep stream backward
                let mut dims = vec![d0];
                dims.extend_from_slice(&self.hidden);
                let mut mlp_grads: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                let mut dy = dh;
                for layer in (0..n_hidden).rev() {
                    let (m, n) = (dims[layer], dims[layer + 1]);
                    let (dx, dw, db) = dense_bwd(&dy, &cache.mlp[layer], mlp_ws[layer], b, m, n, true);
                    mlp_grads.push((dw, db));
                    dy = dx;
                }
                mlp_grads.reverse();
                for (a, g) in dx0.iter_mut().zip(&dy) {
                    *a += g;
                }

                // cross stream backward
                let mut cross_grads: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                for l in (0..self.n_cross).rev() {
                    let cc = &cache.cross[l];
                    match self.kind {
                        ModelKind::Dcn => {
                            // x_{l+1} = x0 * s + b + xl, s = xl . w
                            let ds = rowdot(&cache.x0, &dxl, b, d0); // [b]
                            let w = cross_ws[l];
                            let mut dw = vec![0.0f32; d0];
                            for i in 0..b {
                                for j in 0..d0 {
                                    dw[j] += ds[i] * cc.xl[i * d0 + j];
                                }
                            }
                            let db = colsum(&dxl, b, d0);
                            // dx0 += s * dxl ; dxl_new = dxl + ds ⊗ w
                            let mut dxl_new = vec![0.0f32; b * d0];
                            for i in 0..b {
                                for j in 0..d0 {
                                    dx0[i * d0 + j] += cc.su[i] * dxl[i * d0 + j];
                                    dxl_new[i * d0 + j] = dxl[i * d0 + j] + ds[i] * w[j];
                                }
                            }
                            cross_grads.push((dw, db));
                            dxl = dxl_new;
                        }
                        ModelKind::DcnV2 => {
                            // x_{l+1} = x0 ⊙ u + xl, u = xl@W + b
                            let mut du = vec![0.0f32; b * d0];
                            for j in 0..b * d0 {
                                du[j] = cache.x0[j] * dxl[j];
                                dx0[j] += cc.su[j] * dxl[j];
                            }
                            let dw = matmul_tn(&cc.xl, &du, b, d0, d0);
                            let db = colsum(&du, b, d0);
                            let dxl_add = matmul_nt(&du, cross_ws[l], b, d0, d0);
                            for j in 0..b * d0 {
                                dxl[j] += dxl_add[j];
                            }
                            cross_grads.push((dw, db));
                        }
                        _ => unreachable!(),
                    }
                }
                cross_grads.reverse();
                // x0 also receives the layer-0 dxl (xl starts as x0)
                for (a, g) in dx0.iter_mut().zip(&dxl) {
                    *a += g;
                }

                for i in 0..b {
                    for t in 0..f * d {
                        dembeds[i * f * d + t] += dx0[i * d0 + t];
                    }
                }
                let dtable = embed_bwd_sparse(&dembeds, ids, touched, d);
                grads.push(GradTensor::Sparse(SparseRows::new(v, d, touched.to_vec(), dtable)));
                for (dw, db) in cross_grads {
                    if self.kind == ModelKind::Dcn {
                        grads.push(GradTensor::Dense(Tensor::f32(vec![d0], dw)));
                    } else {
                        grads.push(GradTensor::Dense(Tensor::f32(vec![d0, d0], dw)));
                    }
                    grads.push(GradTensor::Dense(Tensor::f32(vec![d0], db)));
                }
                for (dw, db) in mlp_grads {
                    let n = db.len();
                    let m = dw.len() / n;
                    grads.push(GradTensor::Dense(Tensor::f32(vec![m, n], dw)));
                    grads.push(GradTensor::Dense(Tensor::f32(vec![n], db)));
                }
                grads.push(GradTensor::Dense(Tensor::f32(vec![hc, 1], dhead_w)));
                grads.push(GradTensor::Dense(Tensor::f32(vec![1], dhead_b)));
            }
        }

        ensure!(grads.len() == params.len(), "gradient arity mismatch");
        for (g, e) in grads.iter().zip(&params.spec) {
            ensure!(g.matches_shape(&e.shape), "grad shape mismatch for {}", e.name);
        }
        Ok(grads)
    }
}

/// Forward caches reused by backward.
struct Cache {
    embeds: Vec<f32>,
    x0: Vec<f32>,
    fm_sums: Vec<f32>,
    #[allow(dead_code)]
    wide_used: bool,
    mlp: Vec<DenseCache>,
    cross: Vec<CrossCache>,
    head_in: Vec<f32>,
}

/// Per-cross-layer cache: the layer input and the scalar/vector gate.
struct CrossCache {
    xl: Vec<f32>,
    /// DCN: `s [b]`; DCNv2: `u [b, d0]`.
    su: Vec<f32>,
}

/// Positional walker over the non-vocab parameter tensors handed to
/// [`ReferenceModel::infer_gathered`].
struct SliceReader<'a> {
    tensors: &'a [&'a Tensor],
    i: usize,
}

impl<'a> SliceReader<'a> {
    fn new(tensors: &'a [&'a Tensor]) -> Self {
        SliceReader { tensors, i: 0 }
    }

    fn next(&mut self) -> Result<&'a [f32]> {
        ensure!(self.i < self.tensors.len(), "dense parameter underflow");
        let t = self.tensors[self.i].as_f32()?;
        self.i += 1;
        Ok(t)
    }

    fn finish(&self) -> Result<()> {
        ensure!(
            self.i == self.tensors.len(),
            "consumed {} of {} dense params",
            self.i,
            self.tensors.len()
        );
        Ok(())
    }
}

/// Positional parameter walker (twin of python's ParamReader).
struct Reader<'a> {
    params: &'a ParamSet,
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(params: &'a ParamSet) -> Self {
        Reader { params, i: 0 }
    }

    fn next(&mut self) -> Result<&'a [f32]> {
        ensure!(self.i < self.params.len(), "parameter underflow");
        let t = self.params.tensors[self.i].as_f32()?;
        self.i += 1;
        Ok(t)
    }

    fn finish(&self) -> Result<()> {
        ensure!(self.i == self.params.len(), "consumed {} of {} params", self.i, self.params.len());
        Ok(())
    }
}
