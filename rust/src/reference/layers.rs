//! Differentiable building blocks with explicit forward caches and
//! hand-derived backward passes (twins of `python/compile/models/common.py`
//! and the Pallas kernels' math).
//!
//! Two tiers, mirroring `linalg`:
//!
//! * The original allocating functions (`embed_fwd`, `dense_fwd`, …) are
//!   kept as the simple reference forms and as oracles for the tests.
//! * The `_into` / `_strided` variants are the hot-path forms: they
//!   write into caller-owned [`super::Scratch`] buffers, fuse the
//!   embedding gather with the `x0` concat ([`embed_concat_fwd`]), and
//!   read/write the embedding block *in place inside `x0`* (stride
//!   `d0`), so the model forward/backward never materializes a separate
//!   `[b, F·d]` embeds tensor.

use super::linalg::{colsum, matmul, matmul_nt, matmul_tn};
use super::simd::Kernels;

/// Embedding gather: `out[b, F, d] = table[ids[b, F]]`.
pub fn embed_fwd(table: &[f32], ids: &[i32], b: usize, f: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(ids.len(), b * f);
    let mut out = vec![0.0f32; b * f * d];
    for (slot, &id) in ids.iter().enumerate() {
        let src = &table[id as usize * d..(id as usize + 1) * d];
        out[slot * d..(slot + 1) * d].copy_from_slice(src);
    }
    out
}

/// Embedding backward: scatter-add `g[b, F, d]` into `dtable[V, d]`.
pub fn embed_bwd(g: &[f32], ids: &[i32], v: usize, d: usize) -> Vec<f32> {
    let mut dtable = vec![0.0f32; v * d];
    for (slot, &id) in ids.iter().enumerate() {
        let dst = &mut dtable[id as usize * d..(id as usize + 1) * d];
        for (t, &gv) in dst.iter_mut().zip(&g[slot * d..(slot + 1) * d]) {
            *t += gv;
        }
    }
    dtable
}

/// Sparse twin of [`embed_bwd`]: scatter-add `g[b, F, d]` into the
/// packed rows of the sorted unique `touched` id list (which must
/// contain every id in `ids`). Output is `touched.len() * d` values —
/// O(b·F·(log T + d)) instead of O(V·d).
pub fn embed_bwd_sparse(g: &[f32], ids: &[i32], touched: &[u32], d: usize) -> Vec<f32> {
    let mut vals = vec![0.0f32; touched.len() * d];
    for (slot, &id) in ids.iter().enumerate() {
        let k = touched
            .binary_search(&(id as u32))
            .expect("batch id missing from touched list");
        let dst = &mut vals[k * d..(k + 1) * d];
        for (t, &gv) in dst.iter_mut().zip(&g[slot * d..(slot + 1) * d]) {
            *t += gv;
        }
    }
    vals
}

/// Fused gather + concat: one pass builds `x0[b, d0]` rows as
/// `[table[ids[i, 0]] … table[ids[i, F-1]] | dense_x[i]]` — the
/// embedding read and the deep-stream input concat the model used to do
/// in two passes (gather into a `[b, F·d]` embeds buffer, then copy)
/// collapse into a single write per row. `d0 = f·d + nd`.
pub fn embed_concat_fwd(
    table: &[f32],
    ids: &[i32],
    dense_x: &[f32],
    b: usize,
    f: usize,
    d: usize,
    nd: usize,
    x0: &mut [f32],
) {
    let d0 = f * d + nd;
    debug_assert_eq!(ids.len(), b * f);
    debug_assert_eq!(dense_x.len(), b * nd);
    debug_assert_eq!(x0.len(), b * d0);
    for (i, row) in x0.chunks_exact_mut(d0).enumerate() {
        for (j, &id) in ids[i * f..(i + 1) * f].iter().enumerate() {
            row[j * d..(j + 1) * d]
                .copy_from_slice(&table[id as usize * d..(id as usize + 1) * d]);
        }
        if nd > 0 {
            row[f * d..].copy_from_slice(&dense_x[i * nd..(i + 1) * nd]);
        }
    }
}

/// Strided twin of [`embed_bwd_sparse`]: scatter-add the embedding block
/// of each `dx0` row (columns `[0, f·d)` of a `[b, stride]` layout) into
/// the packed rows of the sorted unique `touched` id list. Slot order is
/// identical to the flat twin, so results are bitwise equal.
pub fn embed_bwd_sparse_strided(
    g: &[f32],
    stride: usize,
    ids: &[i32],
    touched: &[u32],
    f: usize,
    d: usize,
) -> Vec<f32> {
    debug_assert!(stride >= f * d);
    let mut vals = vec![0.0f32; touched.len() * d];
    for (slot, &id) in ids.iter().enumerate() {
        let (i, j) = (slot / f, slot % f);
        let k = touched
            .binary_search(&(id as u32))
            .expect("batch id missing from touched list");
        let src = &g[i * stride + j * d..i * stride + (j + 1) * d];
        let dst = &mut vals[k * d..(k + 1) * d];
        for (t, &gv) in dst.iter_mut().zip(src) {
            *t += gv;
        }
    }
    vals
}

/// Wide (first-order) logit: `out[b] = bias + sum_f wide[ids[b,f]]`.
pub fn wide_fwd(wide: &[f32], bias: f32, ids: &[i32], b: usize, f: usize) -> Vec<f32> {
    (0..b)
        .map(|i| {
            bias + ids[i * f..(i + 1) * f]
                .iter()
                .map(|&id| wide[id as usize])
                .sum::<f32>()
        })
        .collect()
}

/// Wide backward: `(dwide[V], dbias)` from upstream `dout[b]`.
pub fn wide_bwd(dout: &[f32], ids: &[i32], v: usize, b: usize, f: usize) -> (Vec<f32>, f32) {
    let mut dwide = vec![0.0f32; v];
    let mut dbias = 0.0f32;
    for i in 0..b {
        dbias += dout[i];
        for &id in &ids[i * f..(i + 1) * f] {
            dwide[id as usize] += dout[i];
        }
    }
    (dwide, dbias)
}

/// Sparse twin of [`wide_bwd`]: `(dwide[touched.len()], dbias)`.
pub fn wide_bwd_sparse(
    dout: &[f32],
    ids: &[i32],
    touched: &[u32],
    f: usize,
) -> (Vec<f32>, f32) {
    let mut dwide = vec![0.0f32; touched.len()];
    let mut dbias = 0.0f32;
    for (i, &dv) in dout.iter().enumerate() {
        dbias += dv;
        for &id in &ids[i * f..(i + 1) * f] {
            let k = touched
                .binary_search(&(id as u32))
                .expect("batch id missing from touched list");
            dwide[k] += dv;
        }
    }
    (dwide, dbias)
}

/// Write-into twin of [`wide_fwd`]: same per-row accumulation order, no
/// allocation.
pub fn wide_fwd_into(wide: &[f32], bias: f32, ids: &[i32], b: usize, f: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), b);
    for (i, o) in out.iter_mut().enumerate() {
        let mut s = 0.0f32;
        for &id in &ids[i * f..(i + 1) * f] {
            s += wide[id as usize];
        }
        *o = bias + s;
    }
}

/// FM second-order term (twin of the Pallas `fm2` kernel):
/// `out[b] = 0.5 * sum_d((sum_f v)^2 - sum_f v^2)`. Returns the cached
/// field-sum `[b, d]` used by the backward pass.
pub fn fm2_fwd(v: &[f32], b: usize, f: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut out = vec![0.0f32; b];
    let mut sums = vec![0.0f32; b * d];
    for i in 0..b {
        let base = i * f * d;
        let srow = &mut sums[i * d..(i + 1) * d];
        let mut sq = vec![0.0f32; d];
        for fj in 0..f {
            for t in 0..d {
                let x = v[base + fj * d + t];
                srow[t] += x;
                sq[t] += x * x;
            }
        }
        out[i] = 0.5 * srow.iter().zip(&sq).map(|(s, q)| s * s - q).sum::<f32>();
    }
    (out, sums)
}

/// FM backward: `dv[b,f,:] = (sum_f' v - v[b,f,:]) * dout[b]`.
pub fn fm2_bwd(v: &[f32], sums: &[f32], dout: &[f32], b: usize, f: usize, d: usize) -> Vec<f32> {
    let mut dv = vec![0.0f32; b * f * d];
    for i in 0..b {
        let srow = &sums[i * d..(i + 1) * d];
        let ct = dout[i];
        for fj in 0..f {
            let base = i * f * d + fj * d;
            for t in 0..d {
                dv[base + t] = (srow[t] - v[base + t]) * ct;
            }
        }
    }
    dv
}

/// Strided, write-into twin of [`fm2_fwd`]: the embedding block lives in
/// the first `f·d` columns of each `[b, stride]` row of `x` (i.e. inside
/// `x0` directly, no separate embeds tensor). `out[b]`, `sums[b, d]` and
/// the per-row square accumulator `sq[d]` are caller-owned scratch.
/// Accumulation order matches [`fm2_fwd`] exactly (bitwise).
#[allow(clippy::too_many_arguments)]
pub fn fm2_fwd_strided(
    x: &[f32],
    stride: usize,
    b: usize,
    f: usize,
    d: usize,
    out: &mut [f32],
    sums: &mut [f32],
    sq: &mut [f32],
) {
    debug_assert!(stride >= f * d);
    debug_assert_eq!(out.len(), b);
    debug_assert_eq!(sums.len(), b * d);
    debug_assert_eq!(sq.len(), d);
    for i in 0..b {
        let base = i * stride;
        let srow = &mut sums[i * d..(i + 1) * d];
        srow.fill(0.0);
        sq.fill(0.0);
        for fj in 0..f {
            for t in 0..d {
                let v = x[base + fj * d + t];
                srow[t] += v;
                sq[t] += v * v;
            }
        }
        out[i] = 0.5 * srow.iter().zip(sq.iter()).map(|(s, q)| s * s - q).sum::<f32>();
    }
}

/// Strided, *accumulating* twin of [`fm2_bwd`]: adds
/// `(sum_f' v - v[b,f,:]) * dout[b]` into the embedding block of each
/// `dv` row (`[b, dv_stride]` layout) — so the FM gradient lands
/// directly in `dx0` without a separate dembeds buffer.
#[allow(clippy::too_many_arguments)]
pub fn fm2_bwd_strided_acc(
    x: &[f32],
    x_stride: usize,
    sums: &[f32],
    dout: &[f32],
    b: usize,
    f: usize,
    d: usize,
    dv: &mut [f32],
    dv_stride: usize,
) {
    debug_assert!(x_stride >= f * d && dv_stride >= f * d);
    for i in 0..b {
        let srow = &sums[i * d..(i + 1) * d];
        let ct = dout[i];
        for fj in 0..f {
            let xb = i * x_stride + fj * d;
            let db = i * dv_stride + fj * d;
            for t in 0..d {
                dv[db + t] += (srow[t] - x[xb + t]) * ct;
            }
        }
    }
}

/// One dense layer cache: input and pre-activation.
pub struct DenseCache {
    pub x: Vec<f32>,
    pub pre: Vec<f32>,
}

/// Affine + optional ReLU. Caches enough for backward.
pub fn dense_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    m: usize,
    n: usize,
    relu: bool,
) -> (Vec<f32>, DenseCache) {
    let mut y = matmul(x, w, b, m, n);
    for i in 0..b {
        for (yv, &bv) in y[i * n..(i + 1) * n].iter_mut().zip(bias) {
            *yv += bv;
        }
    }
    let pre = y.clone();
    if relu {
        for yv in &mut y {
            if *yv < 0.0 {
                *yv = 0.0;
            }
        }
    }
    (y, DenseCache { x: x.to_vec(), pre })
}

/// Inference-only twin of [`dense_fwd`]: identical affine + optional
/// ReLU math, but no backward cache is allocated — the serving tier's
/// forward must not pay for gradient state it will never use.
pub fn dense_infer(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    m: usize,
    n: usize,
    relu: bool,
) -> Vec<f32> {
    let mut y = matmul(x, w, b, m, n);
    for i in 0..b {
        for (yv, &bv) in y[i * n..(i + 1) * n].iter_mut().zip(bias) {
            *yv += bv;
        }
    }
    if relu {
        for yv in &mut y {
            if *yv < 0.0 {
                *yv = 0.0;
            }
        }
    }
    y
}

/// Write-into twin of [`dense_fwd`]: affine into `pre` (kept for the
/// backward relu mask), activated copy into `out`. The matmul routes
/// through the caller's kernel vtable (`k`); with the scalar vtable the
/// op order matches the allocating form exactly (bitwise).
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_into(
    k: &Kernels,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    m: usize,
    n: usize,
    relu: bool,
    pre: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(pre.len(), b * n);
    debug_assert_eq!(out.len(), b * n);
    (k.matmul_into)(x, w, pre, b, m, n);
    for row in pre.chunks_exact_mut(n) {
        for (yv, &bv) in row.iter_mut().zip(bias) {
            *yv += bv;
        }
    }
    out.copy_from_slice(pre);
    if relu {
        for yv in out.iter_mut() {
            if *yv < 0.0 {
                *yv = 0.0;
            }
        }
    }
}

/// Write-into twin of [`dense_infer`]: no pre-activation kept. The
/// matmul routes through the caller's kernel vtable (`k`).
#[allow(clippy::too_many_arguments)]
pub fn dense_infer_into(
    k: &Kernels,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    m: usize,
    n: usize,
    relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), b * n);
    (k.matmul_into)(x, w, out, b, m, n);
    for row in out.chunks_exact_mut(n) {
        for (yv, &bv) in row.iter_mut().zip(bias) {
            *yv += bv;
        }
    }
    if relu {
        for yv in out.iter_mut() {
            if *yv < 0.0 {
                *yv = 0.0;
            }
        }
    }
}

/// In-place ReLU backward mask: zero `dy` wherever the cached
/// pre-activation was non-positive.
pub fn relu_mask(dy: &mut [f32], pre: &[f32]) {
    debug_assert_eq!(dy.len(), pre.len());
    for (gv, &p) in dy.iter_mut().zip(pre) {
        if p <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Write-into twin of [`bce_fwd_bwd`]: the gradient lands in
/// caller-owned `dlogits`, the mean loss is returned.
pub fn bce_fwd_bwd_into(logits: &[f32], y: &[f32], dlogits: &mut [f32]) -> f32 {
    let b = logits.len();
    debug_assert_eq!(dlogits.len(), b);
    let mut loss = 0.0f64;
    for i in 0..b {
        let z = logits[i] as f64;
        let yi = y[i] as f64;
        loss += z.max(0.0) - z * yi + (-z.abs()).exp().ln_1p();
        let p = 1.0 / (1.0 + (-z).exp());
        dlogits[i] = ((p - yi) / b as f64) as f32;
    }
    (loss / b as f64) as f32
}

/// Backward of `dense_fwd`. Returns `(dx, dw, dbias)`.
pub fn dense_bwd(
    dy: &[f32],
    cache: &DenseCache,
    w: &[f32],
    b: usize,
    m: usize,
    n: usize,
    relu: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut g = dy.to_vec();
    if relu {
        for (gv, &p) in g.iter_mut().zip(&cache.pre) {
            if p <= 0.0 {
                *gv = 0.0;
            }
        }
    }
    let dx = matmul_nt(&g, w, b, m, n);
    let dw = matmul_tn(&cache.x, &g, b, m, n);
    let db = colsum(&g, b, n);
    (dx, dw, db)
}

/// Stable BCE-with-logits mean loss and its gradient
/// `dlogit = (sigmoid(z) - y) / b`.
pub fn bce_fwd_bwd(logits: &[f32], y: &[f32]) -> (f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; logits.len()];
    let loss = bce_fwd_bwd_into(logits, y, &mut dlogits);
    (loss, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embed_roundtrip_gradient() {
        let table = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // V=3, d=2
        let ids = [0i32, 2, 2, 1];
        let out = embed_fwd(&table, &ids, 2, 2, 2);
        assert_eq!(out, vec![1.0, 2.0, 5.0, 6.0, 5.0, 6.0, 3.0, 4.0]);
        let g = vec![1.0f32; 8];
        let dt = embed_bwd(&g, &ids, 3, 2);
        assert_eq!(dt, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0]); // id 2 hit twice
    }

    #[test]
    fn sparse_backward_twins_match_dense() {
        let ids = [0i32, 2, 2, 1];
        let touched = [0u32, 1, 2];
        let g = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]; // b=2, f=2, d=2
        let dense = embed_bwd(&g, &ids, 3, 2);
        let sparse = embed_bwd_sparse(&g, &ids, &touched, 2);
        for (k, &id) in touched.iter().enumerate() {
            assert_eq!(&sparse[k * 2..(k + 1) * 2], &dense[id as usize * 2..(id as usize + 1) * 2]);
        }

        let dout = [1.0f32, 2.0];
        let (dw_dense, db_dense) = wide_bwd(&dout, &ids, 3, 2, 2);
        let (dw_sparse, db_sparse) = wide_bwd_sparse(&dout, &ids, &touched, 2);
        assert_eq!(db_dense, db_sparse);
        for (k, &id) in touched.iter().enumerate() {
            assert_eq!(dw_sparse[k], dw_dense[id as usize]);
        }
    }

    #[test]
    fn wide_fwd_bwd() {
        let wide = [0.1f32, 0.2, 0.3];
        let ids = [0i32, 2, 1, 1];
        let out = wide_fwd(&wide, 1.0, &ids, 2, 2);
        assert!((out[0] - 1.4).abs() < 1e-6);
        assert!((out[1] - 1.4).abs() < 1e-6);
        let (dw, db) = wide_bwd(&[1.0, 2.0], &ids, 3, 2, 2);
        assert_eq!(dw, vec![1.0, 4.0, 1.0]);
        assert_eq!(db, 3.0);
    }

    #[test]
    fn fm2_matches_bruteforce() {
        let (b, f, d) = (2usize, 3usize, 2usize);
        let v: Vec<f32> = (0..b * f * d).map(|i| (i as f32) * 0.1 - 0.4).collect();
        let (out, _) = fm2_fwd(&v, b, f, d);
        for i in 0..b {
            let mut brute = 0.0f32;
            for a in 0..f {
                for c in (a + 1)..f {
                    for t in 0..d {
                        brute += v[i * f * d + a * d + t] * v[i * f * d + c * d + t];
                    }
                }
            }
            assert!((out[i] - brute).abs() < 1e-5);
        }
    }

    #[test]
    fn fm2_gradient_finite_difference() {
        let (b, f, d) = (1usize, 3usize, 2usize);
        let mut v: Vec<f32> = vec![0.3, -0.2, 0.5, 0.1, -0.4, 0.25];
        let (_, sums) = fm2_fwd(&v, b, f, d);
        let dv = fm2_bwd(&v, &sums, &[1.0], b, f, d);
        let eps = 1e-3f32;
        for i in 0..v.len() {
            let orig = v[i];
            v[i] = orig + eps;
            let (hi, _) = fm2_fwd(&v, b, f, d);
            v[i] = orig - eps;
            let (lo, _) = fm2_fwd(&v, b, f, d);
            v[i] = orig;
            let fd = (hi[0] - lo[0]) / (2.0 * eps);
            assert!((fd - dv[i]).abs() < 1e-3, "i={i}: fd {fd} vs {}", dv[i]);
        }
    }

    #[test]
    fn dense_relu_gradient_finite_difference() {
        let (b, m, n) = (2usize, 3usize, 2usize);
        let x: Vec<f32> = vec![0.5, -1.0, 0.3, 0.8, 0.2, -0.6];
        let mut w: Vec<f32> = vec![0.4, -0.3, 0.7, 0.2, -0.5, 0.1];
        let bias = vec![0.05f32, -0.1];
        let loss = |w: &[f32]| -> f32 {
            let (y, _) = dense_fwd(&x, w, &bias, b, m, n, true);
            y.iter().sum()
        };
        let (_, cache) = dense_fwd(&x, &w, &bias, b, m, n, true);
        let dy = vec![1.0f32; b * n];
        let (_, dw, _) = dense_bwd(&dy, &cache, &w, b, m, n, true);
        let eps = 1e-3;
        for i in 0..w.len() {
            let orig = w[i];
            w[i] = orig + eps;
            let hi = loss(&w);
            w[i] = orig - eps;
            let lo = loss(&w);
            w[i] = orig;
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - dw[i]).abs() < 1e-2, "i={i}: fd {fd} vs {}", dw[i]);
        }
    }

    #[test]
    fn dense_infer_matches_dense_fwd() {
        let (b, m, n) = (3usize, 4usize, 2usize);
        let x: Vec<f32> = (0..b * m).map(|i| (i as f32) * 0.17 - 1.0).collect();
        let w: Vec<f32> = (0..m * n).map(|i| (i as f32) * 0.05 - 0.2).collect();
        let bias = vec![0.3f32, -0.4];
        for relu in [false, true] {
            let (y, _) = dense_fwd(&x, &w, &bias, b, m, n, relu);
            let yi = dense_infer(&x, &w, &bias, b, m, n, relu);
            assert_eq!(y, yi, "relu={relu}");
        }
    }

    #[test]
    fn fused_concat_matches_gather_plus_copy() {
        let (b, f, d, nd) = (3usize, 2usize, 2usize, 2usize);
        let d0 = f * d + nd;
        let table: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect(); // V=5, d=2
        let ids = [0i32, 4, 2, 1, 3, 3];
        let dense: Vec<f32> = (0..b * nd).map(|i| -(i as f32)).collect();
        // oracle: gather then concat
        let embeds = embed_fwd(&table, &ids, b, f, d);
        let mut want = vec![0.0f32; b * d0];
        for i in 0..b {
            want[i * d0..i * d0 + f * d].copy_from_slice(&embeds[i * f * d..(i + 1) * f * d]);
            want[i * d0 + f * d..(i + 1) * d0].copy_from_slice(&dense[i * nd..(i + 1) * nd]);
        }
        let mut x0 = vec![9.0f32; b * d0];
        embed_concat_fwd(&table, &ids, &dense, b, f, d, nd, &mut x0);
        assert_eq!(x0, want);
        // no dense features
        let mut x0nd = vec![9.0f32; b * f * d];
        embed_concat_fwd(&table, &ids, &[], b, f, d, 0, &mut x0nd);
        assert_eq!(x0nd, embeds);
    }

    #[test]
    fn strided_fm2_and_scatter_match_flat_oracles() {
        let (b, f, d, nd) = (4usize, 3usize, 2usize, 1usize);
        let d0 = f * d + nd;
        let mut x0 = vec![0.0f32; b * d0];
        let v: Vec<f32> = (0..b * f * d).map(|i| (i as f32) * 0.13 - 0.7).collect();
        for i in 0..b {
            x0[i * d0..i * d0 + f * d].copy_from_slice(&v[i * f * d..(i + 1) * f * d]);
            x0[i * d0 + f * d] = 99.0; // dense column must be ignored
        }
        let (out_o, sums_o) = fm2_fwd(&v, b, f, d);
        let mut out = vec![0.0f32; b];
        let mut sums = vec![0.0f32; b * d];
        let mut sq = vec![0.0f32; d];
        fm2_fwd_strided(&x0, d0, b, f, d, &mut out, &mut sums, &mut sq);
        assert_eq!(out, out_o);
        assert_eq!(sums, sums_o);

        let dout = [1.0f32, -2.0, 0.5, 3.0];
        let dv_o = fm2_bwd(&v, &sums_o, &dout, b, f, d);
        let mut dx0 = vec![0.25f32; b * d0];
        fm2_bwd_strided_acc(&x0, d0, &sums, &dout, b, f, d, &mut dx0, d0);
        for i in 0..b {
            for t in 0..f * d {
                assert_eq!(dx0[i * d0 + t], 0.25 + dv_o[i * f * d + t], "i={i} t={t}");
            }
            assert_eq!(dx0[i * d0 + f * d], 0.25, "dense column must be untouched");
        }

        // strided sparse scatter == flat sparse scatter on the embed block
        let ids = [0i32, 2, 1, 1, 0, 2, 2, 0, 1, 0, 1, 2];
        let touched = [0u32, 1, 2];
        let flat = embed_bwd_sparse(&dv_o, &ids, &touched, d);
        // build a strided g holding dv in the embed block
        let mut g = vec![7.0f32; b * d0];
        for i in 0..b {
            g[i * d0..i * d0 + f * d].copy_from_slice(&dv_o[i * f * d..(i + 1) * f * d]);
        }
        let strided = embed_bwd_sparse_strided(&g, d0, &ids, &touched, f, d);
        assert_eq!(strided, flat);
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let (b, m, n) = (3usize, 5usize, 4usize);
        let x: Vec<f32> = (0..b * m).map(|i| (i as f32) * 0.11 - 0.8).collect();
        let w: Vec<f32> = (0..m * n).map(|i| (i as f32) * 0.07 - 0.6).collect();
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.1 - 0.2).collect();
        for relu in [false, true] {
            let k = super::super::simd::scalar();
            let (y, cache) = dense_fwd(&x, &w, &bias, b, m, n, relu);
            let mut pre = vec![1.0f32; b * n];
            let mut out = vec![2.0f32; b * n];
            dense_fwd_into(k, &x, &w, &bias, b, m, n, relu, &mut pre, &mut out);
            assert_eq!(out, y, "relu={relu}");
            assert_eq!(pre, cache.pre, "relu={relu}");
            let mut out2 = vec![3.0f32; b * n];
            dense_infer_into(k, &x, &w, &bias, b, m, n, relu, &mut out2);
            assert_eq!(out2, y, "infer relu={relu}");
        }
        // wide into
        let wide = [0.1f32, 0.2, 0.3];
        let ids = [0i32, 2, 1, 1];
        let want = wide_fwd(&wide, 1.0, &ids, 2, 2);
        let mut got = vec![0.0f32; 2];
        wide_fwd_into(&wide, 1.0, &ids, 2, 2, &mut got);
        assert_eq!(got, want);
        // bce into
        let logits = [0.3f32, -1.2, 2.0];
        let ys = [1.0f32, 0.0, 1.0];
        let (l1, d1) = bce_fwd_bwd(&logits, &ys);
        let mut d2 = vec![0.0f32; 3];
        let l2 = bce_fwd_bwd_into(&logits, &ys, &mut d2);
        assert_eq!(l1, l2);
        assert_eq!(d1, d2);
        // relu mask
        let mut dy = vec![1.0f32, 2.0, 3.0, 4.0];
        relu_mask(&mut dy, &[0.5, -0.1, 0.0, 2.0]);
        assert_eq!(dy, vec![1.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn bce_known_values_and_grad() {
        let (loss, d) = bce_fwd_bwd(&[0.0, 0.0], &[1.0, 0.0]);
        assert!((loss - std::f64::consts::LN_2 as f32).abs() < 1e-6);
        assert!((d[0] + 0.25).abs() < 1e-6); // (0.5-1)/2
        assert!((d[1] - 0.25).abs() < 1e-6);
    }
}
