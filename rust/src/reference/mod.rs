//! Pure-Rust reference implementation of the full training math.
//!
//! This is a from-scratch twin of the L2 JAX programs: forward and
//! hand-derived backward passes for all four CTR models, plus a complete
//! training step (clip → L2 → Adam). It serves three purposes:
//!
//! 1. **Parity oracle** — integration tests drive the HLO artifacts and
//!    this engine on identical inputs and require matching gradients,
//!    losses and updates, which is the strongest end-to-end correctness
//!    signal the repo has.
//! 2. **No-artifact fallback** — `cowclip train --engine reference` runs
//!    without `make artifacts` (slower; used in CI-like environments).
//! 3. **Finite-difference ground truth** — the backward passes themselves
//!    are verified against numerical gradients in this module's tests.

pub mod layers;
pub mod linalg;
pub mod model;
pub mod scratch;
pub mod simd;
pub mod step;

pub use model::{ModelKind, ReferenceModel};
pub use scratch::Scratch;
pub use simd::{KernelMode, Kernels};
pub use step::{GradOutput, ReferenceEngine};
