//! Model metadata: the AOT manifest contract and parameter management.
//!
//! The L2 compile path owns the model *math*; this module owns the model
//! *state*: positional parameter layout (from `artifacts/manifest.json`),
//! host-side initialization matching the paper's recipe, and checkpoints.

pub mod init;
pub mod manifest;
pub mod params;

pub use init::{init_params, InitConfig};
pub use manifest::{Artifact, Manifest, ParamEntry};
pub use params::ParamSet;
