//! Model metadata: the AOT manifest contract and parameter management.
//!
//! The L2 compile path owns the model *math*; this module owns the model
//! *state*: positional parameter layout (from `artifacts/manifest.json`),
//! host-side initialization matching the paper's recipe, the shard-owned
//! [`store::ParamStore`] (weights + Adam moments + maintained per-field
//! norms, partitioned for the parallel apply stage), and checkpoints.

pub mod init;
pub mod manifest;
pub mod params;
pub mod store;

pub use init::{init_params, InitConfig};
pub use manifest::{Artifact, Manifest, ParamEntry};
pub use params::ParamSet;
pub use store::{inspect_checkpoint, ApplyCtx, CheckpointEntry, CheckpointInfo, ParamStore, ShardPlan};
